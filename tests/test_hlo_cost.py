"""Trip-count-aware HLO cost accounting: equality with cost_analysis() on
loop-free graphs; correct trip multiplication on scanned graphs (where
cost_analysis undercounts); collective accounting inside loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze, peak_live_bytes
from tests.util import run_with_devices

D = 128


def _flops_of(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0]
    mine = analyze(compiled.as_text())
    return float(c.get("flops", 0.0)), mine


def test_matches_cost_analysis_loop_free():
    w = jax.random.normal(jax.random.PRNGKey(0), (D, D))
    x = jax.random.normal(jax.random.PRNGKey(1), (D, D))

    def fn(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    xla_flops, mine = _flops_of(fn, x, w)
    assert mine.flops == pytest.approx(4 * 2 * D ** 3, rel=0.01)
    assert mine.flops == pytest.approx(xla_flops, rel=0.05)


def test_scan_trip_count_multiplied():
    w = jax.random.normal(jax.random.PRNGKey(0), (D, D))
    x = jax.random.normal(jax.random.PRNGKey(1), (D, D))

    def fn(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    xla_flops, mine = _flops_of(fn, x, w)
    assert xla_flops == pytest.approx(2 * D ** 3, rel=0.01)  # the known bug
    assert mine.flops == pytest.approx(10 * 2 * D ** 3, rel=0.01)  # fixed


def test_nested_scan():
    w = jax.random.normal(jax.random.PRNGKey(0), (D, D))
    x = jax.random.normal(jax.random.PRNGKey(1), (D, D))

    def fn(x, w):
        def inner(c, _):
            return jnp.tanh(c @ w), None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    _, mine = _flops_of(fn, x, w)
    assert mine.flops == pytest.approx(15 * 2 * D ** 3, rel=0.01)


def test_dot_general_batched():
    a = jax.random.normal(jax.random.PRNGKey(0), (8, 32, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (8, 64, 16))
    _, mine = _flops_of(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    assert mine.flops == pytest.approx(2 * 8 * 32 * 64 * 16, rel=0.01)


def test_bytes_scale_with_trip_count():
    w = jax.random.normal(jax.random.PRNGKey(0), (D, D))
    x = jax.random.normal(jax.random.PRNGKey(1), (D, D))

    def make(n):
        def fn(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        return fn

    _, c5 = _flops_of(make(5), x, w)
    _, c10 = _flops_of(make(10), x, w)
    assert c10.bytes == pytest.approx(2 * c5.bytes, rel=0.1)


def test_peak_live_bytes_sees_largest_intermediate():
    """The liveness sweep must at least account for the biggest live value
    and stay within a small factor of XLA's own buffer accounting."""
    w = jax.random.normal(jax.random.PRNGKey(0), (D, D))
    x = jax.random.normal(jax.random.PRNGKey(1), (D, D))

    def fn(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    compiled = jax.jit(fn).lower(x, w).compile()
    peak = peak_live_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    xla = mem.temp_size_in_bytes + mem.argument_size_in_bytes
    assert peak >= D * D * 4  # one live matrix, at minimum
    assert xla * 0.5 <= peak <= xla * 6, (peak, xla)


def test_peak_live_bytes_sees_scan_stacked_residuals():
    """A scan that stacks residuals must dominate the peak (this is the
    structure of the naive/pnode reverse passes the planner compares)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (D, D))
    x = jax.random.normal(jax.random.PRNGKey(1), (D, D))

    def make(n):
        def fn(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), c
            return jax.lax.scan(body, x, None, length=n)
        return fn

    peaks = []
    for n in (4, 16):
        compiled = jax.jit(make(n)).lower(x, w).compile()
        peaks.append(peak_live_bytes(compiled.as_text()))
        assert peaks[-1] >= n * D * D * 4  # the stacked ys buffer
    assert peaks[1] > 2 * peaks[0]  # grows with trip count


@pytest.mark.slow
def test_collectives_inside_scan_multiplied():
    out = run_with_devices("""
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.hlo_cost import analyze
mesh = jax.make_mesh((8,), ("d",))
x = jnp.ones((8, 64), jnp.float32)

def inner(x):
    def body(c, _):
        return jax.lax.psum(c, "d"), None
    out, _ = jax.lax.scan(body, x, None, length=7)
    return out

fn = shard_map(inner, mesh=mesh, in_specs=(P("d"),), out_specs=P("d"),
               check_rep=False)
compiled = jax.jit(fn).lower(x).compile()
c = analyze(compiled.as_text())
per_step = 1 * 64 * 4   # one (1,64) f32 shard all-reduced per step
total = c.collective_bytes["all-reduce"]
assert abs(total - 7 * per_step) / (7 * per_step) < 0.05, total
print("COLL_OK", total)
""")
    assert "COLL_OK" in out
