"""repro.mem: offload-store gradient identity, budget planner, cost model,
and the odeint(adjoint="auto", mem_budget=...) acceptance criterion.

Offload grads must be *bitwise* identical to the in-device policies: the
store only relocates checkpoints, the adjoint arithmetic (op sequence and
operand values) is unchanged.  Planner monotonicity and the auto-policy
budget check are deterministic parametrized cases (no hypothesis — the
offline stub has no shrinking to offer here anyway).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import odeint_adaptive
from repro.core.adjoint import odeint
from repro.mem import (DeviceStore, HostStore, SpillStore, candidate_costs,
                       host_memory_kind, measure_reverse_cost,
                       plan_depth_remat, plan_odeint, policy_cost,
                       tree_bytes)

jax.config.update("jax_enable_x64", True)

D = 6
N_STEPS = 12
DT = 0.05


def _vf():
    def f(u, th, t):
        return jnp.tanh(th["W"] @ u + th["b"]) + 0.1 * jnp.sin(t) * u
    return f


def _problem(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    u0 = jax.random.normal(ks[0], (D,))
    th = {"W": 0.3 * jax.random.normal(ks[1], (D, D)),
          "b": 0.1 * jax.random.normal(ks[2], (D,))}
    return u0, th


def _grads(policy, *, method="rk4", n_steps=N_STEPS, **kw):
    f = _vf()
    u0, th = _problem()

    def loss(u0_, th_):
        uf = odeint(f, u0_, th_, dt=DT, n_steps=n_steps, method=method,
                    adjoint=policy, **kw)
        return jnp.sum(uf ** 2)

    return jax.grad(loss, argnums=(0, 1))(u0, th)


def _assert_bitwise(g, g_ref):
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# offload stores: gradient identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,kw", [
    ("pnode", {}),
    ("revolve", {"ncheck": 3}),
    ("revolve2", {"ncheck": 3}),
])
def test_spill_grads_bitwise_identical(policy, kw):
    """Host-spilled checkpoints change WHERE data lives, not the math."""
    _assert_bitwise(_grads(policy, offload="spill", **kw),
                    _grads(policy, **kw))


@pytest.mark.parametrize("policy,kw", [("revolve", {"ncheck": 3}),
                                       ("revolve2", {"ncheck": 2})])
def test_host_offload_grads_bitwise_identical(policy, kw):
    """pinned-host tier (degrades to device on XLA:CPU, still exact)."""
    _assert_bitwise(_grads(policy, offload="host", **kw),
                    _grads(policy, **kw))


def test_spill_grads_under_jit():
    f = _vf()
    u0, th = _problem()

    def gfn(offload):
        def L(u0_, th_):
            return jnp.sum(odeint(f, u0_, th_, dt=DT, n_steps=N_STEPS,
                                  adjoint="pnode", offload=offload) ** 2)
        return jax.jit(jax.grad(L, argnums=(0, 1)))(u0, th)

    _assert_bitwise(gfn("spill"), gfn(None))


def test_adaptive_spill_grads_bitwise_identical():
    f = _vf()
    u0, th = _problem()

    def gfn(offload):
        def L(u0_, th_):
            uf, _ = odeint_adaptive(f, u0_, th_, t0=0.0, t1=0.6,
                                    rtol=1e-6, atol=1e-6, max_steps=64,
                                    offload=offload)
            return jnp.sum(uf ** 2)
        return jax.grad(L, argnums=(0, 1))(u0, th)

    _assert_bitwise(gfn("spill"), gfn(None))


def test_host_store_degrades_on_cpu_and_reports():
    st = HostStore()
    assert st.effective_tier in ("host", "device")
    if host_memory_kind() is None:
        assert st.effective_tier == "device"


def test_spill_store_roundtrip_and_free():
    st = SpillStore()
    tree = {"a": jnp.arange(4.0), "b": (jnp.ones((2, 3)),)}
    st.put(5, tree)
    jax.block_until_ready(st._tok)
    got = st.get(5)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st.free(5)
    jax.block_until_ready(st._tok)
    assert 5 not in st._host


def test_device_store_pack_order_matches_slots():
    st = DeviceStore()
    st.put(0, "x0")
    st.put(7, "x7")
    assert st.pack() == ("x0", "x7")
    st2 = DeviceStore()
    st2.unpack(("x0", "x7"), [0, 7])
    assert st2.get(7) == "x7"


# ---------------------------------------------------------------------------
# input validation (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ncheck", [0, -3])
def test_nonpositive_ncheck_rejected(ncheck):
    with pytest.raises(ValueError, match="positive"):
        _grads("revolve", ncheck=ncheck)


@pytest.mark.parametrize("ncheck", [N_STEPS, N_STEPS + 5])
def test_oversized_ncheck_rejected(ncheck):
    with pytest.raises(ValueError, match="n_steps"):
        _grads("revolve", ncheck=ncheck)


@pytest.mark.parametrize("policy", ["revolve", "revolve2"])
def test_revolve_without_ncheck_suggests_auto(policy):
    with pytest.raises(ValueError, match="auto"):
        _grads(policy)


def test_mem_budget_without_auto_rejected():
    with pytest.raises(ValueError, match="auto"):
        _grads("pnode", mem_budget=10 ** 9)


def test_bad_offload_tier_rejected():
    with pytest.raises(ValueError, match="offload"):
        _grads("pnode", offload="vram")
    with pytest.raises(ValueError, match="offload"):
        _grads("naive", offload="spill")


# ---------------------------------------------------------------------------
# planner (satellite: deterministic monotonicity; tentpole: budget solve)
# ---------------------------------------------------------------------------

def _rank(plan):
    # offloaded plans trade f-evals for transfer bytes the NFE metric does
    # not see; they are strictly worse than any fitting in-device plan
    return (0 if plan.offload is None else 1, plan.extra_fevals)


def test_planner_monotone_in_budget_model_mode():
    """Larger budget => never more extra f-evals (and never a forced
    offload when an in-device policy previously fit)."""
    f = _vf()
    u0, th = _problem()
    budgets = [1_000, 2_000, 3_000, 5_000, 8_000, 12_000, 20_000, 50_000,
               10 ** 6, 10 ** 9]
    prev = None
    for budget in budgets:
        plan = plan_odeint(f, u0, th, dt=DT, n_steps=N_STEPS, method="rk4",
                           mem_budget=budget, verify="model")
        rank = _rank(plan)
        if prev is not None:
            assert rank <= prev, (budget, rank, prev)
        prev = rank


def test_planner_unconstrained_is_pnode():
    f = _vf()
    u0, th = _problem()
    plan = plan_odeint(f, u0, th, dt=DT, n_steps=N_STEPS, method="rk4")
    assert plan.policy == "pnode" and plan.offload is None


def test_planner_huge_budget_is_naive():
    f = _vf()
    u0, th = _problem()
    plan = plan_odeint(f, u0, th, dt=DT, n_steps=N_STEPS, method="rk4",
                       mem_budget=10 ** 12, verify="model")
    assert plan.policy == "naive" and plan.extra_fevals == 0


def test_planner_tiny_budget_offloads():
    f = _vf()
    u0, th = _problem()
    plan = plan_odeint(f, u0, th, dt=DT, n_steps=N_STEPS, method="rk4",
                       mem_budget=1, verify="model")
    assert plan.offload == "spill" and plan.policy == "pnode"


def test_candidates_sorted_by_recompute():
    costs = candidate_costs(method="dopri5", n_steps=16, state_bytes=1024,
                            theta_bytes=4096, mem_budget=10 ** 6)
    extras = [c.extra_fevals for c in costs]
    assert extras == sorted(extras)
    assert costs[0].policy == "naive"


def test_plan_depth_remat_ladder():
    from repro.configs.base import ShapeCell
    from repro.configs.registry import get_arch
    cfg = get_arch("smollm-135m")
    cell = ShapeCell("t", 128, 8, "train")
    remats = [plan_depth_remat(cfg, cell, b)[0]
              for b in (10 ** 12, 10 ** 8, 10 ** 7, 10 ** 4)]
    # shrinking budget walks down the recompute ladder monotonically
    order = {"none": 0, "sqrt": 1, "full": 2, "revolve": 3}
    assert [order[r] for r in remats] == sorted(order[r] for r in remats)
    assert remats[0] == "none" and remats[-1] == "revolve"


# ---------------------------------------------------------------------------
# cost model vs lowered HLO (tentpole validation)
# ---------------------------------------------------------------------------

def test_model_ranks_policies_like_measurement():
    """The analytic model must order the Table-2 policies the same way the
    lowered HLO does — that ordering is what the planner relies on."""
    f = _vf()
    u0, th = _problem()
    from repro.mem import f_activation_bytes
    kw = dict(dt=DT, n_steps=N_STEPS, method="rk4")
    sb, tb = tree_bytes(u0), tree_bytes(th)
    fa = f_activation_bytes(f, u0, th)
    assert fa > sb  # the O(N_l) AD-residual term naive pays per stage
    order = [("naive", None), ("pnode", None), ("pnode2", None)]
    measured = [measure_reverse_cost(f, u0, th, policy=p, ncheck=k,
                                     **kw)["hlo_peak_bytes"]
                for p, k in order]
    predicted = [policy_cost(p, method="rk4", n_steps=N_STEPS,
                             state_bytes=sb, theta_bytes=tb, f_act_bytes=fa,
                             ncheck=k).peak_bytes
                 for p, k in order]
    assert measured == sorted(measured, reverse=True), measured
    assert predicted == sorted(predicted, reverse=True), predicted


def test_model_checkpoint_term_scales_with_n_steps():
    """Prediction and measurement must agree on the *slope* sign and rough
    magnitude of the pnode checkpoint growth (Fig. 3's claim)."""
    f = _vf()
    u0, th = _problem()
    sb, tb = tree_bytes(u0), tree_bytes(th)

    def both(n):
        m = measure_reverse_cost(f, u0, th, dt=DT, n_steps=n, method="rk4",
                                 policy="pnode")["hlo_peak_bytes"]
        p = policy_cost("pnode", method="rk4", n_steps=n, state_bytes=sb,
                        theta_bytes=tb).peak_bytes
        return m, p

    m8, p8 = both(8)
    m16, p16 = both(16)
    assert m16 > m8 and p16 > p8
    meas_slope = (m16 - m8) / 8
    pred_slope = (p16 - p8) / 8
    assert 0.2 < pred_slope / meas_slope < 5.0, (pred_slope, meas_slope)


def test_spill_shrinks_measured_residuals():
    """The offload claim, measured: spilling pnode checkpoints removes the
    O(N_t) term from the reverse pass's peak live bytes."""
    f = _vf()
    u0, th = _problem()
    kw = dict(dt=DT, method="rk4", policy="pnode")
    dev = [measure_reverse_cost(f, u0, th, n_steps=n, **kw)["hlo_peak_bytes"]
           for n in (8, 24)]
    spl = [measure_reverse_cost(f, u0, th, n_steps=n, offload="spill",
                                **kw)["hlo_peak_bytes"]
           for n in (8, 24)]
    dev_slope = (dev[1] - dev[0]) / 16
    spl_slope = (spl[1] - spl[0]) / 16
    assert spl[1] < dev[1]
    assert spl_slope < 0.25 * dev_slope, (dev, spl)


# ---------------------------------------------------------------------------
# acceptance: odeint(adjoint="auto", mem_budget=B)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["euler", "midpoint", "bosh3", "rk4",
                                    "dopri5"])
def test_auto_grads_match_naive_all_tableaus(method):
    """auto under a pnode-sized budget: grads == naive to the suite's
    existing tolerances, for every tableau."""
    f = _vf()
    u0, th = _problem()
    n = 8
    budget = int(measure_reverse_cost(
        f, u0, th, dt=DT, n_steps=n, method=method,
        policy="pnode")["hlo_peak_bytes"])

    def loss(policy):
        def L(u0_, th_):
            return jnp.sum(odeint(
                f, u0_, th_, dt=DT, n_steps=n, method=method,
                adjoint=policy,
                **({"mem_budget": budget} if policy == "auto" else {})) ** 2)
        return jax.grad(L, argnums=(0, 1))(u0, th)

    g = loss("auto")
    g_ref = loss("naive")
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-13)


@pytest.mark.parametrize("anchor,ncheck", [
    ("pnode", None), ("pnode2", None), ("revolve", 3)])
def test_auto_measured_peak_fits_budget(anchor, ncheck):
    """The acceptance criterion: when the budget equals a known policy's
    measured peak (so at least one policy fits), the planner's choice
    measures <= the budget on the lowered reverse pass."""
    f = _vf()
    u0, th = _problem()
    kw = dict(dt=DT, n_steps=N_STEPS, method="rk4")
    budget = int(measure_reverse_cost(f, u0, th, policy=anchor,
                                      ncheck=ncheck, **kw)["hlo_peak_bytes"])
    plan = plan_odeint(f, u0, th, mem_budget=budget, **kw)
    assert plan.fits
    chosen = measure_reverse_cost(f, u0, th, policy=plan.policy,
                                  ncheck=plan.ncheck, offload=plan.offload,
                                  **kw)["hlo_peak_bytes"]
    assert chosen <= budget, (plan.policy, plan.ncheck, chosen, budget)
    # and the choice is reverse-accurate
    g = _grads(plan.policy, ncheck=plan.ncheck, offload=plan.offload)
    g_ref = _grads("naive")
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-13)
