"""MoE invariants (hypothesis): dropless conservation of gate mass, capacity
monotonicity, and exactness vs a dense per-token reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # hermetic container: deterministic fallback examples
    from tests._hypothesis_stub import given, settings, st

from repro.nn.moe import init_moe, moe_block


def _dense_ref(params, x, n_experts, top_k, act="silu"):
    """Per-token dense reference: run every token through its top-k experts
    directly (no capacity, no dispatch)."""
    from repro.nn.layers import act_fn
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["w_router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, top_k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xf)
    for e in range(n_experts):
        g = act_fn(act)(xf @ params["w_gate"][e])
        u = xf @ params["w_up"][e]
        y = (g * u) @ params["w_down"][e]
        w = jnp.where(gi == e, gv, 0.0).sum(-1)
        out = out + y * w[:, None]
    return out.reshape(b, s, d)


@given(e=st.sampled_from([2, 4]), k=st.sampled_from([1, 2]),
       t=st.sampled_from([16, 32]))
@settings(max_examples=8, deadline=None)
def test_dropless_matches_dense_reference(e, k, t):
    params = init_moe(jax.random.PRNGKey(0), 16, 32, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t, 16))
    out, _ = moe_block(params, x, n_experts=e, top_k=k,
                       capacity_factor=float(e))   # dropless
    ref = _dense_ref(params, x, e, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_dropping_reduces_output_mass():
    """Tiny capacity must drop tokens (outputs shrink toward zero), and
    capacity is monotone."""
    e, k = 4, 2
    params = init_moe(jax.random.PRNGKey(0), 16, 32, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    norms = []
    for cf in (0.25, 1.0, float(e)):
        out, _ = moe_block(params, x, n_experts=e, top_k=k,
                           capacity_factor=cf)
        norms.append(float(jnp.linalg.norm(out)))
    assert norms[0] < norms[2]
    assert norms[1] <= norms[2] + 1e-5


def test_aux_loss_uniform_router_is_one():
    """With a uniform router, Switch aux loss -> E * E * (1/E)*(1/E) = 1."""
    e = 4
    params = init_moe(jax.random.PRNGKey(0), 16, 32, e)
    params = dict(params, w_router=jnp.zeros_like(params["w_router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 16))
    _, aux = moe_block(params, x, n_experts=e, top_k=1,
                       capacity_factor=float(e))
    np.testing.assert_allclose(float(aux), 1.0, atol=0.1)


def test_group_size_invariance_when_dropless():
    e, k = 4, 2
    params = init_moe(jax.random.PRNGKey(0), 16, 32, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    o1, _ = moe_block(params, x, n_experts=e, top_k=k,
                      capacity_factor=float(e), group_size=16)
    o2, _ = moe_block(params, x, n_experts=e, top_k=k,
                      capacity_factor=float(e), group_size=128)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)
