"""Multi-tier checkpointing (PR 9): the file-backed disk spill tier, the
dolfin-adjoint ``snaps_in_ram`` RAM/disk slot split, truly-async segment
prefetch, the segment-flushed adaptive forward sweep, and the planner's
RAM/disk budget split.

The load-bearing assertions are *bitwise*: every new storage medium and
every async path must reproduce the device-tier gradient exactly — the
paper's reproducibility contract is tier-invariant."""
import glob
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adjoint import odeint
from repro.core.implicit import odeint_implicit
from repro.ft import FaultPlan, FaultSpec
from repro.mem.model import policy_cost, slot_bytes
from repro.mem.offload import (_DISK_PREFIX, make_store, reset_spill_stats,
                               spill_stats)
from repro.mem.planner import plan_odeint

jax.config.update("jax_enable_x64", True)

D = 3
U0 = jnp.array([0.1, -0.4, 0.9])
TH = jnp.linspace(0.5, 1.5, D)
N_STEPS = 21


def _f(u, th, t):
    return jnp.sin(u) * th + 0.1 * jnp.cos(t)


def _grad(**kw):
    def loss(th):
        uf = odeint(_f, U0, th, dt=0.02, n_steps=N_STEPS, **kw)
        return jnp.sum(uf ** 2)

    return np.asarray(jax.jit(jax.grad(loss))(TH))


@pytest.fixture(scope="module")
def g_dev():
    return _grad()


# ---------------------------------------------------------------------------
# disk tier + RAM/disk split: bitwise vs the device oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(offload="disk"),
    dict(offload="disk", offload_segment=8),
    dict(offload="spill", snaps_in_ram=0),
    dict(offload="spill", snaps_in_ram=3),
    dict(offload="spill", snaps_in_ram=10_000),  # split never triggers
])
def test_disk_and_split_grads_bitwise(kw, g_dev):
    assert np.array_equal(_grad(**kw), g_dev)


@pytest.mark.parametrize("kw", [
    dict(adjoint="revolve", ncheck=5, offload="disk"),
    dict(adjoint="revolve2", ncheck=5, offload="spill", snaps_in_ram=2),
])
def test_revolve_slots_on_disk_bitwise(kw, g_dev):
    assert np.array_equal(_grad(**kw), g_dev)


def test_disk_tier_actually_hits_disk():
    reset_spill_stats()
    _grad(offload="disk")
    st = spill_stats()
    assert st["disk_write_bytes"] > 0
    assert st["disk_read_bytes"] == st["disk_write_bytes"]
    assert st["ram_bytes_peak"] == 0  # nothing RAM-resident on pure disk


def test_split_caps_ram_resident_bytes():
    # slot = (stages+1)*state for rk4; 3 slots in RAM, the rest on disk.
    # routing is whole-batch: the segment must fit the RAM cap for any
    # batch to stay resident, so use segment=2 < snaps_in_ram=3
    cap = 3 * slot_bytes("rk4", U0.size * U0.dtype.itemsize)
    reset_spill_stats()
    _grad(offload="spill", snaps_in_ram=3, offload_segment=2)
    st = spill_stats()
    assert 0 < st["ram_bytes_peak"] <= cap
    assert st["disk_write_bytes"] > 0


def test_offload_dir_pins_files_and_sweeps_stale(tmp_path):
    # a dead run's stale segment file must be swept on store init
    stale = tmp_path / (_DISK_PREFIX + "deadbeef.npz")
    stale.write_bytes(b"not a real npz")
    st = make_store("disk", disk_dir=str(tmp_path))
    assert st.swept_files == 1
    assert not stale.exists()

    g = _grad(offload="disk", offload_dir=str(tmp_path))
    assert np.array_equal(g, _grad())
    # the run's own files are cleaned up with the store; the caller-owned
    # directory survives
    assert tmp_path.exists()


def test_disk_files_cleaned_up_on_store_gc(tmp_path):
    st = make_store("disk", disk_dir=str(tmp_path))
    tok = st.init_token()
    rows = jnp.arange(8.0).reshape(4, 2)
    tok = st.write_batch(tok, 0, rows)
    jax.block_until_ready(tok)
    assert len(glob.glob(str(tmp_path / (_DISK_PREFIX + "*.npz")))) == 1
    del st, tok
    import gc
    # the dispatch cache pins the store via its callback closures — the
    # finalize fires once the last reference (cache entry) is gone
    jax.clear_caches()
    gc.collect()
    assert glob.glob(str(tmp_path / (_DISK_PREFIX + "*.npz"))) == []


# ---------------------------------------------------------------------------
# store-level: remainder zero-fill, split census, token ordering
# ---------------------------------------------------------------------------

def test_disk_remainder_segment_zero_fill_roundtrip():
    # 5 slots written, segment reads of 4: the second read's tail (slots
    # 6,7) was never written and must come back zero-filled, not garbage
    st = make_store("disk")
    tok = st.init_token()
    rows = jnp.arange(10.0).reshape(5, 2)
    tok = st.write_batch(tok, 0, rows)
    tok, seg0 = st.prefetch(tok, 0, 4)
    tok, seg1 = st.prefetch(tok, 4, 4)
    jax.block_until_ready((seg0, seg1))
    assert np.array_equal(np.asarray(seg0), np.asarray(rows[:4]))
    assert np.array_equal(np.asarray(seg1[0]), np.asarray(rows[4]))
    assert np.all(np.asarray(seg1[1:]) == 0.0)


def test_split_store_census_routes_overflow_to_disk():
    st = make_store("spill", snaps_in_ram=3)
    tok = st.init_token()
    tok = st.write_batch(tok, 0, jnp.ones((3, 2)))
    tok = st.write_batch(tok, 3, jnp.ones((4, 2)) * 2)
    jax.block_until_ready(tok)
    census = st.slot_census()
    assert census == {"ram": 3, "disk": 4, "disk_files": 1}
    tok, seg = st.prefetch(tok, 3, 4)
    jax.block_until_ready(seg)
    assert np.all(np.asarray(seg) == 2.0)


def test_async_prefetch_token_ordering_snapshot():
    """Regression (satellite): an issued background gather must serve the
    bytes as of ISSUE time — a write that lands between issue and wait
    cannot leak into the already-dispatched read (the token chain orders
    the callbacks; the executor job snapshots under the I/O lock)."""
    st = make_store("spill")
    tok = st.init_token()
    first = jnp.arange(8.0).reshape(4, 2)
    tok = st.write_batch(tok, 0, first)
    tok = st.prefetch_issue(tok, 0, 4)
    # overwrite the same slots AFTER the issue, BEFORE the wait
    tok = st.write_batch(tok, 0, first * 100.0)
    tok, seg = st.prefetch(tok, 0, 4)
    jax.block_until_ready(seg)
    assert np.array_equal(np.asarray(seg), np.asarray(first))
    stats = st.stats
    assert stats["dispatch_cb"] == 1
    assert stats["prefetch_hit_cb"] == 1


def test_reverse_sweep_pipelines_prefetch():
    # the scanned bwd issues segment k-1 while adjointing segment k: with
    # >1 full segment every wait but possibly the first is an async hit
    reset_spill_stats()
    _grad(offload="spill", offload_segment=4)
    st = spill_stats()
    assert st["dispatch_cb"] >= 1
    assert st["prefetch_hit_cb"] == st["dispatch_cb"]
    # dispatches are token-only: data callbacks stay O(N/seg)
    n_segments = -(-N_STEPS // 4)
    assert st["read_cb"] == n_segments


# ---------------------------------------------------------------------------
# disk-tier fault injection: CRC + resilient recompute stays bitwise
# ---------------------------------------------------------------------------

def _imp_grad(plan=None, resilient=False, **kw):
    def loss(th):
        uf = odeint_implicit(_f, U0, th, dt=0.05, n_steps=12, method="cn",
                             adjoint="pnode", offload_segment=4,
                             newton_iters=8, newton_tol=1e-12,
                             fault_plan=plan, resilient=resilient, **kw)
        return jnp.sum(uf ** 2)

    return np.asarray(jax.jit(jax.grad(loss))(jnp.asarray(0.7)))


def test_disk_corruption_resilient_recompute_bitwise():
    clean = _imp_grad(offload="disk")
    plan = FaultPlan([FaultSpec("spill.write", 1, "corrupt")])
    reset_spill_stats()
    g = _imp_grad(offload="disk", plan=plan, resilient=True)
    assert np.array_equal(g, clean)
    assert spill_stats()["integrity_fail"] >= 1
    assert plan.fired_count("spill.write") == 1


def test_split_tier_corruption_resilient_bitwise():
    clean = _imp_grad(offload="spill")
    plan = FaultPlan([FaultSpec("spill.write", 2, "corrupt")])
    g = _imp_grad(offload="spill", snaps_in_ram=1, plan=plan,
                  resilient=True)
    assert np.array_equal(g, clean)


# ---------------------------------------------------------------------------
# cost model + planner: the RAM/disk split is solved, priced, explained
# ---------------------------------------------------------------------------

def test_cost_model_prices_the_split():
    sb = slot_bytes("rk4", 100)
    full = policy_cost("pnode", method="rk4", n_steps=64, state_bytes=100,
                       offload="spill")
    assert full.ram_bytes == full.ckpt_bytes and full.disk_bytes == 0
    split = policy_cost("pnode", method="rk4", n_steps=64, state_bytes=100,
                        offload="spill", snaps_in_ram=10)
    assert split.ram_bytes == 10 * sb
    assert split.disk_bytes == split.ckpt_bytes - 10 * sb
    disk = policy_cost("pnode", method="rk4", n_steps=64, state_bytes=100,
                       offload="disk")
    assert disk.ram_bytes == 0 and disk.disk_bytes == disk.ckpt_bytes
    # disk bandwidth < RAM bandwidth: all-disk must price slower than
    # all-RAM at equal bytes
    assert disk.io_seconds > full.io_seconds
    # offloaded peaks exclude the checkpoint set regardless of medium
    assert disk.peak_bytes == disk.work_bytes


def test_planner_solves_snaps_split_under_ram_budget():
    sb = slot_bytes("rk4", U0.size * 8)
    p = plan_odeint(_f, U0, TH, dt=0.02, n_steps=64, ram_budget=10 * sb,
                    verify="model", explain=True)
    assert (p.policy, p.offload) == ("pnode", "spill")
    assert p.snaps_in_ram == 10 and p.snaps_on_disk == 54
    assert p.fits
    assert p.report[-1].snaps_in_ram == 10

    # zero-slot RAM budget degenerates to the pure disk tier
    p0 = plan_odeint(_f, U0, TH, dt=0.02, n_steps=64, ram_budget=sb - 1,
                     verify="model")
    assert p0.offload == "disk" and p0.snaps_in_ram is None
    assert p0.snaps_on_disk == 64

    # overflow beyond the disk budget is flagged, not hidden
    pbad = plan_odeint(_f, U0, TH, dt=0.02, n_steps=64, ram_budget=10 * sb,
                       disk_budget=sb, verify="model")
    assert not pbad.fits


def test_auto_with_ram_budget_end_to_end_bitwise(g_dev):
    sb = slot_bytes("rk4", U0.size * 8)
    g = _grad(adjoint="auto", ram_budget=4 * sb, mem_verify="model")
    assert np.array_equal(g, g_dev)
    g0 = _grad(adjoint="auto", ram_budget=0, mem_verify="model")
    assert np.array_equal(g0, g_dev)


def test_budget_knobs_require_auto():
    with pytest.raises(ValueError, match="ram_budget"):
        _grad(adjoint="pnode", ram_budget=1 << 20)
    with pytest.raises(ValueError, match="snaps_in_ram"):
        _grad(offload="host", snaps_in_ram=2)
    with pytest.raises(ValueError, match="offload_dir"):
        _grad(offload="host", offload_dir="/tmp/x")


# ---------------------------------------------------------------------------
# adaptive forward staging ring: O(N/seg) callbacks, tiers bitwise
# ---------------------------------------------------------------------------

def test_adaptive_disk_and_split_bitwise():
    from repro.core.adaptive import odeint_adaptive

    def loss(th, **kw):
        uf, _ = odeint_adaptive(_f, U0, th, t0=0.0, t1=1.0, rtol=1e-8,
                                atol=1e-8, max_steps=128, **kw)
        return jnp.sum(uf ** 2)

    g_dev = np.asarray(jax.jit(jax.grad(loss))(TH))
    for kw in (dict(offload="disk"), dict(offload="spill", snaps_in_ram=2),
               dict(offload="disk", offload_segment=5)):
        g = np.asarray(
            jax.jit(jax.grad(lambda t, kw=kw: loss(t, **kw)))(TH))
        assert np.array_equal(g, g_dev), kw


# ---------------------------------------------------------------------------
# launch drift guard (satellite): zero/absent prediction -> drift=null
# ---------------------------------------------------------------------------

def test_train_peak_drift_guard_zero_prediction(tmp_path):
    from repro.configs.base import ShapeCell, reduced
    from repro.configs.registry import get_arch
    from repro.launch.train import train
    from repro.obs.sink import MetricsSink, read_jsonl

    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    cell = ShapeCell("t", 32, 2, "train")
    path = tmp_path / "metrics.jsonl"
    with MetricsSink(str(path)) as sink:
        # predicted_peak_bytes=0 (planner skipped / dryrun): must not
        # divide by zero — the compile record still lands, drift=null
        train(cfg, cell, steps=2, sink=sink, predicted_peak_bytes=0,
              log_fn=lambda *_: None)
    recs = [r for r in read_jsonl(str(path)) if r["event"] == "train.compile"]
    assert len(recs) == 1
    assert recs[0]["drift"] is None
