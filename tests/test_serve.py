"""repro.serve (PR 10): the continuous-batching inference service and the
per-request checkpoint key scheme it rides on.

The load-bearing assertions are *bitwise*: a batched offloaded solve
(vmapped odeint with lane-keyed spill/disk checkpoints) must reproduce
the unbatched per-request loop exactly — across tiers, across the
RAM/disk split, with padding lanes in the batch, and across changing
batch compositions through one compiled program.  Scheduler tests prove
FIFO-with-aging cannot starve a request under sustained high-priority
load, and store tests prove departures free their slots."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import odeint_adaptive
from repro.core.adjoint import odeint
from repro.core.cnf import exact_trace_vf
from repro.mem.offload import make_store
from repro.mem.planner import plan_odeint
from repro.models.ode_nets import cnf_vf, cnf_vf_init
from repro.obs import FlightRecorder, MetricsRegistry
from repro.serve import (AdmissionError, BucketSpec, ODEEngine,
                         RequestQueue)

DIM = 3
DT, N_STEPS, SEG = 0.1, 8, 4


@pytest.fixture(scope="module", autouse=True)
def _f32_regime():
    # the serve stack targets the f32 regime; other test modules flip the
    # global x64 flag at import (collection order is alphabetical), so pin
    # it off for this whole module — module fixtures included
    with jax.experimental.disable_x64():
        yield


@pytest.fixture(scope="module")
def theta():
    return cnf_vf_init(jax.random.PRNGKey(0), DIM, hidden=(8, 8))


@pytest.fixture(scope="module")
def xs():
    rng = np.random.default_rng(7)
    return rng.normal(size=(5, DIM)).astype(np.float32)


def _logp_ref(**kw):
    """Unbatched reference density (same formula the engine uses).  Takes
    theta as a traced ARGUMENT like the engine's compiled programs do —
    closing over it would let XLA constant-fold differently and shift the
    last ulp."""
    aug = exact_trace_vf(cnf_vf, DIM)

    def logp(th, x_):
        z, dl = odeint(aug, (x_, jnp.zeros((), x_.dtype)), th,
                       dt=DT, n_steps=N_STEPS, method="rk4",
                       adjoint="pnode", **kw)
        return (-0.5 * jnp.sum(z ** 2)
                - 0.5 * DIM * jnp.log(2 * jnp.pi) + dl)

    return logp


# -- queue: admission -------------------------------------------------------

def test_admission_rejections():
    reg = MetricsRegistry()
    q = RequestQueue(kinds=("density",), dim=DIM, max_payload_bytes=64,
                     registry=reg)
    with pytest.raises(AdmissionError):
        q.submit("nope", np.zeros(DIM, np.float32))
    with pytest.raises(AdmissionError):
        q.submit("density", np.zeros(DIM + 1, np.float32))  # wrong dim
    with pytest.raises(AdmissionError):
        q.submit("density", np.zeros(100, np.float64))  # over byte cap
    with pytest.raises(AdmissionError):
        q.submit("density", np.array([1.0, np.nan, 0.0], np.float32))
    with pytest.raises(AdmissionError):
        q.submit("density", np.array(["a"] * DIM))  # non-numeric
    assert reg.counter("serve.rejected") == 5
    assert q.depth() == 0
    q.submit("density", np.zeros(DIM, np.float32))
    assert reg.counter("serve.submitted") == 1
    assert q.depth() == 1


# -- queue: scheduling ------------------------------------------------------

def test_fifo_aging_no_starvation():
    """A zero-priority request survives a sustained stream of
    high-priority arrivals: its aging score grows without bound, so it is
    scheduled within (max_priority/aging)+1 ticks."""
    q = RequestQueue(kinds=("k",), dim=1, aging=1.0)
    victim = None
    victim_tk = q.submit("k", np.zeros(1, np.float32), rid="victim")
    served = []
    for i in range(20):
        q.submit("k", np.zeros(1, np.float32), priority=5.0, rid=f"vip{i}")
        batch = q.next_batch(1)
        served.extend(r.rid for r, _ in batch)
        if "victim" in served:
            victim = i
            break
    assert victim is not None and victim <= 6, served
    assert not victim_tk.done()  # scheduled, not yet resolved
    # ties broken by arrival order: same-priority requests serve FIFO
    q2 = RequestQueue(kinds=("k",), dim=1, aging=1.0)
    for i in range(4):
        q2.submit("k", np.zeros(1, np.float32), rid=f"r{i}")
    got = [r.rid for r, _ in q2.next_batch(4)]
    assert got == ["r0", "r1", "r2", "r3"]


def test_aging_zero_can_starve():
    """Control: with aging disabled, strict priority DOES starve — the
    aging term is the no-starvation mechanism, not an accident."""
    q = RequestQueue(kinds=("k",), dim=1, aging=0.0)
    q.submit("k", np.zeros(1, np.float32), rid="victim")
    served = []
    for i in range(20):
        q.submit("k", np.zeros(1, np.float32), priority=5.0, rid=f"vip{i}")
        served.extend(r.rid for r, _ in q.next_batch(1))
    assert "victim" not in served


def test_kind_homogeneous_batches():
    q = RequestQueue(kinds=("a", "b"), dim=1, aging=1.0)
    for i in range(3):
        q.submit("a", np.zeros(1, np.float32), rid=f"a{i}")
        q.submit("b", np.zeros(1, np.float32), rid=f"b{i}")
    batch = q.next_batch(8)
    kinds = {r.kind for r, _ in batch}
    assert len(kinds) == 1 and len(batch) == 3


def test_bucket_spec():
    b = BucketSpec((1, 2, 4, 8))
    assert [b.bucket_for(n) for n in (1, 2, 3, 5, 8, 9)] == \
        [1, 2, 4, 8, 8, 8]
    assert b.max_size == 8
    with pytest.raises(ValueError):
        BucketSpec((0, 2))


# -- the per-request key scheme: bitwise vs the unbatched loop --------------

@pytest.mark.parametrize("tier_kw", [
    dict(offload="spill"),
    dict(offload="disk"),
    dict(offload="spill", snaps_in_ram=3),
], ids=["spill", "disk", "split"])
def test_engine_bitwise_fixed(theta, xs, tier_kw, tmp_path):
    """Batched (vmapped, lane-keyed, jitted) density and score through the
    engine == the unbatched per-request loop, bit for bit — including the
    padding lanes a non-full bucket adds."""
    eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                    offload_segment=SEG, buckets=BucketSpec((4,)),
                    spool_dir=str(tmp_path), **tier_kw)
    t_d = [eng.submit("density", x) for x in xs[:3]]  # 3 lanes + 1 pad
    eng.run()
    t_s = [eng.submit("score", x) for x in xs[:3]]
    eng.run()
    logp = jax.jit(_logp_ref())
    score = jax.jit(jax.grad(_logp_ref(), argnums=1))
    for tk, x in zip(t_d, xs[:3]):
        assert np.array_equal(
            np.asarray(tk.result(5), np.float32),
            np.asarray(logp(theta, jnp.asarray(x)), np.float32))
    for tk, x in zip(t_s, xs[:3]):
        assert np.array_equal(tk.result(5),
                              np.asarray(score(theta, jnp.asarray(x))))
    census = eng.slot_census()
    assert not any(census.values()), census


def test_engine_bitwise_across_compositions(theta, xs):
    """One compiled bucket program serves CHANGING batch compositions:
    lane keys are consulted at callback execution time, so re-keying does
    not retrace and every composition stays bitwise."""
    eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                    offload="spill", offload_segment=SEG,
                    buckets=BucketSpec((2,)))
    score = jax.jit(jax.grad(_logp_ref(), argnums=1))
    # three rounds through the same (score, bucket=2) program
    for lo, hi in ((0, 2), (2, 4), (4, 5)):  # last round: 1 lane + pad
        ts = [eng.submit("score", x) for x in xs[lo:hi]]
        eng.run()
        for tk, x in zip(ts, xs[lo:hi]):
            assert np.array_equal(
                tk.result(5), np.asarray(score(theta, jnp.asarray(x))))
    assert len(eng._fns) == 1  # one compiled program served all rounds


def test_engine_bitwise_adaptive(theta, xs):
    """The adaptive per-request loop path: engine results == direct
    odeint_adaptive calls (density and score)."""
    eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                    offload="spill", offload_segment=SEG, adaptive=True,
                    max_steps=64)
    aug = exact_trace_vf(cnf_vf, DIM)
    t1 = DT * N_STEPS

    # reference takes theta as a traced ARGUMENT like the engine does —
    # closing over it would let XLA constant-fold differently and shift
    # the last ulp
    def logp(th, x_):
        (z, dl), _ = odeint_adaptive(
            aug, (x_, jnp.zeros((), x_.dtype)), th, t0=0.0, t1=t1,
            rtol=1e-6, atol=1e-6, max_steps=64, offload="spill",
            offload_segment=SEG)
        return (-0.5 * jnp.sum(z ** 2)
                - 0.5 * DIM * jnp.log(2 * jnp.pi) + dl)

    td = [eng.submit("density", x) for x in xs[:2]]
    ts = [eng.submit("score", x) for x in xs[:2]]
    eng.run()
    for tk, x in zip(td, xs[:2]):
        ref = np.asarray(jax.jit(logp)(theta, jnp.asarray(x)))
        assert np.array_equal(np.asarray(tk.result(5), ref.dtype),
                              np.atleast_1d(ref))
    for tk, x in zip(ts, xs[:2]):
        ref = np.asarray(jax.jit(jax.grad(logp, argnums=1))(
            theta, jnp.asarray(x)))
        assert np.array_equal(tk.result(5), ref)


def test_engine_classify_head(theta, xs):
    """Classifier kind: integrate the raw field, apply the readout; the
    forward-only path writes zero checkpoints.

    The bitwise reference is the *batched no-offload* program: the claim
    under test is that the lane-keyed spill store perturbs nothing, not
    that XLA lowers a batched matmul identically to a row-wise one (with
    the x64 flag on, CPU dot_general for (B,d)@(d,k) can differ from
    (d,)@(d,k) in the last ulp — a lowering artifact independent of this
    subsystem).  The ODE transport itself IS bitwise lane-vs-single,
    asserted separately on uT before the head."""
    W = jnp.asarray(np.random.default_rng(0).normal(size=(DIM, 2)),
                    jnp.float32)
    eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                    offload="spill", offload_segment=SEG,
                    head=lambda u: u @ W, buckets=BucketSpec((2,)))

    def uT_one(th, x_):  # theta as a traced argument, like the engine
        return odeint(cnf_vf, x_, th, dt=DT, n_steps=N_STEPS,
                      method="rk4", adjoint="pnode")

    def batched_ref(th, xb):  # same vmap+head shape, no offload store
        return jax.vmap(lambda x_: uT_one(th, x_) @ W)(xb)

    ts = [eng.submit("classify", x) for x in xs[:2]]
    eng.run()
    refb = np.asarray(jax.jit(batched_ref)(theta, jnp.asarray(xs[:2])))
    # offloaded batched logits == no-offload batched logits, bitwise
    for i, tk in enumerate(ts):
        assert np.array_equal(tk.result(5), refb[i])
    # and the transport under the head is bitwise lane-vs-single
    uTb = np.asarray(jax.jit(jax.vmap(uT_one, in_axes=(None, 0)))(
        theta, jnp.asarray(xs[:2])))
    for i in range(2):
        assert np.array_equal(
            uTb[i], np.asarray(jax.jit(uT_one)(theta, jnp.asarray(xs[i]))))
    census = eng.slot_census()
    assert not any(census.values()), census


# -- callback bounds --------------------------------------------------------

def test_callbacks_independent_of_lane_count(theta, xs):
    """The point of lane-keyed batching: host callbacks per SOLVE are
    O(n_steps/segment) regardless of how many requests share the batch —
    so callbacks per REQUEST shrink as occupancy grows."""
    n_seg = math.ceil(N_STEPS / SEG)

    def run(n_req):
        reg = MetricsRegistry()
        eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                        offload="spill", offload_segment=SEG,
                        buckets=BucketSpec((4,)), registry=reg)
        eng.warmup(kinds=("score",))
        store = eng._store(4)
        before = dict(store.stats)
        for x in xs[:n_req]:
            eng.submit("score", x)
        eng.run()
        return {k: store.stats[k] - before.get(k, 0)
                for k in ("write_cb", "read_cb", "dispatch_cb")}

    solo = run(1)
    batched = run(4)
    # same per-solve callback structure whether 1 or 4 requests rode it
    assert batched == solo
    assert solo["write_cb"] == n_seg
    assert solo["read_cb"] + solo["dispatch_cb"] <= 2 * (n_seg + 1)
    # per-request cost: 4x cheaper at occupancy 4
    per_req_solo = sum(solo.values()) / 1
    per_req_batched = sum(batched.values()) / 4
    assert per_req_batched == per_req_solo / 4


# -- departures free their slots -------------------------------------------

def test_departure_frees_slots(theta, xs):
    """Run a lane-keyed batched grad holding the store open, then retire
    requests one by one: each departure frees exactly its own slots and
    the census returns to empty."""
    store = make_store("spill")
    aug = exact_trace_vf(cnf_vf, DIM)

    def score_b(xb):
        def one(x_):
            def logp(x__):
                z, dl = odeint(aug, (x__, jnp.zeros((), x__.dtype)), theta,
                               dt=DT, n_steps=N_STEPS, method="rk4",
                               adjoint="pnode", offload="spill",
                               offload_segment=SEG, offload_store=store)
                return (-0.5 * jnp.sum(z ** 2)
                        - 0.5 * DIM * jnp.log(2 * jnp.pi) + dl)
            return jax.grad(logp)(x_)
        return jax.vmap(one)(xb)

    rids = ("req-a", "req-b", None)  # 2 live lanes + 1 padding
    store.lane_keys = rids
    g = jax.block_until_ready(jax.jit(score_b)(jnp.asarray(xs[:3])))
    assert np.all(np.isfinite(np.asarray(g)[:2]))
    census0 = store.slot_census()
    assert census0["ram"] > 0
    assert store.request_slots("req-a") > 0
    assert store.request_slots("req-b") > 0
    n_a = store.free_request("req-a")  # mid-batch departure
    assert n_a > 0
    assert store.request_slots("req-a") == 0
    assert store.request_slots("req-b") > 0  # batch-mate untouched
    store.free_request("req-b")
    census = store.slot_census()
    assert not any(census.values()), census
    # padding lanes never stored anything to begin with
    assert store.free_request(None) == 0


# -- planner: batched working set ------------------------------------------

def test_plan_odeint_batch_pricing():
    u0 = jnp.zeros(DIM)
    th = jnp.zeros(DIM)
    f = lambda u, t_, t: u
    kw = dict(dt=DT, n_steps=N_STEPS, method="rk4", verify="model")
    p1 = plan_odeint(f, u0, th, **kw)
    p8 = plan_odeint(f, u0, th, batch=8, **kw)
    assert p8.predicted.peak_bytes > p1.predicted.peak_bytes
    # ram_budget split: lanes multiply the slot bytes, so the same RAM
    # budget holds ~1/8 the steps in RAM
    ram = None
    p1r = plan_odeint(f, u0, th, ram_budget=N_STEPS * DIM * 4 * 6, **kw)
    p8r = plan_odeint(f, u0, th, ram_budget=N_STEPS * DIM * 4 * 6,
                      batch=8, **kw)
    del ram
    assert p1r.offload in ("spill", "disk")
    assert p8r.offload in ("spill", "disk")
    in_ram_1 = p1r.snaps_in_ram if p1r.snaps_in_ram is not None else N_STEPS
    in_ram_8 = p8r.snaps_in_ram if p8r.snaps_in_ram is not None else N_STEPS
    assert in_ram_8 < in_ram_1
    with pytest.raises(ValueError):
        plan_odeint(f, u0, th, batch=0, **kw)


def test_engine_planner_integration(theta, xs):
    """A budget-configured engine routes through plan_odeint and still
    serves bitwise results."""
    eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                    offload_segment=SEG, ram_budget=1,
                    buckets=BucketSpec((2,)))
    assert eng.plan is not None and eng.plan.policy == "pnode"
    assert eng.offload == "disk"  # 1-byte RAM budget: everything to disk
    tk = eng.submit("score", xs[0])
    eng.run()
    ref = jax.jit(jax.grad(_logp_ref(), argnums=1))(
        theta, jnp.asarray(xs[0]))
    assert np.array_equal(tk.result(5), np.asarray(ref))


# -- bounded compile cache --------------------------------------------------

def test_compile_cache_bounded(theta, xs):
    eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                    offload="spill", offload_segment=SEG,
                    buckets=BucketSpec((1, 2)))
    n = eng.warmup()
    assert n == len(ODEEngine.KINDS) * 2
    # traffic across many compositions never grows the cache
    for i in range(3):
        eng.submit("density", xs[i % len(xs)])
        eng.run()
    assert len(eng._fns) <= len(ODEEngine.KINDS) * 2


# -- trace export -----------------------------------------------------------

def test_trace_export_roundtrip(tmp_path, theta, xs):
    from repro.obs import export_chrome_trace, to_chrome_trace
    rec = FlightRecorder()
    eng = ODEEngine(cnf_vf, theta, dim=DIM, dt=DT, n_steps=N_STEPS,
                    offload="spill", offload_segment=SEG,
                    buckets=BucketSpec((2,)), obs=rec)
    eng.submit("score", xs[0])
    eng.submit("density", xs[1])
    eng.run()
    evs = rec.events()
    assert any(e.kind.startswith("spill.") for e in evs)
    assert any(e.kind.startswith("queue.") for e in evs)
    assert any(e.kind == "serve.batch" for e in evs)
    assert all(e.ts > 0 for e in evs)  # wall-clock stamped
    doc = to_chrome_trace(e.to_json() for e in evs)
    names = {t.get("name") for t in doc["traceEvents"]}
    assert "serve.batch" in names
    assert any(n and n.startswith("spill bytes") for n in names)
    assert "queue depth" in names
    # JSONL round trip (the FlightRecorder dump format)
    p = tmp_path / "events.jsonl"
    rec.to_jsonl(str(p))
    out = tmp_path / "trace.json"
    n = export_chrome_trace(str(p), str(out))
    assert n > 0 and out.exists()
    import json
    loaded = json.loads(out.read_text())
    assert loaded["traceEvents"]


# -- serve driver accounting (satellite: warm-up vs steady state) -----------

def test_serve_stats_accounting():
    from repro.launch.serve import _stats_from_log
    log = [
        {"op": "prefill", "wall_s": 2.0, "tokens": 4, "compile": True,
         "lanes": 4},
        {"op": "decode", "wall_s": 3.0, "tokens": 8, "steps": 2,
         "compile": True, "lanes": 4},
        {"op": "decode", "wall_s": 0.5, "tokens": 8, "steps": 2,
         "compile": False, "lanes": 4},
        {"op": "decode", "wall_s": 0.5, "tokens": 8, "steps": 2,
         "compile": False, "lanes": 4},
    ]
    s = _stats_from_log(log, tokens_total=4 * 7)
    assert s["prefill_s"] == 2.0
    assert s["decode_s"] == 4.0
    # compile-time decode lumped into warm-up, not steady state
    assert s["warmup_s"] == 5.0
    assert s["steady_s"] == 1.0
    assert s["tok_per_s_steady"] == 16 / 1.0
    # the first (prefill-sampled) token counts in end-to-end throughput
    assert s["tok_per_s"] == pytest.approx(28 / 6.0)
