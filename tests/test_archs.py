"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED same-family config, runs one forward + one
train step on CPU, asserts output shapes and finiteness; decode consistency
checks prefill+decode against the full-sequence forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeCell, reduced
from repro.configs.registry import ARCHS, cell_runnable, get_arch
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.optim.adamw import AdamW

CELL = ShapeCell("smoke", 32, 2, "train")
ARCH_IDS = sorted(ARCHS)


@pytest.fixture(scope="module")
def params_cache():
    return {}


def _setup(arch, params_cache):
    cfg = reduced(get_arch(arch))
    if arch not in params_cache:
        params_cache[arch] = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params_cache[arch]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, params_cache):
    cfg, params = _setup(arch, params_cache)
    batch = SyntheticLM(cfg, CELL).batch(jnp.zeros((), jnp.int32))
    logits, aux = lm.forward(cfg, params, batch)
    # VLM frontends prepend n_patches patch embeddings; logits cover only
    # the text positions (tokens are (B, S - n_patches))
    n_text = CELL.seq_len - (cfg.n_patches
                             if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (CELL.global_batch, n_text, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss_and_finite(arch, params_cache):
    cfg, params = _setup(arch, params_cache)
    opt = AdamW(lr=1e-3, total_steps=10, warmup_steps=1)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    pipe = SyntheticLM(cfg, CELL)
    batch = pipe.batch(jnp.zeros((), jnp.int32))
    losses = []
    p = params
    for i in range(4):
        p, opt_state, m = step_fn(p, opt_state, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses  # same batch -> must improve


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, params_cache):
    """Teacher-forced consistency: prefill on S-1 tokens + 1 decode step
    must reproduce the full-sequence forward logits at the last position."""
    cfg, params = _setup(arch, params_cache)
    if cfg.n_experts:
        # forward() uses training-time capacity dropping; serving is
        # dropless — compare against the dropless forward
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.n_experts))
    batch = SyntheticLM(cfg, CELL).batch(jnp.zeros((), jnp.int32))
    tokens = batch["tokens"]
    b, s = tokens.shape
    logits_full, _ = lm.forward(cfg, params, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :-1]
    pre_batch.pop("targets", None)
    n_front = cfg.n_patches if cfg.frontend == "vision_stub" else 0
    state, _ = lm.prefill(cfg, params, pre_batch, max_seq=s + n_front + 4)
    logits_dec, _ = lm.decode_step(cfg, params, state, tokens[:, -1:],
                                   jnp.int32(s - 1 + n_front))
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_remat_policies_value_equivalent(arch, params_cache):
    """The PNODE depth-gradient policy must not change the forward value."""
    cfg, params = _setup(arch, params_cache)
    batch = SyntheticLM(cfg, CELL).batch(jnp.zeros((), jnp.int32))
    outs = []
    for remat, kw in [("none", {}), ("full", {}), ("sqrt", {}),
                      ("revolve", {"ncheck": 2})]:
        c = dataclasses.replace(cfg, remat=remat, **kw)
        loss, _ = lm.loss_fn(c, params, batch)
        outs.append(float(loss))
    np.testing.assert_allclose(outs, outs[0], rtol=1e-6)


def test_full_configs_match_assignment():
    """The exact architecture table from the assignment."""
    spec = {
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }
    for name, (nl, dm, nh, nkv, dff, vs) in spec.items():
        cfg = get_arch(name)
        assert cfg.n_layers == nl, name
        assert cfg.d_model == dm, name
        if nh:
            assert cfg.n_heads == nh, name
            assert cfg.n_kv_heads == nkv, name
        assert cfg.d_ff == dff, name
        assert cfg.vocab_size == vs, name
    assert get_arch("dbrx-132b").n_experts == 16
    assert get_arch("dbrx-132b").top_k == 4
    assert get_arch("mixtral-8x7b").n_experts == 8
    assert get_arch("mixtral-8x7b").top_k == 2


def test_cell_skip_policy():
    """long_500k runs only for sub-quadratic archs; everything else skips
    with a documented reason; all other cells always run."""
    from repro.configs.base import LONG_CONTEXT_OK
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, reason = cell_runnable(arch, shape)
            if shape == "long_500k":
                assert ok == (arch in LONG_CONTEXT_OK), (arch, reason)
                if not ok:
                    assert reason
            else:
                assert ok, (arch, shape, reason)


def test_param_counts_in_expected_range():
    """Sanity-check the closed-form param counts against the names."""
    expect = {"smollm-135m": (0.10e9, 0.2e9),
              "tinyllama-1.1b": (0.9e9, 1.3e9),
              "phi3-mini-3.8b": (3.3e9, 4.3e9),
              "mixtral-8x7b": (40e9, 50e9),
              "dbrx-132b": (110e9, 145e9),
              "rwkv6-7b": (6e9, 8.5e9)}
    for name, (lo, hi) in expect.items():
        n = get_arch(name).param_count()
        assert lo < n < hi, (name, n)
    # MoE active < total
    for name in ("mixtral-8x7b", "dbrx-132b"):
        cfg = get_arch(name)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()
