"""Helpers for multi-device tests: run a snippet in a subprocess with a
forced host-platform device count (the only way to get >1 CPU device
without polluting the parent process's jax state)."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh across jax versions: new jax takes (sizes, names),
    jax 0.4.x takes a ((name, size), ...) shape tuple."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))

PREAMBLE = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys
sys.path.insert(0, {src!r})
import jax
import jax.numpy as jnp
"""


def run_with_devices(snippet: str, n_devices: int = 8,
                     timeout: int = 600) -> str:
    """Run ``snippet`` under ``n_devices`` fake CPU devices; returns stdout.
    Raises CalledProcessError (with stderr attached) on failure."""
    code = PREAMBLE.format(n=n_devices, src=str(REPO / "src")) + snippet
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, cwd=REPO,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    if proc.returncode != 0:
        raise RuntimeError(
            f"subprocess failed\nstdout:\n{proc.stdout}\n"
            f"stderr:\n{proc.stderr[-4000:]}")
    return proc.stdout
