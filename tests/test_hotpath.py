"""PR-3 hot-path regression tests: segment-batched checkpoint I/O, the
masked adaptive reverse sweep, and the fused Pallas stage kernels.

Bitwise-grad tests run under jit: within one compiled program the fused
kernel's accumulation order matches the unfused tree_axpy chain exactly
(and XLA's FMA-contraction decisions are consistent), so gradients must be
*bitwise* identical — any drift means the kernel reordered the math.
Host-callback counts are asserted via the spill store's host-side
counters (``repro.mem.offload.spill_stats``), which count executions, not
traces.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import odeint_adaptive
from repro.core.adjoint import odeint
from repro.kernels.ops import fused_lincomb
from repro.kernels.ref import lincomb_ref
from repro.mem.offload import (SpillStore, default_segment,
                               reset_spill_stats, spill_stats)

jax.config.update("jax_enable_x64", True)

D = 5
N_STEPS = 12
DT = 0.05
TABLEAUS = ["euler", "midpoint", "bosh3", "rk4", "dopri5"]


def _vf():
    def f(u, th, t):
        return jnp.tanh(th["W"] @ u + th["b"]) + 0.1 * jnp.sin(t) * u
    return f


def _problem(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    u0 = jax.random.normal(ks[0], (D,))
    th = {"W": 0.3 * jax.random.normal(ks[1], (D, D)),
          "b": 0.1 * jax.random.normal(ks[2], (D,))}
    return u0, th


def _jit_grads(policy, *, method="rk4", n_steps=N_STEPS, **kw):
    f = _vf()
    u0, th = _problem()

    def loss(u0_, th_):
        uf = odeint(f, u0_, th_, dt=DT, n_steps=n_steps, method=method,
                    adjoint=policy, **kw)
        return jnp.sum(uf ** 2)

    return jax.jit(jax.grad(loss, argnums=(0, 1)))(u0, th)


def _assert_bitwise(g, g_ref):
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused Pallas stage kernels: bitwise-grad regression vs the PR-2 paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", TABLEAUS)
def test_fused_stages_grads_bitwise_identical(method):
    """fused_stages=True only re-fuses the stage lincombs — same math,
    same order, bitwise-equal gradients, for every tableau."""
    _assert_bitwise(_jit_grads("pnode", method=method, fused_stages=True),
                    _jit_grads("pnode", method=method))


@pytest.mark.parametrize("policy,kw", [
    ("pnode2", {}),
    ("revolve", {"ncheck": 3}),
    ("revolve2", {"ncheck": 3}),
])
def test_fused_stages_grads_bitwise_other_policies(policy, kw):
    _assert_bitwise(_jit_grads(policy, fused_stages=True, **kw),
                    _jit_grads(policy, **kw))


def test_fused_stages_forward_bitwise_identical():
    f = _vf()
    u0, th = _problem()

    def run(fused):
        return jax.jit(lambda a, b: odeint(
            f, a, b, dt=DT, n_steps=N_STEPS, adjoint="pnode",
            fused_stages=fused))(u0, th)

    _assert_bitwise(run(True), run(False))


@pytest.mark.parametrize("policy", ["naive", "continuous", "anode", "aca"])
def test_fused_stages_rejected_for_lowlevel_policies(policy):
    """Policies that differentiate through the step graph cannot use the
    Pallas kernels (no AD rules) — loud error, not a crash mid-trace."""
    with pytest.raises(ValueError, match="fused_stages"):
        _jit_grads(policy, fused_stages=True)


def test_fused_with_spill_offload_composes():
    _assert_bitwise(
        _jit_grads("pnode", offload="spill", offload_segment=4,
                   fused_stages=True),
        _jit_grads("pnode"))


# ---------------------------------------------------------------------------
# fused_lincomb kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (4, 5), (2, 3, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_lincomb_matches_oracle(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    base = jax.random.normal(ks[0], shape, dtype)
    terms = [jax.random.normal(k, shape, dtype) for k in ks[1:]]
    ws = [0.5, -0.25, 1 / 3, 2.0]

    def fused(b, *ts):
        return fused_lincomb(b, ts, ws, scale=0.1)

    def ref(b, *ts):
        return lincomb_ref(b, list(ts), ws, scale=0.1)

    np.testing.assert_array_equal(
        np.asarray(jax.jit(fused)(base, *terms)),
        np.asarray(jax.jit(ref)(base, *terms)))


def test_fused_lincomb_traced_scale_and_base_coeff():
    base = jax.random.normal(jax.random.PRNGKey(2), (6, 3))
    terms = [jax.random.normal(jax.random.PRNGKey(3 + i), (6, 3))
             for i in range(3)]
    ws = [0.3, 0.6, -1.2]

    def fused(b, h, *ts):
        return fused_lincomb(b, ts, ws, scale=h, base_coeff=0.25)

    def ref(b, h, *ts):
        return lincomb_ref(b, list(ts), ws, scale=h, base_coeff=0.25)

    h = jnp.asarray(0.05)
    np.testing.assert_array_equal(
        np.asarray(jax.jit(fused)(base, h, *terms)),
        np.asarray(jax.jit(ref)(base, h, *terms)))


# ---------------------------------------------------------------------------
# segment-batched spill I/O: bitwise grads + one callback per segment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", TABLEAUS)
def test_batched_spill_grads_bitwise_identical(method):
    """Batched write_batch/prefetch I/O relocates checkpoints in segments;
    the adjoint arithmetic (and so the grads, bitwise) is unchanged.
    segment=5 does not divide n_steps=12, covering the remainder path."""
    _assert_bitwise(
        _jit_grads("pnode", method=method, offload="spill",
                   offload_segment=5),
        _jit_grads("pnode", method=method))


def test_spill_one_callback_per_segment():
    """The tentpole claim, host-measured: ceil(12/4)=3 write callbacks in
    the forward sweep and 3 prefetch callbacks in the reverse sweep —
    not 12+12 as with the per-step API."""
    f = _vf()
    u0, th = _problem()

    def loss(u0_, th_):
        uf = odeint(f, u0_, th_, dt=DT, n_steps=N_STEPS, adjoint="pnode",
                    offload="spill", offload_segment=4)
        return jnp.sum(uf ** 2)

    gfn = jax.jit(jax.grad(loss, argnums=(0, 1)))
    jax.block_until_ready(gfn(u0, th))  # compile + first run
    reset_spill_stats()
    jax.block_until_ready(gfn(u0, th))
    st = spill_stats()
    n_segments = math.ceil(N_STEPS / 4)
    assert st["write_cb"] == n_segments, st
    assert st["read_cb"] == n_segments, st
    assert st["write_slots"] == N_STEPS, st
    assert st["read_slots"] == N_STEPS, st


def test_spill_default_segment_is_sqrt():
    assert default_segment(1) == 1
    assert default_segment(16) == 4
    assert default_segment(24) == 5
    assert default_segment(512) == 23
    for n in (1, 7, 100):
        s = default_segment(n)
        assert s * s >= n and (s - 1) ** 2 < n


def test_write_batch_prefetch_roundtrip():
    st = SpillStore()
    tree = {"a": jnp.arange(8.0).reshape(4, 2), "b": (jnp.ones((4, 3)),)}
    tok = st.init_token()
    tok = st.write_batch(tok, 10, tree)  # slots 10..13
    jax.block_until_ready(tok)
    assert set(st._host) == {10, 11, 12, 13}
    tok2, got = st.prefetch(tok, 10, 4)
    jax.block_until_ready(tok2)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # out-of-range slots read back as zeros (the cond-masked tail)
    _, padded = st.prefetch(tok2, 12, 3)
    np.testing.assert_array_equal(np.asarray(padded["a"][2]),
                                  np.zeros(2))


def test_offload_segment_validation():
    with pytest.raises(ValueError, match="offload_segment"):
        _jit_grads("pnode", offload_segment=4)  # no spill tier selected
    with pytest.raises(ValueError, match="offload_segment"):
        _jit_grads("pnode", offload="spill", offload_segment=0)


# ---------------------------------------------------------------------------
# masked adaptive reverse sweep
# ---------------------------------------------------------------------------

def _adaptive_grads(offload=None, **kw):
    f = _vf()
    u0, th = _problem()

    def loss(u0_, th_):
        uf, _ = odeint_adaptive(f, u0_, th_, t0=0.0, t1=0.6, rtol=1e-6,
                                atol=1e-6, max_steps=64, offload=offload,
                                **kw)
        return jnp.sum(uf ** 2)

    return jax.jit(jax.grad(loss, argnums=(0, 1)))(u0, th)


def test_adaptive_masked_sweep_grads_match_spill_and_fused():
    g_dev = _adaptive_grads()
    _assert_bitwise(_adaptive_grads(offload="spill", offload_segment=8),
                    g_dev)
    _assert_bitwise(_adaptive_grads(fused_stages=True), g_dev)


def test_adaptive_reverse_reads_only_accepted_prefix():
    """Segments past n_accepted are cond-skipped: the reverse sweep
    prefetches ceil(n_acc/seg) segments, not max_steps/seg — host-counted
    proof the invalid ring-buffer tail costs nothing."""
    f = _vf()
    u0, th = _problem()
    max_steps, seg = 64, 8

    uf, info = odeint_adaptive(f, u0, th, t0=0.0, t1=0.6, rtol=1e-6,
                               atol=1e-6, max_steps=max_steps)
    n_acc = int(info.n_accepted)
    n_att = n_acc + int(info.n_rejected)
    assert 0 < n_acc < max_steps // 2  # the tail actually exists

    def loss(u0_, th_):
        uf, _ = odeint_adaptive(f, u0_, th_, t0=0.0, t1=0.6, rtol=1e-6,
                                atol=1e-6, max_steps=max_steps,
                                offload="spill", offload_segment=seg)
        return jnp.sum(uf ** 2)

    gfn = jax.jit(jax.grad(loss, argnums=(0, 1)))
    jax.block_until_ready(gfn(u0, th))
    reset_spill_stats()
    jax.block_until_ready(gfn(u0, th))
    st = spill_stats()
    assert st["read_cb"] <= math.ceil(n_acc / seg) + 1, (st, n_acc)
    assert st["read_slots"] <= n_acc + 2 * seg, (st, n_acc)
    # the forward staging ring flushes once per FULL segment of accepted
    # steps plus one trailing partial flush — O(n/seg) callbacks, never
    # one per attempted step (the pre-PR-9 O(N) path)
    assert st["write_cb"] <= math.ceil(n_att / seg) + 1, (st, n_att)
    # flushes ship whole rings: accepted slots rounded up to the segment
    assert st["write_slots"] == math.ceil(n_acc / seg) * seg, (st, n_acc)


def test_adaptive_gradient_still_correct_vs_fd():
    f = _vf()
    u0, th = _problem()

    def loss(u0_):
        uf, _ = odeint_adaptive(f, u0_, th, t0=0.0, t1=0.8, rtol=1e-9,
                                atol=1e-9, max_steps=256)
        return jnp.sum(uf ** 2)

    g = jax.grad(loss)(u0)
    eps = 1e-6
    for i in range(2):
        e = jnp.zeros(D).at[i].set(eps)
        fd = (loss(u0 + e) - loss(u0 - e)) / (2 * eps)
        np.testing.assert_allclose(g[i], fd, rtol=5e-6)


# ---------------------------------------------------------------------------
# vmap-of-odeint-with-offload: clear error (satellite)
# ---------------------------------------------------------------------------

def test_vmap_offload_raises_clear_error():
    f = _vf()
    u0, th = _problem()
    us = jnp.stack([u0, u0 + 0.1])
    with pytest.raises(NotImplementedError, match="offload='device'"):
        jax.vmap(lambda u: odeint(f, u, th, dt=DT, n_steps=N_STEPS,
                                  adjoint="pnode", offload="spill"))(us)
    with pytest.raises(NotImplementedError, match="offload='device'"):
        jax.vmap(lambda u: odeint_adaptive(
            f, u, th, t0=0.0, t1=0.5, offload="spill")[0])(us)


def test_vmap_of_grad_offload_raises_clear_error():
    """vmap(grad(...)) wraps the batch axis inside JVP tracers — the guard
    must unwrap them, or the host dict would alias per-example checkpoints
    and silently return wrong gradients."""
    f = _vf()
    u0, th = _problem()
    us = jnp.stack([u0, u0 + 0.1])

    def loss(u):
        return jnp.sum(odeint(f, u, th, dt=DT, n_steps=N_STEPS,
                              adjoint="pnode", offload="spill") ** 2)

    with pytest.raises(NotImplementedError, match="offload='device'"):
        jax.vmap(jax.grad(loss))(us)


def test_offload_segment_rejected_for_slot_addressed_policies():
    """revolve checkpoints are slot-addressed; the segment knob would be
    silently ignored — reject it loudly."""
    with pytest.raises(ValueError, match="slot-addressed"):
        _jit_grads("revolve", ncheck=3, offload="spill", offload_segment=4)


def test_vmap_device_offload_still_works():
    f = _vf()
    u0, th = _problem()
    us = jnp.stack([u0, u0 + 0.1])
    out = jax.vmap(lambda u: odeint(f, u, th, dt=DT, n_steps=N_STEPS,
                                    adjoint="pnode"))(us)
    assert out.shape == (2, D) and bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# planner: caller's loss_fn in measured-verify mode (satellite)
# ---------------------------------------------------------------------------

def test_planner_accepts_caller_loss_fn():
    from repro.mem import measure_reverse_cost, plan_odeint
    f = _vf()
    u0, th = _problem()
    kw = dict(dt=DT, n_steps=8, method="rk4")

    def caller_loss(uf):
        return jnp.sum(jnp.abs(uf)) + jnp.sum(uf ** 4)

    m_canon = measure_reverse_cost(f, u0, th, policy="pnode", **kw)
    m_caller = measure_reverse_cost(f, u0, th, policy="pnode",
                                    loss_fn=caller_loss, **kw)
    assert m_caller["hlo_peak_bytes"] > 0
    # distinct cache entries: the caller's loss compiles its own reverse
    m_caller2 = measure_reverse_cost(f, u0, th, policy="pnode",
                                     loss_fn=caller_loss, **kw)
    assert m_caller2 is m_caller or m_caller2 == m_caller

    budget = int(m_caller["hlo_peak_bytes"])
    plan = plan_odeint(f, u0, th, mem_budget=budget, verify="measure",
                       loss_fn=caller_loss, **kw)
    assert plan.fits
    assert plan.measured_bytes is not None
    assert plan.measured_bytes <= budget


def test_planner_records_spill_callback_count():
    from repro.mem import policy_cost, spill_callback_counts
    c = policy_cost("pnode", method="rk4", n_steps=16, state_bytes=100,
                    offload="spill", segment=4)
    assert c.host_callbacks == 2 * 4  # 2 * ceil(16/4)
    assert spill_callback_counts("pnode", 16, segment=4)["total"] == 8
    r = spill_callback_counts("revolve", 16, ncheck=4)
    assert r["forward"] == 5 and r["total"] > r["forward"]
