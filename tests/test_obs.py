"""PR-7 observability tests.

The load-bearing property: attaching a FlightRecorder (``obs=``) must be a
pure debug effect — gradients bitwise-identical to the unobserved solve —
across adjoint policy x offload tier x (eager|jit), for the explicit
tableau family and both implicit theta-methods.  Plus: the adaptive trace
reconstructs the exact accepted/rejected sequence, spill traffic is
attributed per store and per segment, the planner's explain report is
consistent with candidate_costs, and the JSONL sink round-trips.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.adaptive import odeint_adaptive
from repro.core.adjoint import odeint
from repro.core.implicit import odeint_implicit
from repro.mem import offload
from repro.mem.planner import candidate_costs, plan_odeint
from repro.obs import (FevalCounter, FlightRecorder, Gate, JitCounter,
                       MetricsRegistry, MetricsSink, StructuredLogger,
                       check_against_baseline, read_jsonl)

D = 3


def _vf(u, theta, t):
    return jnp.tanh(u * theta["a"]) + theta["b"] * jnp.sin(t)


def _problem():
    u0 = jnp.array([0.3, -0.7, 1.1])
    theta = {"a": jnp.array([0.5, 1.0, -0.4]), "b": jnp.array(0.2)}
    return u0, theta


def _bitwise(a, b) -> bool:
    return all(bool((x == y).all()) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# bitwise neutrality: obs on == obs off, policy x tier x (eager|jit)
# ---------------------------------------------------------------------------

EXPLICIT_METHODS = ("euler", "midpoint", "bosh3", "rk4", "dopri5")


@pytest.mark.parametrize("method", EXPLICIT_METHODS)
@pytest.mark.parametrize("policy,tier", [
    ("pnode", None), ("pnode", "spill"),
    ("revolve", None), ("revolve", "spill"),
    ("revolve2", None), ("revolve2", "spill"),
])
def test_obs_bitwise_explicit_jit(method, policy, tier):
    u0, theta = _problem()
    kw = dict(dt=0.1, n_steps=6, method=method, adjoint=policy,
              offload=tier)
    if policy.startswith("revolve"):
        kw["ncheck"] = 2

    def loss(th, obs=None):
        return jnp.sum(odeint(_vf, u0, th, obs=obs, **kw) ** 2)

    g_off = jax.jit(jax.grad(loss))(theta)
    rec = FlightRecorder()
    g_on = jax.jit(lambda th: jax.grad(lambda t: loss(t, obs=rec))(th))(theta)
    assert _bitwise(g_off, g_on)
    assert len(rec) > 0  # the recorder actually saw the solve


@pytest.mark.parametrize("policy", ["pnode", "revolve"])
def test_obs_bitwise_explicit_eager(policy):
    u0, theta = _problem()
    kw = dict(dt=0.1, n_steps=6, method="rk4", adjoint=policy)
    if policy == "revolve":
        kw["ncheck"] = 2

    def loss(th, obs=None):
        return jnp.sum(odeint(_vf, u0, th, obs=obs, **kw) ** 2)

    g_off = jax.grad(loss)(theta)
    rec = FlightRecorder()
    g_on = jax.grad(lambda t: loss(t, obs=rec))(theta)
    assert _bitwise(g_off, g_on)


@pytest.mark.parametrize("method", ["cn", "beuler"])
@pytest.mark.parametrize("policy,tier", [
    ("pnode", None), ("pnode", "spill"),
    ("revolve", None), ("revolve", "spill"),
    ("revolve2", None),
])
def test_obs_bitwise_implicit_jit(method, policy, tier):
    u0, theta = _problem()
    kw = dict(dt=0.05, n_steps=5, method=method, adjoint=policy,
              offload=tier, newton_iters=6, gmres_iters=8)
    if policy.startswith("revolve"):
        kw["ncheck"] = 2

    def loss(th, obs=None):
        return jnp.sum(odeint_implicit(_vf, u0, th, obs=obs, **kw) ** 2)

    g_off = jax.jit(jax.grad(loss))(theta)
    rec = FlightRecorder()
    g_on = jax.jit(lambda th: jax.grad(lambda t: loss(t, obs=rec))(th))(theta)
    assert _bitwise(g_off, g_on)
    # the stacked forward taps expand to exactly one record per step
    steps = rec.implicit_steps()
    assert [d["step"] for d in steps] == list(range(kw["n_steps"]))
    assert all(isinstance(d["iters"], int) for d in steps)


def test_obs_bitwise_adaptive_jit():
    u0, theta = _problem()

    def loss(th, obs=None):
        uf, _ = odeint_adaptive(_vf, u0, th, t0=0.0, t1=0.5, max_steps=64,
                                obs=obs)
        return jnp.sum(uf ** 2)

    g_off = jax.jit(jax.grad(loss))(theta)
    rec = FlightRecorder()
    g_on = jax.jit(lambda th: jax.grad(lambda t: loss(t, obs=rec))(th))(theta)
    assert _bitwise(g_off, g_on)


# ---------------------------------------------------------------------------
# adaptive trace reconstruction
# ---------------------------------------------------------------------------

def test_adaptive_trace_reconstructs_accept_reject_sequence():
    u0, theta = _problem()
    rec = FlightRecorder()

    def fwd(th):
        return odeint_adaptive(_vf, u0, th, t0=0.0, t1=0.5, max_steps=64,
                               obs=rec)

    _, info = jax.jit(fwd)(theta)
    steps = rec.adaptive_steps()
    # one tap per attempted step, ordered by the attempt counter each tap
    # carried (immune to debug-callback reordering)
    assert [d["attempt"] for d in steps] == list(range(len(steps)))
    acc, rej = rec.accepted_rejected()
    assert acc == int(info.n_accepted)
    assert rej == int(info.n_rejected)
    # accepted attempts advance t monotonically; every error norm on an
    # accepted attempt is <= 1
    accepted = [d for d in steps if d["accept"]]
    ts = [d["t"] for d in accepted]
    assert ts == sorted(ts)
    assert all(d["err_norm"] <= 1.0 for d in accepted)
    assert all(d["err_norm"] > 1.0 for d in steps if not d["accept"])


def test_adaptive_spill_trace_matches_store_counters():
    u0, theta = _problem()
    offload.reset_spill_stats()
    rec = FlightRecorder()

    def loss(th):
        uf, _ = odeint_adaptive(_vf, u0, th, t0=0.0, t1=0.5, max_steps=64,
                                offload="spill", offload_segment=8, obs=rec)
        return jnp.sum(uf ** 2)

    g = jax.jit(jax.grad(loss))
    jax.block_until_ready(g(theta))  # compile + warm
    offload.reset_spill_stats()
    rec.clear()
    jax.block_until_ready(g(theta))
    traffic = rec.spill_traffic()
    per_store = offload.per_store_spill_stats()
    # the flight recorder's per-store view must agree with the host-side
    # counters, event for event
    assert set(traffic) == set(per_store)
    for sid, t in traffic.items():
        for k in ("write_cb", "read_cb", "write_slots", "read_slots",
                  "write_bytes", "read_bytes"):
            assert t[k] == per_store[sid][k], (sid, k)
        # per-segment slots sum to the totals
        assert sum(s["write_slots"] for s in t["segments"].values()) \
            == t["write_slots"]
        assert sum(s["read_slots"] for s in t["segments"].values()) \
            == t["read_slots"]


# ---------------------------------------------------------------------------
# per-store spill counters (satellite: the global-dict fix)
# ---------------------------------------------------------------------------

def test_per_store_counters_and_aggregate_agree():
    offload.reset_spill_stats()
    s1 = offload.SpillStore()
    s2 = offload.SpillStore()
    x = jnp.arange(6.0)

    @jax.jit
    def roundtrip(v):
        t1 = s1.write_batch(s1.init_token(), 0, v.reshape(2, 3))
        t1, y = s1.prefetch(t1, 0, 2)
        t2 = s2.write_batch(s2.init_token(), 0, v.reshape(2, 3))
        return y.sum() + (t1 + t2) * 0.0

    jax.block_until_ready(roundtrip(x))
    agg = offload.spill_stats()
    per = offload.per_store_spill_stats()
    assert s1.store_id in per and s2.store_id in per
    assert per[s1.store_id]["write_cb"] == 1
    assert per[s1.store_id]["read_cb"] == 1
    assert per[s2.store_id]["write_cb"] == 1
    assert per[s2.store_id]["read_cb"] == 0
    for k in offload._STAT_KEYS:
        if k == "ram_bytes_peak":
            # high-water gauge: max-merged into the aggregate, not summed
            assert agg[k] == max(p[k] for p in per.values()), k
        else:
            assert agg[k] == sum(p[k] for p in per.values()), k
    offload.reset_spill_stats()
    assert all(v == 0 for v in offload.spill_stats().values())
    assert offload.per_store_spill_stats() == {}


# ---------------------------------------------------------------------------
# planner explain report
# ---------------------------------------------------------------------------

def test_explain_report_consistent_with_candidate_costs():
    u0 = jnp.ones((16,))
    theta = jnp.ones((4,))

    def f(u, th, t):
        return -u * th.sum() + t

    budget = 10 ** 9
    plan = plan_odeint(f, u0, theta, dt=0.1, n_steps=12, method="rk4",
                       mem_budget=budget, verify="model", explain=True)
    from repro.mem.model import f_activation_bytes, tree_bytes
    cands = candidate_costs(method="rk4", n_steps=12,
                            state_bytes=tree_bytes(u0),
                            theta_bytes=tree_bytes(theta),
                            f_act_bytes=f_activation_bytes(f, u0, theta,
                                                           0.0),
                            mem_budget=budget)
    # report rows mirror Plan.candidates one-to-one, in rank order
    assert len(plan.report) >= len(plan.candidates)
    for row, cand in zip(plan.report, plan.candidates):
        assert row.policy == cand.policy
        assert row.ncheck == cand.ncheck
        assert row.predicted_peak_bytes == int(cand.peak_bytes)
        assert row.extra_fevals == int(cand.extra_fevals)
    assert [c.policy for c in plan.candidates] == [c.policy for c in cands]
    # exactly one chosen row; every other row carries a reason
    chosen = [r for r in plan.report if r.chosen]
    assert len(chosen) == 1
    assert chosen[0].policy == plan.policy
    assert all(r.reason for r in plan.report)
    for r in plan.report:
        if not r.chosen:
            assert r.reason.startswith(("rejected", "skipped"))


def test_explain_report_rejects_every_candidate_under_tiny_budget():
    u0 = jnp.ones((64,))
    theta = jnp.ones(())

    def f(u, th, t):
        return -u * th

    plan = plan_odeint(f, u0, theta, dt=0.1, n_steps=20, method="rk4",
                       mem_budget=64, verify="model", explain=True)
    assert plan.offload == "spill"
    # every in-device candidate must state its rejection reason
    in_device = [r for r in plan.report if r.offload is None]
    assert len(in_device) == len(plan.candidates)
    assert all(not r.chosen and "rejected" in r.reason for r in in_device)
    assert plan.report[-1].offload == "spill" and plan.report[-1].chosen


def test_explain_off_keeps_report_empty():
    u0 = jnp.ones((8,))
    theta = jnp.ones(())

    def f(u, th, t):
        return -u * th

    plan = plan_odeint(f, u0, theta, dt=0.1, n_steps=8, method="rk4",
                       mem_budget=10 ** 9, verify="model")
    assert plan.report == ()


# ---------------------------------------------------------------------------
# JSONL sink round-trip + unified baseline checker
# ---------------------------------------------------------------------------

def test_metrics_sink_roundtrip(tmp_path):
    path = tmp_path / "metrics.jsonl"
    with MetricsSink(str(path)) as sink:
        sink.emit("train.step", step=0, loss=1.5,
                  grad_norm=float(jnp.asarray(2.0)))
        sink.emit("train.step", step=1, loss=1.25, nested={"a": [1, 2]})
    recs = read_jsonl(str(path))
    assert [r["event"] for r in recs] == ["train.step", "train.step"]
    assert [r["seq"] for r in recs] == [0, 1]
    assert recs[0]["loss"] == 1.5 and recs[1]["nested"] == {"a": [1, 2]}
    assert all("ts" in r for r in recs)


def test_flight_recorder_to_jsonl_roundtrip(tmp_path):
    rec = FlightRecorder()
    rec.record("odeint.solve", method="rk4", n_steps=4)
    rec.record("spill.write", _runtime=True, store="spill-0", base=0,
               slots=4, bytes=128)
    path = tmp_path / "trace.jsonl"
    n = rec.to_jsonl(str(path))
    assert n == 2
    back = read_jsonl(str(path))
    assert back[0]["kind"] == "odeint.solve" and not back[0]["runtime"]
    assert back[1]["kind"] == "spill.write" and back[1]["runtime"]
    assert json.dumps(back[1])  # fully JSON-serializable


def test_structured_logger_both_channels(tmp_path):
    lines = []
    path = tmp_path / "log.jsonl"
    with MetricsSink(str(path)) as sink:
        slog = StructuredLogger(log_fn=lines.append, sink=sink)
        slog.log("train.resume", "[train] resumed from step 3", step=3)
        slog.metric("train.step", step=3, loss=0.5)
    assert lines == ["[train] resumed from step 3"]
    recs = read_jsonl(str(path))
    assert recs[0]["event"] == "train.resume" and recs[0]["step"] == 3
    assert recs[1]["event"] == "train.step" and "msg" not in recs[1]


def test_unified_checker_gate_semantics():
    reg = MetricsRegistry()
    record = {"size": 24, "io": {"cb": 6}, "ok": True,
              "fused": {"rk4": {"bit": True}, "euler": {"bit": False}}}
    baseline = {"size": 24, "max_cb": 8}
    gates = [
        Gate("size", "size", "==", None, precondition=True),
        Gate("cb", "io.cb", "<=", None),
        Gate("ok", "ok", "truthy"),
        Gate("fused", "fused.*.bit", "truthy"),
    ]
    from repro.obs import BaselineRef
    gates[0] = Gate("size", "size", "==", BaselineRef("size"),
                    precondition=True)
    gates[1] = Gate("cb", "io.cb", "<=", BaselineRef("max_cb"))
    errs = check_against_baseline(record, gates, baseline, bench="t",
                                  registry=reg)
    # the euler fused gate fails; everything else passes
    assert len(errs) == 1 and "fused.euler.bit" in errs[0]
    counters = reg.snapshot()["counters"]
    assert counters["baseline.t.pass"] == 3
    assert counters["baseline.t.fail"] == 1
    # precondition short-circuit: wrong size returns only that message
    errs2 = check_against_baseline(dict(record, size=99), gates, baseline,
                                   bench="t2", registry=reg)
    assert len(errs2) == 1 and "[size]" in errs2[0]
    assert reg.snapshot()["counters"]["baseline.t2.skipped"] == 1
    # missing baseline file
    errs3 = check_against_baseline(record, gates, "/nonexistent/b.json")
    assert errs3 == ["baseline file missing: /nonexistent/b.json"]


def test_bench_gate_modules_use_unified_checker():
    import benchmarks.hotpath as hp
    import benchmarks.stiff_ensemble as se
    assert all(isinstance(g, Gate) for g in hp.GATES)
    assert all(isinstance(g, Gate) for g in se.GATES)
    # hotpath's FevalCounter is the promoted repro.obs one
    assert hp.FevalCounter is FevalCounter


# ---------------------------------------------------------------------------
# jit-safe counters
# ---------------------------------------------------------------------------

def test_jit_counter_counts_under_jit():
    c = JitCounter()

    @jax.jit
    def f(x):
        return c.tap(x) * 2.0

    jax.block_until_ready(f(jnp.ones(())))
    jax.block_until_ready(f(jnp.ones(())))
    # pure_callback results feed the computation, so block_until_ready
    # guarantees the host taps have run
    assert c.count == 2


def test_feval_counter_wraps_field():
    calls = FevalCounter(_vf)
    u0, theta = _problem()

    @jax.jit
    def solve(th):
        return odeint(calls, u0, th, dt=0.1, n_steps=4, method="euler")

    jax.block_until_ready(solve(theta))
    jax.effects_barrier()
    assert calls.count == 4  # euler: one f eval per step
    calls.reset()
    assert calls.count == 0
