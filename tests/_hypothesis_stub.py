"""Minimal offline stand-in for the slice of the `hypothesis` API our
property tests use (``given`` / ``settings`` / ``strategies.integers`` /
``strategies.sampled_from``).

The real hypothesis is declared in pyproject's test extras and is used
whenever importable; this fallback keeps the suite runnable in hermetic
containers by replaying ``max_examples`` deterministic pseudo-random draws
per test (seeded per test name, so failures reproduce).  No shrinking, no
database — just example generation.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable, Sequence

_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(options: Sequence[Any]) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda r: r.choice(opts))


def booleans() -> _Strategy:
    return _Strategy(lambda r: r.choice([False, True]))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


st = strategies = types.SimpleNamespace(
    integers=integers, sampled_from=sampled_from, booleans=booleans,
    floats=floats)


def settings(max_examples: int | None = None, **_kw):
    """Records max_examples on the test fn for ``given`` to pick up; every
    other hypothesis knob (deadline, ...) is irrelevant here and ignored."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit above OR below @given: check the wrapper
            # (settings applied after given) before the inner fn
            n = getattr(wrapper, "_stub_max_examples", None) \
                or getattr(fn, "_stub_max_examples", None) \
                or _DEFAULT_EXAMPLES
            rnd = random.Random(fn.__name__)
            for _ in range(n):
                drawn = {k: s._draw(rnd) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # hide the drawn params from pytest's fixture resolution: expose only
        # the remaining (fixture) parameters, like real hypothesis does
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strats]
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper
    return deco
