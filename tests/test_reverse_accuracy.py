"""Reverse accuracy across ALL adjoint policies (the paper's central claim,
swept over `repro.core.adjoint.POLICIES`).

Every discrete policy (anode / aca / pnode / pnode2 / revolve / revolve2)
must reproduce the `naive` AD-through-the-solver gradients to machine
precision — they are exact reorderings of the same chain rule.  The
`continuous` adjoint is the one policy that is NOT reverse-accurate: its
per-step discrepancy is O(h^2) (Prop. 1), checked here by a dt-halving
convergence sweep at fixed horizon (global gap O(h), per-step gap O(h^2)).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adjoint import POLICIES, odeint

jax.config.update("jax_enable_x64", True)

D = 6
HORIZON = 0.6


def _vf():
    def f(u, th, t):
        return jnp.tanh(th["W"] @ u + th["b"]) - 0.2 * u \
            + 0.05 * jnp.cos(t) * u
    return f


def _problem(seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    u0 = jax.random.normal(ks[0], (D,))
    th = {"W": 0.4 * jax.random.normal(ks[1], (D, D)),
          "b": 0.1 * jax.random.normal(ks[2], (D,))}
    return u0, th


def _grads(policy, *, method="rk4", n_steps=12, dt=HORIZON / 12, **kw):
    f = _vf()
    u0, th = _problem()

    def loss(u0_, th_):
        uf = odeint(f, u0_, th_, dt=dt, n_steps=n_steps, method=method,
                    adjoint=policy, **kw)
        return jnp.sum(uf ** 2)

    return jax.grad(loss, argnums=(0, 1))(u0, th)


def _gap(g, g_ref) -> float:
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree_util.tree_leaves(g),
                   jax.tree_util.tree_leaves(g_ref)))


@pytest.mark.parametrize("policy", [p for p in POLICIES if p != "continuous"])
def test_policy_reverse_accurate(policy):
    """Each discrete policy == naive grads to near machine precision."""
    kw = {"ncheck": 3} if policy.startswith("revolve") else {}
    g_ref = _grads("naive")
    g = _grads(policy, **kw)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-13)


def test_continuous_adjoint_o_h2_per_step():
    """Prop. 1: the continuous adjoint's per-step gradient discrepancy is
    O(h^2): halving dt at fixed horizon must shrink the per-step gap ~4x
    (global gap ~2x, since the step count doubles)."""
    def gap_at(n_steps):
        dt = HORIZON / n_steps
        g_c = _grads("continuous", method="euler", n_steps=n_steps, dt=dt)
        g_n = _grads("naive", method="euler", n_steps=n_steps, dt=dt)
        return _gap(g_c, g_n)

    ns = (8, 16, 32, 64)
    gaps = [gap_at(n) for n in ns]
    per_step = [g / n for g, n in zip(gaps, ns)]
    assert gaps[0] > 1e-9, "discrepancy must be real, not roundoff"
    for a, b in zip(per_step, per_step[1:]):
        assert a / b > 2.8, (per_step, "per-step gap must shrink ~4x per "
                                       "dt halving (O(h^2), Prop. 1)")
    # contrast: a reverse-accurate policy stays at machine eps on the same ladder
    for n in (ns[0], ns[-1]):
        g_p = _grads("pnode", method="euler", n_steps=n, dt=HORIZON / n)
        g_n = _grads("naive", method="euler", n_steps=n, dt=HORIZON / n)
        assert _gap(g_p, g_n) < 1e-10
