"""Reverse accuracy across ALL adjoint policies (the paper's central claim,
swept over `repro.core.adjoint.POLICIES`).

Every discrete policy (anode / aca / pnode / pnode2 / revolve / revolve2)
must reproduce the `naive` AD-through-the-solver gradients to machine
precision — they are exact reorderings of the same chain rule.  The
`continuous` adjoint is the one policy that is NOT reverse-accurate: its
per-step discrepancy is O(h^2) (Prop. 1), checked here by a dt-halving
convergence sweep at fixed horizon (global gap O(h), per-step gap O(h^2)).

The implicit family (theta-methods, §3.3) gets the same lockdown: for
theta in {0.5 (cn), 1.0 (beuler)} the discrete adjoint of every implicit
checkpoint policy must match AD through an unrolled dense-Jacobian Newton
solve of the same scheme (the differentiable oracle — backprop through the
production Newton/GMRES ``while_loop`` has no reverse rule), and within a
policy the gradients must be **bitwise-identical across every offload
tier** (device / host / spill), in both eager and jit execution — the
store moves checkpoints, never changes a single arithmetic op.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adjoint import POLICIES, odeint
from repro.core.implicit import odeint_implicit

jax.config.update("jax_enable_x64", True)

D = 6
HORIZON = 0.6


def _vf():
    def f(u, th, t):
        return jnp.tanh(th["W"] @ u + th["b"]) - 0.2 * u \
            + 0.05 * jnp.cos(t) * u
    return f


def _problem(seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    u0 = jax.random.normal(ks[0], (D,))
    th = {"W": 0.4 * jax.random.normal(ks[1], (D, D)),
          "b": 0.1 * jax.random.normal(ks[2], (D,))}
    return u0, th


def _grads(policy, *, method="rk4", n_steps=12, dt=HORIZON / 12, **kw):
    f = _vf()
    u0, th = _problem()

    def loss(u0_, th_):
        uf = odeint(f, u0_, th_, dt=dt, n_steps=n_steps, method=method,
                    adjoint=policy, **kw)
        return jnp.sum(uf ** 2)

    return jax.grad(loss, argnums=(0, 1))(u0, th)


def _gap(g, g_ref) -> float:
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree_util.tree_leaves(g),
                   jax.tree_util.tree_leaves(g_ref)))


@pytest.mark.parametrize("policy", [p for p in POLICIES if p != "continuous"])
def test_policy_reverse_accurate(policy):
    """Each discrete policy == naive grads to near machine precision."""
    kw = {"ncheck": 3} if policy.startswith("revolve") else {}
    g_ref = _grads("naive")
    g = _grads(policy, **kw)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-13)


def test_continuous_adjoint_o_h2_per_step():
    """Prop. 1: the continuous adjoint's per-step gradient discrepancy is
    O(h^2): halving dt at fixed horizon must shrink the per-step gap ~4x
    (global gap ~2x, since the step count doubles)."""
    def gap_at(n_steps):
        dt = HORIZON / n_steps
        g_c = _grads("continuous", method="euler", n_steps=n_steps, dt=dt)
        g_n = _grads("naive", method="euler", n_steps=n_steps, dt=dt)
        return _gap(g_c, g_n)

    ns = (8, 16, 32, 64)
    gaps = [gap_at(n) for n in ns]
    per_step = [g / n for g, n in zip(gaps, ns)]
    assert gaps[0] > 1e-9, "discrepancy must be real, not roundoff"
    for a, b in zip(per_step, per_step[1:]):
        assert a / b > 2.8, (per_step, "per-step gap must shrink ~4x per "
                                       "dt halving (O(h^2), Prop. 1)")
    # contrast: a reverse-accurate policy stays at machine eps on the same ladder
    for n in (ns[0], ns[-1]):
        g_p = _grads("pnode", method="euler", n_steps=n, dt=HORIZON / n)
        g_n = _grads("naive", method="euler", n_steps=n, dt=HORIZON / n)
        assert _gap(g_p, g_n) < 1e-10


# ---------------------------------------------------------------------------
# implicit family (theta-methods): oracle accuracy + bitwise tier identity
# ---------------------------------------------------------------------------

N_IMP = 6
DT_IMP = HORIZON / N_IMP
_THETA_OF = {"cn": 0.5, "beuler": 1.0}

#: (policy, ncheck, offload tiers that policy writes through)
IMPLICIT_MATRIX = [
    ("pnode", None, (None, "spill")),
    ("revolve", 2, (None, "host", "spill")),
    ("revolve2", 2, (None, "host", "spill")),
]


def _implicit_grads(method, policy="pnode", *, jit=False, **kw):
    f = _vf()
    u0, th = _problem()

    def loss(u0_, th_):
        uf = odeint_implicit(f, u0_, th_, dt=DT_IMP, n_steps=N_IMP,
                             method=method, adjoint=policy, newton_iters=20,
                             newton_tol=1e-13, gmres_tol=1e-13, **kw)
        return jnp.sum(uf ** 2)

    fn = jax.grad(loss, argnums=(0, 1))
    return (jax.jit(fn) if jit else fn)(u0, th)


def _oracle_implicit_grads(method):
    """AD through an unrolled dense-Jacobian Newton solve of the identical
    theta-scheme: the reverse-accuracy reference the production
    matrix-free solver cannot provide itself."""
    theta = _THETA_OF[method]
    f = _vf()
    u0, th = _problem()

    def step(u, th_, t_n):
        t_next = t_n + DT_IMP
        g_const = u + DT_IMP * (1 - theta) * f(u, th_, t_n)
        v = u + DT_IMP * f(u, th_, t_n)
        for _ in range(25):
            r = v - DT_IMP * theta * f(v, th_, t_next) - g_const
            J = jnp.eye(D) - DT_IMP * theta * jax.jacfwd(
                lambda uu: f(uu, th_, t_next))(v)
            v = v - jnp.linalg.solve(J, r)
        return v

    def loss(u0_, th_):
        u = u0_
        for k in range(N_IMP):
            u = step(u, th_, k * DT_IMP)
        return jnp.sum(u ** 2)

    return jax.grad(loss, argnums=(0, 1))(u0, th)


def _assert_bitwise(g, g_ref, ctx=""):
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=ctx)


@pytest.mark.parametrize("method", ["cn", "beuler"])
@pytest.mark.parametrize("policy,ncheck", [(p, k) for p, k, _ in
                                           IMPLICIT_MATRIX])
def test_implicit_policy_matches_ad_through_newton(method, policy, ncheck):
    """Each implicit checkpoint policy reproduces AD-through-dense-Newton
    to tight tolerance, for both theta points of the family."""
    g_ref = _oracle_implicit_grads(method)
    kw = {"ncheck": ncheck} if ncheck is not None else {}
    g = _implicit_grads(method, policy, **kw)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-7, atol=1e-9)


@pytest.mark.parametrize("method", ["cn", "beuler"])
@pytest.mark.parametrize("policy,ncheck,tiers",
                         IMPLICIT_MATRIX,
                         ids=[p for p, _, _ in IMPLICIT_MATRIX])
def test_implicit_bitwise_across_offload_tiers(method, policy, ncheck,
                                               tiers):
    """Within a policy the offload tier must not change one bit of the
    gradient — eager and jit each compared against their own device-tier
    anchor (XLA fusion may round eager and jit differently, but tiers
    within a mode run the identical op sequence)."""
    kw = {"ncheck": ncheck} if ncheck is not None else {}
    for jit in (False, True):
        anchor = _implicit_grads(method, policy, jit=jit, **kw)
        for tier in tiers[1:]:
            g = _implicit_grads(method, policy, jit=jit, offload=tier, **kw)
            _assert_bitwise(g, anchor,
                            f"{method}/{policy}/offload={tier}/jit={jit}")


def test_implicit_policies_bitwise_identical_under_jit():
    """Under jit the checkpoint policies are not merely close — recompute
    is bitwise-deterministic, so dense pnode, revolve and revolve2 agree
    exactly (the implicit analogue of the explicit policy matrix)."""
    anchor = _implicit_grads("cn", "pnode", jit=True)
    for policy, ncheck, _ in IMPLICIT_MATRIX[1:]:
        g = _implicit_grads("cn", policy, jit=True, ncheck=ncheck)
        _assert_bitwise(g, anchor, f"cn/{policy} vs pnode under jit")
