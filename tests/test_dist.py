"""Distribution: sharding-rule unit tests on an abstract mesh (no devices
needed) + multi-device integration tests in subprocesses with a forced CPU
device count (sharded train step, pipeline parallelism, compressed psum,
small-mesh dry-run)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.dist import sharding as shd
from repro.models import lm
from tests.util import abstract_mesh, run_with_devices

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _specs(arch, mesh=MESH):
    cfg = get_arch(arch)
    shapes = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, shapes, shd.param_specs(cfg, shapes, mesh)


def _assert_divisible(shapes, specs, mesh):
    ok = True

    def check(path, leaf, spec):
        nonlocal ok
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if leaf.shape[d] % n != 0:
                ok = False
                raise AssertionError(f"{path}: dim {d} ({leaf.shape[d]}) "
                                     f"not divisible by {ax} ({n})")

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["smollm-135m", "mixtral-8x7b", "dbrx-132b",
                                  "rwkv6-7b", "recurrentgemma-9b",
                                  "whisper-medium", "gemma3-4b"])
def test_param_specs_divisible(arch):
    cfg, shapes, specs = _specs(arch)
    _assert_divisible(shapes, specs, MESH)


def test_large_weights_actually_sharded():
    """The big leaves (embeddings, FFN) must not silently replicate —
    replication of dbrx's 6144x10752x16 experts would never fit 16 GB."""
    cfg, shapes, specs = _specs("dbrx-132b")
    flat = jax.tree_util.tree_leaves_with_path(
        jax.tree_util.tree_map(lambda s: s, specs),
        is_leaf=lambda x: isinstance(x, P))
    shapes_flat = jax.tree_util.tree_leaves(shapes)
    total_repl = 0
    for (path, spec), shape in zip(flat, shapes_flat):
        n_elem = 1
        for d in shape.shape:
            n_elem *= d
        shard_factor = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shard_factor *= MESH.shape[a]
        if n_elem > 1e6 and shard_factor == 1:
            raise AssertionError(f"large leaf replicated: {path} {shape}")
        total_repl += n_elem // shard_factor
    # per-device param bytes must be < 2 GB (bf16) for dbrx on 256 chips
    assert total_repl * 2 < 2e9, total_repl


def test_expert_parallelism_when_divisible():
    """dbrx (16 experts on model=16) -> EP; mixtral (8 experts) -> TP
    within experts (d_ff sharded)."""
    _, shapes_d, specs_d = _specs("dbrx-132b")
    _, shapes_m, specs_m = _specs("mixtral-8x7b")

    def moe_spec(specs):
        out = {}

        def walk(path, spec):
            key = "/".join(str(getattr(p, "key", p)) for p in path)
            if "w_gate" in key or "w_down" in key:
                out[key] = spec

        jax.tree_util.tree_map_with_path(
            walk, specs, is_leaf=lambda x: isinstance(x, P))
        return out

    d = moe_spec(specs_d)
    m = moe_spec(specs_m)
    # leaves live under the stacked 'scan' axis: dims are (scan, E, in, out),
    # so the expert dim is index 1
    assert any(len(s) > 1 and s[1] == "model" for s in d.values()), d  # EP
    assert all(not (len(s) > 1 and s[1] == "model") for s in m.values()), m
    assert any("model" in [a for a in s if a] for s in m.values()), m


def test_multipod_mesh_batch_specs():
    cfg = get_arch("smollm-135m")
    cell = ShapeCell("train_4k", 4096, 256, "train")
    specs = shd.batch_specs(cfg, cell, MESH3)
    assert specs["tokens"] == P(("pod", "data"), None)


def test_long500k_kv_cache_sequence_sharded():
    """B=1 decode cannot batch-shard; the KV cache must shard its sequence
    dim over 'data' (SP) so a 512k cache fits."""
    cfg = get_arch("gemma3-4b")
    cell = ShapeCell("long_500k", 524288, 1, "decode")
    state_shape = jax.eval_shape(
        lambda: lm.init_decode_state(cfg, 1, cell.seq_len))
    specs = shd.decode_state_specs(cfg, cell, state_shape, MESH)
    found_sp = False

    def walk(path, leaf, spec):
        nonlocal found_sp
        # a KV leaf has the 512k sequence dim; it must carry 'data' (SP)
        for d, size in enumerate(leaf.shape):
            if size == cell.seq_len and d < len(spec) and spec[d] == "data":
                found_sp = True

    jax.tree_util.tree_map_with_path(
        walk, state_shape, specs, is_leaf=lambda x: hasattr(x, "shape"))
    assert found_sp


# ---------------------------------------------------------------------------
# multi-device integration (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_train_step_8dev():
    out = run_with_devices("""
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax import tree_util as jtu
from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.data.pipeline import SyntheticLM
from repro.dist import sharding as shd
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.optim.adamw import AdamW

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = reduced(get_arch("smollm-135m"), d_model=64, n_heads=4, n_kv_heads=2)
cell = ShapeCell("t", 32, 8, "train")
with mesh:
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    pspecs = shd.param_specs(cfg, jax.eval_shape(lambda: params), mesh)
    pshard = shd.to_shardings(pspecs, mesh)
    params = jax.device_put(params, pshard)
    opt = AdamW(lr=1e-3, total_steps=10, warmup_steps=1)
    opt_state = jax.jit(opt.init)(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = SyntheticLM(cfg, cell).batch(jnp.int32(0))
    p, o, m = step(params, opt_state, batch, jnp.int32(0))
    # must equal the unsharded single-device result
    params1 = jax.device_put(params, jtu.tree_map(
        lambda _: NamedSharding(mesh, P()), pspecs,
        is_leaf=lambda x: isinstance(x, P)))
    p1, o1, m1 = step(params1, opt_state, batch, jnp.int32(0))
    assert abs(float(m["loss"]) - float(m1["loss"])) < 1e-4, (
        float(m["loss"]), float(m1["loss"]))
    print("LOSS_OK", float(m["loss"]))
""")
    assert "LOSS_OK" in out


@pytest.mark.slow
def test_pipeline_parallel_8dev():
    out = run_with_devices("""
import numpy as np
from repro.dist.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ("pod",))
n_layers, d = 8, 16
W = jax.random.normal(jax.random.PRNGKey(0), (n_layers, d, d)) * 0.1
x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
layer_fn = lambda p, h: h + jnp.tanh(h @ p["w"])
out = pipeline_apply(layer_fn, {"w": W}, x, mesh=mesh, n_micro=4)
ref = x
for i in range(n_layers):
    ref = layer_fn({"w": W[i]}, ref)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-6, err
print("PIPELINE_OK", err)
""")
    assert "PIPELINE_OK" in out


@pytest.mark.slow
def test_compressed_psum_8dev():
    out = run_with_devices("""
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum
mesh = jax.make_mesh((8,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 0.01
true_sum = g.sum(axis=0)
for scheme, tol in [("none", 1e-6), ("bf16", 2e-2), ("int8", 5e-2)]:
    fn = shard_map(lambda gg: compressed_psum(gg, "pod", scheme),
                   mesh=mesh, in_specs=(P("pod"),), out_specs=P("pod"),
                   check_rep=False)
    out = fn(g)[0]
    rel = float(jnp.linalg.norm(out - true_sum) / jnp.linalg.norm(true_sum))
    assert rel < tol, (scheme, rel)
print("PSUM_OK")
""")
    assert "PSUM_OK" in out


@pytest.mark.slow
def test_small_mesh_dryrun_16dev():
    """End-to-end mini version of the production dry-run: lower + compile a
    sharded train step on a (4, 4) mesh for a small-but-real config."""
    out = run_with_devices("""
from repro.launch.dryrun import build_cell, collective_bytes
from repro.configs.base import ShapeCell
import repro.configs.base as base
import repro.launch.dryrun as dr
mesh = jax.make_mesh((4, 4), ("data", "model"))
fn, args, in_sh, cfg, cell = dr.build_cell("smollm-135m", "train_4k", mesh)
with mesh:
    lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
    compiled = lowered.compile()
mem = compiled.memory_analysis()
cost = compiled.cost_analysis()
if isinstance(cost, list):  # jax<=0.4.x returns [dict]
    cost = cost[0]
coll = collective_bytes(compiled.as_text())
assert coll["total"] > 0
assert float(cost.get("flops", 0)) > 0
print("DRYRUN_OK", coll["total"])
""", n_devices=16)
    assert "DRYRUN_OK" in out
