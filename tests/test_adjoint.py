"""Reverse accuracy of every adjoint policy (the paper's central claim) +
the O(h^2) continuous-adjoint discrepancy of Prop. 1 + NFE accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adjoint import (POLICIES, checkpoint_floats, nfe_backward,
                                nfe_forward, odeint)
from repro.core.tableaus import get_tableau

jax.config.update("jax_enable_x64", True)

D = 8


def _vf():
    def f(u, th, t):
        return jnp.tanh(th["W"] @ u + th["b"]) + 0.1 * jnp.sin(t) * u
    return f


def _problem(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    u0 = jax.random.normal(ks[0], (D,))
    th = {"W": 0.3 * jax.random.normal(ks[1], (D, D)),
          "b": 0.1 * jax.random.normal(ks[2], (D,))}
    return u0, th


def _grads(policy, method="rk4", n_steps=16, dt=0.05, **kw):
    f = _vf()
    u0, th = _problem()

    def loss(u0, th):
        uf = odeint(f, u0, th, dt=dt, n_steps=n_steps, method=method,
                    adjoint=policy, **kw)
        return jnp.sum(uf ** 2)

    return jax.grad(loss, argnums=(0, 1))(u0, th)


REVERSE_ACCURATE = ["pnode", "pnode2", "aca", "anode"]


@pytest.mark.parametrize("method", ["euler", "midpoint", "bosh3", "rk4",
                                    "dopri5"])
@pytest.mark.parametrize("policy", REVERSE_ACCURATE)
def test_reverse_accuracy(policy, method):
    """Discrete-adjoint policies match AD-through-the-solver to ~machine eps."""
    g_ref = _grads("naive", method=method)
    g = _grads(policy, method=method)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-13)


@pytest.mark.parametrize("policy", ["revolve", "revolve2"])
@pytest.mark.parametrize("ncheck", [1, 2, 3, 7, 15])
def test_revolve_reverse_accuracy(ncheck, policy):
    g_ref = _grads("naive")
    g = _grads(policy, ncheck=ncheck)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-13)


def test_continuous_adjoint_not_reverse_accurate_but_h2():
    """Prop. 1: continuous-adjoint error is O(h^2) per step ~ O(h) overall
    at fixed horizon; halving h must shrink the gap ~4x per step (>=2x
    accumulated)."""
    f = _vf()
    u0, th = _problem()

    def gap(n_steps):
        dt = 0.8 / n_steps

        def loss(pol):
            def L(u0, th):
                uf = odeint(f, u0, th, dt=dt, n_steps=n_steps,
                            method="euler", adjoint=pol)
                return jnp.sum(uf ** 2)
            return jax.grad(L)(u0, th)

        return float(jnp.max(jnp.abs(gap_ := loss("continuous")
                                     - loss("naive"))))

    g1, g2, g4 = gap(10), gap(20), gap(40)
    assert g1 > 1e-8  # the discrepancy is real
    assert g1 / g2 > 1.7  # shrinks at least linearly with h
    assert g2 / g4 > 1.7


@pytest.mark.parametrize("method", ["euler", "rk4", "dopri5"])
def test_nfe_accounting(method):
    """Counted f evaluations in fwd/bwd match the Table-2 formulas."""
    n_steps = 7
    counter = {"n": 0}

    def f(u, th, t):
        counter["n"] += 1
        return jnp.tanh(th["W"] @ u)

    u0, th = _problem()

    s = get_tableau(method).num_stages
    # forward NFE (count traces: use python-level eval via no jit)
    counter["n"] = 0
    with jax.disable_jit():
        odeint(f, u0, th, dt=0.05, n_steps=n_steps, method=method,
               adjoint="naive")
    assert counter["n"] == nfe_forward(method, n_steps) == s * n_steps

    # pnode backward: one linearization (1 eval) per stage
    counter["n"] = 0
    with jax.disable_jit():
        def L(u0, th):
            return jnp.sum(odeint(f, u0, th, dt=0.05, n_steps=n_steps,
                                  method=method, adjoint="pnode") ** 2)
        jax.grad(L)(u0, th)
    total = counter["n"]
    assert total == nfe_forward(method, n_steps) \
        + nfe_backward(method, n_steps, "pnode")


def test_checkpoint_floats_ordering():
    """Memory model: pnode >= pnode2 >= anode; revolve(ncheck) < pnode for
    small ncheck — Table 2's qualitative ordering."""
    kw = dict(method="dopri5", n_steps=20, state_size=1000)
    pnode = checkpoint_floats(adjoint="pnode", **kw)
    pnode2 = checkpoint_floats(adjoint="pnode2", **kw)
    aca = checkpoint_floats(adjoint="aca", **kw)
    anode = checkpoint_floats(adjoint="anode", **kw)
    rev = checkpoint_floats(adjoint="revolve", ncheck=3, **kw)
    assert pnode > pnode2 == aca > anode
    assert rev < pnode


def test_all_policies_run_pytree_state():
    """Policies accept pytree states (dict of arrays), not just vectors."""
    def f(u, th, t):
        return {"a": jnp.tanh(th @ u["a"]), "b": -u["b"]}

    th = 0.2 * jax.random.normal(jax.random.PRNGKey(0), (4, 4))
    u0 = {"a": jnp.ones((4,)), "b": jnp.ones((3,))}
    for pol in POLICIES:
        kw = {"ncheck": 2} if pol.startswith("revolve") else {}
        uf = odeint(f, u0, th, dt=0.1, n_steps=5, method="midpoint",
                    adjoint=pol, **kw)
        assert jnp.all(jnp.isfinite(uf["a"])) and jnp.all(
            jnp.isfinite(uf["b"]))


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        odeint(_vf(), jnp.ones(3), {}, dt=0.1, n_steps=2, adjoint="bogus")


def test_quadrature_loss_term():
    """eq. 2's integral term: for f = -u, q = |u|^2 the quadrature equals
    (1 - e^{-2T})/2 * |u0|^2, and its gradient is policy-equivalent."""
    from repro.core.adjoint import odeint_with_quadrature

    def f(u, th, t):
        return -u * th

    def q(u, th, t):
        return jnp.sum(u ** 2)

    u0 = jnp.array([1.0, 2.0])
    th = jnp.float64(1.0)
    T, n = 1.0, 200

    def L(u0, th, pol, **kw):
        uf, Q = odeint_with_quadrature(f, q, u0, th, dt=T / n, n_steps=n,
                                       method="rk4", adjoint=pol, **kw)
        return Q + jnp.sum(uf ** 2)

    exact_Q = (1 - np.exp(-2 * T)) / 2 * 5.0
    Q = L(u0, th, "pnode") - float(np.exp(-2 * T) * 5.0)
    np.testing.assert_allclose(float(Q), exact_Q, rtol=1e-8)

    g_ref = jax.grad(lambda a, b: L(a, b, "naive"), argnums=(0, 1))(u0, th)
    for pol, kw in [("pnode", {}), ("revolve", {"ncheck": 3}),
                    ("revolve2", {"ncheck": 3})]:
        g = jax.grad(lambda a, b: L(a, b, pol, **kw), argnums=(0, 1))(u0, th)
        for x, y in zip(jax.tree_util.tree_leaves(g),
                        jax.tree_util.tree_leaves(g_ref)):
            np.testing.assert_allclose(x, y, rtol=1e-12)
