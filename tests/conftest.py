"""Shared fixtures.  NOTE: XLA_FLAGS / device-count forcing is deliberately
NOT set here — smoke tests and benches see the real single CPU device.
Multi-device tests spawn subprocesses (see tests/util.py)."""
import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    # the framework targets bf16/f32; tests that need f64 enable it locally
    # via jax.experimental.enable_x64.
    yield


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
