"""Per-kernel allclose vs the pure-jnp oracles, sweeping shapes/dtypes
(interpret mode on CPU) + hypothesis property tests on the invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # hermetic container: deterministic fallback examples
    from tests._hypothesis_stub import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rwkv6_scan import rwkv6_chunked_bhsd

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


def _qkv(key, b, h, hkv, sq, sk, dh, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, dh), jnp.float32).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,s,dh,bq,bk", [
    (1, 4, 4, 128, 64, 64, 64),     # MHA
    (2, 4, 2, 128, 64, 64, 64),     # GQA 2:1
    (1, 8, 1, 256, 32, 128, 64),    # MQA
    (1, 4, 4, 200, 64, 128, 128),   # ragged: S not multiple of block
    (1, 2, 2, 64, 128, 64, 64),     # wide head
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 48),
                                           (False, 0)])
def test_flash_attention_allclose(b, h, hkv, s, dh, bq, bk, causal, window,
                                  dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, h, hkv, s, s, dh, dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk)
    expected = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        **TOL[dtype])


def test_flash_attention_cross_lengths():
    """Sq != Sk (cross attention / prefix decoding)."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 4, 4, 64, 192, 64, jnp.float32)
    out = flash_attention_bhsd(q, k, v, causal=False, block_q=64, block_k=64)
    expected = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


@given(s=st.integers(16, 160), dh=st.sampled_from([32, 64]),
       h=st.sampled_from([2, 4]), group=st.sampled_from([1, 2]))
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(s, dh, h, group):
    hkv = h // group
    q, k, v = _qkv(jax.random.PRNGKey(s), 1, h, hkv, s, s, dh, jnp.float32)
    out = flash_attention_bhsd(q, k, v, causal=True, block_q=64, block_k=64)
    expected = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_model_layout_wrapper():
    b, s, h, dh = 2, 96, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    expected = jnp.moveaxis(
        ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                          jnp.moveaxis(v, 1, 2), causal=True), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 chunked recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,s,dh,chunk", [
    (1, 2, 128, 32, 32),
    (2, 4, 128, 64, 64),
    (1, 2, 256, 64, 64),
    (1, 1, 64, 128, 16),
])
def test_rwkv6_allclose(b, h, s, dh, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, h, s, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, h, s, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, s, dh), jnp.float32).astype(dtype)
    logw = -jnp.exp(
        jax.random.normal(ks[3], (b, h, s, dh), jnp.float32) * 0.5
    ).astype(dtype)
    u = (0.1 * jax.random.normal(ks[4], (h, dh), jnp.float32)).astype(dtype)
    out, sfin = rwkv6_chunked_bhsd(r, k, v, logw, u, chunk=chunk)
    oref, sref = ref.rwkv6_ref(r, k, v, logw, u)
    # chunked product-form vs sequential scan: different f32 rounding paths,
    # error grows ~sqrt(S); bf16 inputs add quantization noise
    tol = (dict(rtol=2e-2, atol=1e-3) if dtype != jnp.bfloat16
           else dict(rtol=0.15, atol=0.15))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(sfin, np.float32),
                               np.asarray(sref, np.float32), **tol)


@given(s=st.sampled_from([32, 96, 160]), chunk=st.sampled_from([16, 32]),
       dh=st.sampled_from([16, 32]))
@settings(max_examples=10, deadline=None)
def test_rwkv6_property_padding(s, chunk, dh):
    """The ops wrapper pads ragged S and strips it — results must match the
    unpadded oracle exactly on the first S positions."""
    b, h = 1, 2
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + chunk), 5)
    mk = lambda k_: jax.random.normal(k_, (b, s, h, dh), jnp.float32)
    r, k, v = mk(ks[0]), mk(ks[1]), mk(ks[2])
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dh)) * 0.5)
    u = 0.1 * jax.random.normal(ks[4], (h, dh))
    out, _ = ops.rwkv6_chunked(r, k, v, logw, u, chunk=chunk)
    oref, _ = ref.rwkv6_ref(*(jnp.moveaxis(t, 1, 2) for t in (r, k, v, logw)),
                            u)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.moveaxis(oref, 1, 2)),
                               rtol=2e-2, atol=1e-3)


def test_rwkv6_state_carries_across_chunks():
    """Chunked result must be independent of the chunk size."""
    b, h, s, dh = 1, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    mk = lambda k_: jax.random.normal(k_, (b, h, s, dh))
    r, k, v = mk(ks[0]), mk(ks[1]), mk(ks[2])
    logw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, dh)) * 0.5)
    u = 0.1 * jax.random.normal(ks[4], (h, dh))
    o1, s1 = rwkv6_chunked_bhsd(r, k, v, logw, u, chunk=16)
    o2, s2 = rwkv6_chunked_bhsd(r, k, v, logw, u, chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-2,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-2,
                               atol=1e-3)
