"""AdamW + gradient compression: convergence, clipping, schedule shape,
bf16/int8 wire compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamW
from repro.optim.compress import (bf16_compress, bf16_decompress,
                                  int8_compress, int8_decompress, int8_init,
                                  wire_bytes)


def test_adamw_converges_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=300,
                min_lr_frac=1.0)
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_global_norm_clip():
    opt = AdamW(clip_norm=1.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    g = {"x": 1e6 * jnp.ones(4)}
    _, _, metrics = opt.update(g, state, params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_cosine():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(opt.schedule(jnp.int32(s))) for s in range(100)]
    assert lrs[0] < 0.2                       # warmup starts low
    assert abs(max(lrs) - 1.0) < 0.05         # reaches peak
    assert lrs[-1] < 0.2                      # decays to ~min_lr_frac
    assert lrs[-1] > 0.09


def test_moments_stay_fp32_under_bf16_params():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    opt = AdamW()
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_params, new_state, _ = opt.update(g, state, params)
    assert new_params["w"].dtype == jnp.bfloat16
    assert new_state.v["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_bf16_roundtrip_error_small():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (256,))}
    back = bf16_decompress(bf16_compress(g))
    rel = float(jnp.max(jnp.abs(back["a"] - g["a"]))
                / jnp.max(jnp.abs(g["a"])))
    assert rel < 0.01
    assert wire_bytes(g, "bf16") == 256 * 2
    assert wire_bytes(g, "int8") == 256


def test_int8_error_feedback_reduces_bias():
    """With error feedback, the *accumulated* quantized sum tracks the true
    sum far better than independent quantization."""
    key = jax.random.PRNGKey(1)
    grads = [{"g": 0.01 * jax.random.normal(jax.random.fold_in(key, i),
                                            (512,))} for i in range(50)]

    res = int8_init(grads[0])
    acc_ef = jnp.zeros(512)
    acc_naive = jnp.zeros(512)
    acc_true = jnp.zeros(512)
    for g in grads:
        q, res = int8_compress(g, res)
        acc_ef = acc_ef + int8_decompress(q)["g"]
        qn, _ = int8_compress(g, int8_init(g))
        acc_naive = acc_naive + int8_decompress(qn)["g"]
        acc_true = acc_true + g["g"]

    err_ef = float(jnp.linalg.norm(acc_ef - acc_true))
    err_naive = float(jnp.linalg.norm(acc_naive - acc_true))
    assert err_ef < err_naive
    assert err_ef < 0.05 * float(jnp.linalg.norm(acc_true))


def test_int8_quantization_range():
    from repro.optim.compress import int8_dequantize, int8_quantize
    g = jnp.array([-3.0, 0.0, 1.5, 3.0])
    q, s = int8_quantize(g)
    assert q.dtype == jnp.int8
    assert int(q[3]) == 127
    np.testing.assert_allclose(np.asarray(int8_dequantize(q, s)),
                               np.asarray(g), atol=0.05)


def test_compressed_psum_matches_uncompressed():
    """On a size-1 axis, every scheme must be (near-)identity; exercised with
    a real multi-axis psum in the multi-device subprocess test."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim.compress import compressed_psum

    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}

    for scheme in ("none", "bf16", "int8"):
        fn = shard_map(
            lambda gg: compressed_psum(gg, "pod", scheme), mesh=mesh,
            in_specs=(P(),), out_specs=P(), check_rep=False)
        out = fn(g)
        tol = {"none": 1e-7, "bf16": 1e-2, "int8": 3e-2}[scheme]
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(g["w"]), rtol=tol, atol=tol)
