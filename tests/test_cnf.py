"""FFJORD CNF on the PNODE core: exactness of the log-det integral on an
analytically-known linear flow, trace estimators, and policy equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cnf import (cnf_log_prob, cnf_sample, exact_trace_vf,
                            hutchinson_trace_vf)
from repro.models.ode_nets import cnf_vf, cnf_vf_init

jax.config.update("jax_enable_x64", True)


def test_linear_flow_logdet_exact():
    """For f = A x, log det of the flow over [0,T] is T * tr(A)."""
    d = 4
    A = jnp.array(np.random.RandomState(0).randn(d, d) * 0.3)

    def f(x, th, t):
        return x @ th.T

    x = jnp.array(np.random.RandomState(1).randn(8, d))
    T, n = 1.0, 50
    lp = cnf_log_prob(f, x, A, dt=T / n, n_steps=n, method="rk4",
                      adjoint="naive")
    # z = expm(A) x; log p(x) = log N(z; 0, I) + T tr(A)... with sign:
    # d logdet/dt = -tr(A) accumulated, so lp = logN(z) - T tr(A) + T tr(A)?
    z = x @ jax.scipy.linalg.expm(A).T
    base = -0.5 * jnp.sum(z ** 2, -1) - 0.5 * d * jnp.log(2 * jnp.pi)
    expected = base - T * jnp.trace(A)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(expected),
                               rtol=1e-6)


@pytest.mark.parametrize("adjoint", ["pnode", "pnode2", "aca"])
def test_cnf_gradients_policy_equivalent(adjoint):
    d = 3
    theta = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float64),
        cnf_vf_init(jax.random.PRNGKey(0), d, hidden=(16, 16)))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, d), jnp.float64)

    def nll(theta, pol):
        lp = cnf_log_prob(cnf_vf, x, theta, dt=0.1, n_steps=10,
                          method="bosh3", adjoint=pol)
        return -lp.mean()

    g_ref = jax.grad(lambda th: nll(th, "naive"))(theta)
    g = jax.grad(lambda th: nll(th, adjoint))(theta)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-10, atol=1e-12)


def test_hutchinson_trace_unbiased():
    """Average of Hutchinson estimates over many probes ~ exact trace."""
    d = 6
    theta = cnf_vf_init(jax.random.PRNGKey(0), d, hidden=(24,))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, d))
    exact = exact_trace_vf(cnf_vf, d)((x, jnp.zeros(4)), theta, 0.3)[1]

    ests = []
    for i in range(800):
        probe = jax.random.rademacher(
            jax.random.PRNGKey(i), (4, d), jnp.float64)
        est = hutchinson_trace_vf(cnf_vf, probe)((x, jnp.zeros(4)), theta,
                                                 0.3)[1]
        ests.append(np.asarray(est))
    mean_est = np.mean(ests, axis=0)
    np.testing.assert_allclose(mean_est, np.asarray(exact), atol=0.05)


def test_sample_inverts_log_prob_flow():
    """flow(sample(z)) should land back near z for a smooth field."""
    d = 2
    theta = cnf_vf_init(jax.random.PRNGKey(0), d, hidden=(16,))
    z = jax.random.normal(jax.random.PRNGKey(1), (6, d))
    x = cnf_sample(cnf_vf, z, theta, dt=0.02, n_steps=50, method="rk4")

    aug = exact_trace_vf(cnf_vf, d)
    from repro.core.adjoint import odeint
    z_back, _ = odeint(aug, (x, jnp.zeros(6)), theta, dt=0.02, n_steps=50,
                       method="rk4", adjoint="naive")
    np.testing.assert_allclose(np.asarray(z_back), np.asarray(z), atol=1e-5)
