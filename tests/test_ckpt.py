"""Checkpointing: roundtrip, commit atomicity, keep-N retention, async
writer, and elastic restore under a different sharding."""
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import available_steps


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "layers": [jnp.arange(4.0), jnp.ones((2, 2))]},
            "step": jnp.int32(7),
            "m": (jnp.zeros(3), jnp.float32(1.5))}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 5, tree)
    restored, step = load_checkpoint(tmp_path, tree)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_uncommitted_checkpoint_ignored(tmp_path):
    """A directory without the DONE marker (killed mid-write) is invisible."""
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    p = save_checkpoint(tmp_path, 2, tree)
    (p / "DONE").unlink()
    assert available_steps(tmp_path) == [1]
    _, step = load_checkpoint(tmp_path, tree)
    assert step == 1


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    bad = {"params": {"w": jnp.zeros((8, 16))}}  # missing leaves
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, bad)


def test_keep_n_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_write=False)
    tree = _tree()
    for s in (10, 20, 30, 40):
        mgr.save(s, tree)
    assert available_steps(tmp_path) == [30, 40]


def test_async_writer_commits(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=3, async_write=True)
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, tree)
    mgr.wait()
    assert available_steps(tmp_path) == [1, 2]
    assert mgr.latest_step() == 2


def test_restore_after_mutation_differs(tmp_path):
    """The snapshot is taken at save time, not at wait time."""
    mgr = CheckpointManager(tmp_path, async_write=True)
    tree = {"w": jnp.ones(4)}
    mgr.save(1, tree)
    tree["w"] = tree["w"] + 99.0  # mutate after save
    restored, _ = mgr.restore_latest({"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones(4))


def test_elastic_restore_new_sharding(tmp_path):
    """Save unsharded, restore with an explicit (single-device) sharding —
    the elastic-restore path (different mesh shapes use the same code)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = _tree()
    save_checkpoint(tmp_path, 3, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), tree)
    restored, _ = load_checkpoint(tmp_path, tree, shardings=sh)
    w = restored["params"]["w"]
    assert w.sharding == NamedSharding(mesh, P(None, None))
    np.testing.assert_array_equal(np.asarray(w),
                                  np.asarray(tree["params"]["w"]))
