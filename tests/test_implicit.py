"""Implicit time integration (backward Euler / Crank-Nicolson) + its
discrete adjoint (eq. 13): forward accuracy, unconditional stability on
stiff problems where explicit methods blow up, and gradient exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adjoint import odeint
from repro.core.implicit import implicit_step, odeint_implicit

jax.config.update("jax_enable_x64", True)


def _linear_problem(lmbda=-4.0):
    A = jnp.diag(jnp.array([lmbda, -1.0]))
    th = {"A": A}

    def f(u, t_, t):
        return t_["A"] @ u

    u0 = jnp.array([1.0, 1.0])
    return f, u0, th, A


@pytest.mark.parametrize("method,order", [("beuler", 1), ("cn", 2)])
def test_forward_convergence_order(method, order):
    """Against the exact solution of u' = A u."""
    f, u0, th, A = _linear_problem()
    t1 = 1.0
    exact = jax.scipy.linalg.expm(np.asarray(A) * t1) @ np.asarray(u0)

    errs = []
    for n in (20, 40, 80):
        uf = odeint_implicit(f, u0, th, dt=t1 / n, n_steps=n, method=method)
        errs.append(float(np.max(np.abs(np.asarray(uf) - exact))))
    r1 = np.log2(errs[0] / errs[1])
    r2 = np.log2(errs[1] / errs[2])
    assert abs(r1 - order) < 0.35, (errs, r1)
    assert abs(r2 - order) < 0.35, (errs, r2)


def test_stiff_stability_explicit_fails_implicit_survives():
    """u' = -50 u with h = 0.1: explicit Euler diverges (|1+hl| = 4),
    backward Euler contracts."""
    def f(u, th, t):
        return th * u

    th = jnp.float64(-50.0)
    u0 = jnp.ones(1)
    u_exp = odeint(f, u0, th, dt=0.1, n_steps=50, method="euler",
                   adjoint="naive")
    u_imp = odeint_implicit(f, u0, th, dt=0.1, n_steps=50, method="beuler")
    assert not jnp.all(jnp.abs(u_exp) < 1.0)       # exploded
    assert jnp.all(jnp.abs(u_imp) < 1e-8)          # decayed like the truth


@pytest.mark.parametrize("method", ["beuler", "cn"])
def test_gradient_matches_finite_differences(method):
    def f(u, th, t):
        return jnp.tanh(th["W"] @ u + th["b"])

    d = 5
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    u0 = jax.random.normal(ks[0], (d,))
    th = {"W": 0.4 * jax.random.normal(ks[1], (d, d)),
          "b": 0.1 * jax.random.normal(ks[2], (d,))}

    def loss(u0, th):
        uf = odeint_implicit(f, u0, th, dt=0.1, n_steps=8, method=method)
        return jnp.sum(uf ** 2)

    g_u, g_th = jax.grad(loss, argnums=(0, 1))(u0, th)
    eps = 1e-6
    for i in range(d):
        e = jnp.zeros(d).at[i].set(eps)
        fd = (loss(u0 + e, th) - loss(u0 - e, th)) / (2 * eps)
        np.testing.assert_allclose(g_u[i], fd, rtol=2e-6)
    e = jnp.zeros((d, d)).at[1, 2].set(eps)
    fd = (loss(u0, {"W": th["W"] + e, "b": th["b"]})
          - loss(u0, {"W": th["W"] - e, "b": th["b"]})) / (2 * eps)
    np.testing.assert_allclose(g_th["W"][1, 2], fd, rtol=2e-6)


def test_gradient_matches_ad_through_solver():
    """Discrete adjoint == differentiating through an unrolled dense-Newton
    solve of the same scheme.  (Backprop through the production Newton/GMRES
    ``while_loop`` is impossible — the paper's motivating limitation — so the
    oracle here is a fixed-iteration dense-Jacobian Newton that IS
    differentiable.)"""
    def f(u, th, t):
        return jnp.tanh(th @ u) - 0.5 * u

    d = 4
    th = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (d, d))
    u0 = jax.random.normal(jax.random.PRNGKey(2), (d,))
    dt, n, theta = 0.2, 5, 0.5

    def naive_step(u, th, t_n):
        t_next = t_n + dt
        g_const = u + dt * (1 - theta) * f(u, th, t_n)
        v = u + dt * f(u, th, t_n)
        for _ in range(20):  # unrolled Newton, dense Jacobian -> AD-friendly
            r = v - dt * theta * f(v, th, t_next) - g_const
            J = jnp.eye(d) - dt * theta * jax.jacfwd(
                lambda uu: f(uu, th, t_next))(v)
            v = v - jnp.linalg.solve(J, r)
        return v

    def loss_adjoint(th):
        return jnp.sum(odeint_implicit(f, u0, th, dt=dt, n_steps=n,
                                       method="cn", newton_iters=20,
                                       newton_tol=1e-13,
                                       gmres_tol=1e-13) ** 2)

    def loss_naive(th):
        u = u0
        for k in range(n):
            u = naive_step(u, th, k * dt)
        return jnp.sum(u ** 2)

    g1 = jax.grad(loss_adjoint)(th)
    g2 = jax.grad(loss_naive)(th)
    np.testing.assert_allclose(g1, g2, rtol=1e-7, atol=1e-9)


def test_nonconvergence_surfaces_diverged_flag():
    """A deliberately starved Newton solve must surface stats.diverged
    instead of silently returning garbage states/gradients (the pre-stats
    implicit_step exited on newton_iters with no report)."""
    def f(u, th, t):
        return jnp.tanh(th @ u) - 0.5 * u

    d = 4
    th = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (d, d))
    u0 = jax.random.normal(jax.random.PRNGKey(2), (d,))

    # starved: one Newton iteration against an unreachable tolerance
    _, stats = odeint_implicit(f, u0, th, dt=0.2, n_steps=5, method="cn",
                               newton_iters=1, newton_tol=1e-16,
                               return_stats=True)
    assert bool(stats.diverged)
    assert float(stats.max_residual) > 1e-16

    # healthy solve on the same problem: converged, with a real iter count
    _, stats = odeint_implicit(f, u0, th, dt=0.2, n_steps=5, method="cn",
                               return_stats=True)
    assert not bool(stats.diverged)
    assert float(stats.max_residual) <= 1e-9
    assert int(stats.newton_iters) >= 5  # at least one iteration per step


def test_stats_flow_through_policies_jit_and_grad():
    """Every checkpoint policy threads the same stats out of its scan, under
    jit too, and taking grad of a loss alongside return_stats works (the
    stats outputs are non-differentiable auxiliaries)."""
    def f(u, th, t):
        return jnp.tanh(th @ u) - 0.5 * u

    d = 3
    th = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (d, d))
    u0 = jax.random.normal(jax.random.PRNGKey(2), (d,))

    ref = None
    for kw in ({}, {"adjoint": "revolve", "ncheck": 2},
               {"adjoint": "revolve2", "ncheck": 2},
               {"adjoint": "pnode", "offload": "spill"}):
        uf, stats = jax.jit(lambda u, t: odeint_implicit(
            f, u, t, dt=0.2, n_steps=5, method="beuler",
            return_stats=True, **kw))(u0, th)
        assert not bool(stats.diverged), kw
        if ref is None:
            ref = stats
        else:  # forward sweeps are identical -> identical reports
            assert int(stats.newton_iters) == int(ref.newton_iters), kw
            np.testing.assert_array_equal(np.asarray(stats.max_residual),
                                          np.asarray(ref.max_residual))

    def loss(th_):
        uf, stats = odeint_implicit(f, u0, th_, dt=0.2, n_steps=5,
                                    method="beuler", return_stats=True)
        return jnp.sum(uf ** 2)

    g = jax.grad(loss)(th)
    g_plain = jax.grad(lambda th_: jnp.sum(odeint_implicit(
        f, u0, th_, dt=0.2, n_steps=5, method="beuler") ** 2))(th)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_plain))


def test_implicit_step_reports_stepinfo():
    def f(u, th, t):
        return -th * u

    v, info = implicit_step(f, jnp.ones(2), jnp.float64(3.0), 0.0, 0.1, 1.0)
    assert bool(info.converged)
    assert int(info.iters) >= 1
    assert float(info.residual) <= 1e-9


def test_mass_matrix_form():
    """M u' = f with non-identity mass matrix (eq. 11/12)."""
    d = 3
    M = jnp.diag(jnp.array([1.0, 2.0, 4.0]))
    A = -jnp.eye(d)

    def f(u, th, t):
        return th @ u

    uf = odeint_implicit(f, jnp.ones(d), A, dt=0.05, n_steps=40,
                         method="beuler", mass=M)
    # M u' = A u  ->  u' = M^{-1} A u
    exact = jax.scipy.linalg.expm(
        np.linalg.inv(np.asarray(M)) @ np.asarray(A) * 2.0) @ np.ones(d)
    np.testing.assert_allclose(np.asarray(uf), exact, rtol=0.05)
