"""The implicit half of the memory stack: planner contract (property-based,
mirroring tests/test_mem.py's explicit contract), vmapped spill I/O, and an
end-to-end stiff-ensemble training run under a byte budget.

Property tests run against the analytic model only (no compilation), via
real hypothesis when importable or the offline stub fallback.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # hermetic container: deterministic offline fallback
    from tests._hypothesis_stub import given, settings, st

from repro.core.implicit import (IMPLICIT_POLICIES, implicit_nfe_backward,
                                 odeint_implicit)
from repro.mem.model import max_fitting_ncheck, policy_cost
from repro.mem.offload import reset_spill_stats, spill_stats
from repro.mem.planner import candidate_costs, plan_odeint

jax.config.update("jax_enable_x64", True)

S, TH = 48, 288  # state / theta bytes of the canonical d=6 f64 problem


def _vf():
    def f(u, th, t):
        return jnp.tanh(th @ u) - 0.5 * u
    return f


def _problem(d=6, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    u0 = jax.random.normal(ks[0], (d,))
    th = 0.4 * jax.random.normal(ks[1], (d, d))
    return u0, th


# ---------------------------------------------------------------------------
# planner model contract (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(n=st.integers(2, 80), extra=st.integers(1, 40),
       method=st.sampled_from(["cn", "beuler"]),
       policy=st.sampled_from(list(IMPLICIT_POLICIES)))
def test_predicted_peak_monotone_in_n_steps(n, extra, method, policy):
    """More steps can never shrink the predicted peak (pnode stores more
    states; revolve at fixed ncheck keeps storage flat, never less), and
    NFE-B is strictly monotone in n_steps for every policy."""
    kw = dict(method=method, state_bytes=S, theta_bytes=TH)
    nck = {"ncheck": 1} if policy != "pnode" else {}
    a = policy_cost(policy, n_steps=n, **nck, **kw)
    b = policy_cost(policy, n_steps=n + extra, **nck, **kw)
    assert b.peak_bytes >= a.peak_bytes
    assert b.extra_fevals > a.extra_fevals


@settings(max_examples=40)
@given(n=st.integers(4, 60), k=st.integers(1, 30), dk=st.integers(1, 20),
       method=st.sampled_from(["cn", "beuler"]))
def test_revolve_ncheck_tradeoff_monotone(n, k, dk, method):
    """The Prop-2 trade for implicit revolve: more checkpoint slots never
    increase recompute (NFE-B nonincreasing in ncheck) and never shrink
    storage (peak nondecreasing) — so the planner's pick-the-largest-
    fitting-ncheck rule is optimal."""
    k2 = k + dk
    if k2 >= n:
        return
    kw = dict(method=method, n_steps=n, state_bytes=S, theta_bytes=TH)
    a = policy_cost("revolve", ncheck=k, **kw)
    b = policy_cost("revolve", ncheck=k2, **kw)
    assert b.extra_fevals <= a.extra_fevals
    assert b.peak_bytes >= a.peak_bytes


@settings(max_examples=40)
@given(n=st.integers(2, 60), budget_kb=st.integers(1, 64),
       method=st.sampled_from(["cn", "beuler"]))
def test_plan_fits_budget_model_mode(n, budget_kb, method):
    """Model-mode contract: whenever the plan claims to fit, its predicted
    peak is within budget; when no in-device candidate fits, the fallback
    is the spill tier (never a silently over-budget device plan)."""
    f = _vf()
    u0, th = _problem()
    budget = budget_kb * 1024
    plan = plan_odeint(f, u0, th, dt=0.1, n_steps=n, method=method,
                       mem_budget=budget, verify="model")
    if plan.fits:
        assert plan.predicted.peak_bytes <= budget
    if plan.offload is None:
        assert plan.policy in IMPLICIT_POLICIES
        assert plan.fits
    else:
        assert plan.offload == "spill"
    # the chosen plan is recompute-minimal among fitting candidates
    for cand in plan.candidates:
        if cand.peak_bytes <= budget and plan.offload is None:
            assert plan.extra_fevals <= cand.extra_fevals


@settings(max_examples=25)
@given(n=st.integers(3, 50), method=st.sampled_from(["cn", "beuler"]),
       ni=st.integers(1, 12), gi=st.integers(2, 30))
def test_max_fitting_ncheck_consistent(n, method, ni, gi):
    """max_fitting_ncheck's answer actually fits, and one more slot does
    not (or is out of range) — with the implicit S-bytes-per-slot model."""
    kw = dict(method=method, n_steps=n, state_bytes=S, theta_bytes=TH,
              newton_iters=ni, gmres_iters=gi)
    probe = policy_cost("revolve", ncheck=1, **kw)
    budget = probe.peak_bytes + 3 * S  # room for a few more slots
    k = max_fitting_ncheck(budget, method=method, n_steps=n, state_bytes=S,
                           theta_bytes=TH, newton_iters=ni, gmres_iters=gi)
    assert k is not None and 1 <= k <= n - 1
    assert policy_cost("revolve", ncheck=k, **kw).peak_bytes <= budget
    if k < n - 1:
        assert policy_cost("revolve", ncheck=k + 1,
                           **kw).peak_bytes > budget


def test_candidates_implicit_family_only():
    cands = candidate_costs(method="cn", n_steps=20, state_bytes=S,
                            theta_bytes=TH, mem_budget=10 ** 6)
    names = {c.policy for c in cands}
    assert names <= set(IMPLICIT_POLICIES)
    assert "pnode" in names and "revolve" in names
    assert all(c.reverse_accurate for c in cands)


def test_invalid_ncheck_valueerrors():
    f = _vf()
    u0, th = _problem()
    kw = dict(dt=0.1, n_steps=8, method="cn", adjoint="revolve")
    with pytest.raises(ValueError, match="positive"):
        odeint_implicit(f, u0, th, ncheck=0, **kw)
    with pytest.raises(ValueError, match="positive"):
        odeint_implicit(f, u0, th, ncheck=-3, **kw)
    with pytest.raises(ValueError, match="n_steps"):
        odeint_implicit(f, u0, th, ncheck=8, **kw)
    with pytest.raises(ValueError, match="auto"):
        odeint_implicit(f, u0, th, **kw)  # ncheck omitted
    with pytest.raises(ValueError, match="naive"):
        odeint_implicit(f, u0, th, dt=0.1, n_steps=8, method="cn",
                        adjoint="naive")
    with pytest.raises(ValueError, match="auto"):
        odeint_implicit(f, u0, th, dt=0.1, n_steps=8, method="cn",
                        mem_budget=100)


def test_nfe_model_policy_ordering():
    """pnode is the implicit NFE-B floor; checkpoint spacing only adds
    Newton-solve recompute on top of it."""
    base = implicit_nfe_backward(30, "pnode")
    assert implicit_nfe_backward(30, "revolve", ncheck=3) > base
    assert implicit_nfe_backward(30, "revolve2", ncheck=3) > base
    assert implicit_nfe_backward(30, "revolve", ncheck=29) == base


# ---------------------------------------------------------------------------
# measured acceptance (compiles a few reverse passes; mirrors test_mem.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["cn", "beuler"])
def test_auto_measured_peak_fits_budget(method):
    """verify='measure' acceptance for the implicit family: set the budget
    to the measured peak of a known-good anchor; the plan must fit and its
    measured bytes must be within budget."""
    f = _vf()
    u0, th = _problem()
    so = dict(newton_iters=5, gmres_iters=8)
    from repro.mem.model import measure_reverse_cost
    anchor = measure_reverse_cost(f, u0, th, dt=0.1, n_steps=8,
                                  method=method, policy="pnode",
                                  solver_opts=so)["hlo_peak_bytes"]
    plan = plan_odeint(f, u0, th, dt=0.1, n_steps=8, method=method,
                       mem_budget=int(anchor), verify="measure",
                       solver_opts=so)
    assert plan.fits
    assert plan.measured_bytes is not None
    assert plan.measured_bytes <= anchor


# ---------------------------------------------------------------------------
# vmap + spill: the per-batch-element key scheme
# ---------------------------------------------------------------------------

def test_vmap_spill_bitwise_and_callback_counts():
    """A vmapped implicit solve with spill offload must (a) produce
    gradients bitwise-identical to the vmapped in-device solve and (b) pay
    ONE host callback per checkpoint segment for the entire batch (the
    batched callbacks carry all elements; no per-element round-trips)."""
    f = _vf()
    B, d, n = 5, 4, 7
    th = 0.4 * jax.random.normal(jax.random.PRNGKey(1), (d, d))
    u0s = jax.random.normal(jax.random.PRNGKey(2), (B, d))

    def batched_grad(offload):
        def loss(u, t):
            sol = jax.vmap(lambda u0: odeint_implicit(
                f, u0, t, dt=0.2, n_steps=n, method="cn", newton_iters=8,
                adjoint="pnode", offload=offload))(u)
            return jnp.sum(sol ** 2)
        return jax.jit(jax.grad(loss, argnums=(0, 1)))

    g_dev = batched_grad(None)(u0s, th)
    reset_spill_stats()
    g_spl = batched_grad("spill")(u0s, th)
    jax.block_until_ready(g_spl)
    stats = spill_stats()

    for a, b in zip(jax.tree_util.tree_leaves(g_spl),
                    jax.tree_util.tree_leaves(g_dev)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # default segment for n=7 is 3 -> ceil(7/3)=3 callbacks each way,
    # n slots each way, regardless of B
    assert stats["write_cb"] == 3 and stats["read_cb"] == 3
    assert stats["write_slots"] == n and stats["read_slots"] == n


def test_vmap_rejected_for_slot_addressed_offload():
    f = _vf()
    u0, th = _problem(d=3)
    u0s = jnp.stack([u0, u0 + 1.0])
    with pytest.raises(NotImplementedError, match="vmap"):
        jax.vmap(lambda u: odeint_implicit(
            f, u, th[:3, :3], dt=0.1, n_steps=6, method="cn",
            adjoint="revolve", ncheck=2, offload="spill"))(u0s)


# ---------------------------------------------------------------------------
# end-to-end: train the stiff ensemble under a byte budget
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_stiff_ensemble_trains_under_budget():
    """A small version of benchmarks/stiff_ensemble.py: vmapped
    Robertson-style systems trained for a few steps under a budget that
    forces the spill tier; loss must decrease and the executed tier must
    match the plan (spill callbacks actually fired)."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:  # benchmarks/ is a namespace pkg at repo root
        sys.path.insert(0, root)
    from benchmarks.stiff_ensemble import run_ensemble

    rec = run_ensemble(batch=64, n_steps=12, train_steps=4)
    assert rec["plan"]["offload"] == "spill"
    assert rec["effective_tier"] == "spill"
    assert rec["callbacks_per_grad"] > 0
    assert rec["diverged_fraction"] == 0.0
    assert rec["losses"][-1] < rec["losses"][0]
