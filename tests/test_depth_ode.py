"""PNODE over depth: every remat policy of ``checkpointed_scan`` computes
identical values AND gradients; ODEBlock integrates shared-weight depth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.depth_ode import ODEBlock, checkpointed_scan

jax.config.update("jax_enable_x64", True)

N_LAYERS, D = 12, 16


def _layer_fn(carry, p):
    return carry + jnp.tanh(carry @ p["w"] + p["b"])


def _setup():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    stacked = {"w": 0.2 * jax.random.normal(ks[0], (N_LAYERS, D, D)),
               "b": 0.05 * jax.random.normal(ks[1], (N_LAYERS, D))}
    u0 = jax.random.normal(ks[2], (4, D))
    return u0, stacked


@pytest.mark.parametrize("remat,kw", [
    ("full", {}), ("sqrt", {}), ("revolve", {"ncheck": 3}),
    ("revolve", {"ncheck": 1}),
])
def test_policies_match_plain_scan(remat, kw):
    u0, stacked = _setup()

    def loss(remat_, kw_):
        def L(u0, p):
            out = checkpointed_scan(_layer_fn, u0, p, N_LAYERS,
                                    remat=remat_, **kw_)
            return jnp.sum(out ** 2)
        val, grads = jax.value_and_grad(L, argnums=(0, 1))(u0, stacked)
        return val, grads

    v_ref, g_ref = loss("none", {})
    v, g = loss(remat, kw)
    np.testing.assert_allclose(v, v_ref, rtol=1e-14)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-14)


def test_odeblock_policies_agree():
    d = 8
    th = {"w": 0.3 * jax.random.normal(jax.random.PRNGKey(0), (d, d))}

    def vf(u, p, t):
        return jnp.tanh(u @ p["w"])

    u0 = jax.random.normal(jax.random.PRNGKey(1), (3, d))

    def run(adjoint, **kw):
        block = ODEBlock(vf, n_steps=8, method="rk4", adjoint=adjoint, **kw)

        def L(u0, th):
            return jnp.sum(block(u0, th) ** 2)
        return jax.grad(L, argnums=1)(u0, th)

    g_ref = run("naive")
    for pol, kw in [("pnode", {}), ("revolve", {"ncheck": 2})]:
        g = run(pol, **kw)
        np.testing.assert_allclose(g["w"], g_ref["w"], rtol=1e-12)


def test_revolve_requires_ncheck():
    u0, stacked = _setup()
    with pytest.raises(ValueError):
        checkpointed_scan(_layer_fn, u0, stacked, N_LAYERS, remat="revolve")
