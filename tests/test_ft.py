"""Fault tolerance: watchdog, straggler detection, elastic re-mesh plans,
and the end-to-end kill/restart determinism contract."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.ft import (Heartbeat, StragglerDetector, TrainSupervisor,
                      elastic_remesh_plan)
from repro.launch.train import train


def test_heartbeat_fires_on_stall():
    fired = []
    hb = Heartbeat(timeout_s=0.15, on_stall=fired.append, poll_s=0.02)
    hb.start()
    hb.beat()
    time.sleep(0.5)
    hb.stop()
    assert fired and fired[0] > 0.15
    assert hb.stall_count == 1  # fires once per stall, not per poll


def test_heartbeat_quiet_when_beating():
    fired = []
    hb = Heartbeat(timeout_s=0.3, on_stall=fired.append, poll_s=0.02)
    hb.start()
    for _ in range(10):
        hb.beat()
        time.sleep(0.05)
    hb.stop()
    assert not fired


def test_straggler_detection():
    det = StragglerDetector(window=20, k_mad=6.0, min_abs_s=0.0, warmup=3)
    flagged = [det.record(0.1 + 0.001 * i) for i in range(10)]
    assert not any(flagged)
    assert det.record(1.0)            # 10x the median -> straggler
    assert not det.record(0.1)        # baseline unpolluted by the outlier
    assert det.flagged_steps == [11]


def test_elastic_remesh_plan():
    assert elastic_remesh_plan(256, 16, lost=0) == (16, 16)
    assert elastic_remesh_plan(256, 16, lost=16) == (15, 16)
    assert elastic_remesh_plan(256, 16, lost=1) == (15, 16)  # round down
    with pytest.raises(RuntimeError):
        elastic_remesh_plan(16, 16, lost=1)


def test_supervisor_integration():
    sup = TrainSupervisor(heartbeat_timeout_s=60.0)
    with sup:
        for i in range(5):
            sup.step(lambda: time.sleep(0.01), i)
    assert len(sup.step_times) == 5


def test_kill_restart_replays_identically(tmp_path):
    """The paper-scale FT contract: train 10 steps with checkpoints, then
    restart from step 5 — losses 5..9 must be bit-identical (deterministic
    data pipeline + full optimizer state in the checkpoint)."""
    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    cell = ShapeCell("t", 2, 32, "train") and ShapeCell("t", 32, 2, "train")

    run1 = train(cfg, cell, steps=10, ckpt_dir=str(tmp_path / "a"),
                 ckpt_every=5, log_fn=lambda *_: None)
    # second job: restores the step-5 (and later step-10) checkpoint; force
    # restart from 5 by removing later checkpoints
    import shutil
    from repro.ckpt.checkpoint import available_steps
    for s in available_steps(tmp_path / "a"):
        if s > 5:
            shutil.rmtree(tmp_path / "a" / f"step_{s:010d}")
    run2 = train(cfg, cell, steps=10, ckpt_dir=str(tmp_path / "a"),
                 ckpt_every=100, log_fn=lambda *_: None)
    assert run2["resumed_from"] == 5
    np.testing.assert_array_equal(np.asarray(run1["losses"][5:]),
                                  np.asarray(run2["losses"]))
