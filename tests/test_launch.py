"""End-to-end launcher smoke tests: train with checkpoint/resume wiring and
batched serve (prefill + decode) through the public CLI entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import ARCHS, get_arch
from repro.launch.serve import serve
from repro.launch.train import train


def test_train_loss_decreases():
    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=2)
    cell = ShapeCell("t", 64, 4, "train")
    out = train(cfg, cell, steps=15, log_fn=lambda *_: None)
    assert len(out["losses"]) == 15
    assert out["losses"][-1] < out["losses"][0]
    assert all(np.isfinite(out["losses"]))


@pytest.mark.parametrize("scheme", ["bf16", "int8"])
def test_train_with_grad_compression(scheme):
    """Flag-gated wire compression in the production step tracks the
    uncompressed loss curve (bf16 ~ exactly; int8 via error feedback)."""
    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    cell = ShapeCell("t", 32, 4, "train")
    base = train(cfg, cell, steps=5, log_fn=lambda *_: None)["losses"]
    comp = train(cfg, cell, steps=5, compress=scheme,
                 log_fn=lambda *_: None)["losses"]
    assert all(np.isfinite(comp))
    # 5 steps is inside the warmup bump — the claim is that compression
    # tracks the uncompressed curve, not that loss already decreased
    np.testing.assert_allclose(base, comp, rtol=5e-2)


def test_train_rejects_unknown_compression():
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamW
    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    with pytest.raises(ValueError, match="compression"):
        make_train_step(cfg, AdamW(total_steps=10), compress="fp4")


def test_train_grad_accumulation_matches():
    """accum=2 on a fixed batch must track accum=1 closely (same data)."""
    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    cell = ShapeCell("t", 32, 4, "train")
    l1 = train(cfg, cell, steps=5, accum=1, log_fn=lambda *_: None)["losses"]
    l2 = train(cfg, cell, steps=5, accum=2, log_fn=lambda *_: None)["losses"]
    np.testing.assert_allclose(l1, l2, rtol=2e-3)


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-7b",
                                  "recurrentgemma-9b", "whisper-medium"])
def test_serve_generates(arch):
    cfg = reduced(get_arch(arch))
    tokens, stats = serve(cfg, batch=2, prompt_len=16, gen=6,
                          log_fn=lambda *_: None)
    assert tokens.shape == (2, 6)
    assert int(tokens.min()) >= 0 and int(tokens.max()) < cfg.vocab_size
    assert stats["decode_s"] > 0


def test_serve_greedy_deterministic():
    cfg = reduced(get_arch("smollm-135m"))
    t1, _ = serve(cfg, batch=2, prompt_len=16, gen=5, temperature=0.0,
                  log_fn=lambda *_: None)
    t2, _ = serve(cfg, batch=2, prompt_len=16, gen=5, temperature=0.0,
                  log_fn=lambda *_: None)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
