"""Adaptive Dopri5 (bounded while_loop, PI controller) + discrete adjoint
over accepted steps only (paper §4: rejected steps don't affect the adjoint)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import odeint_adaptive

jax.config.update("jax_enable_x64", True)


def _f():
    def f(u, th, t):
        return jnp.tanh(th["W"] @ u + th["b"])
    return f


def _problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    return (jax.random.normal(ks[0], (6,)),
            {"W": 0.3 * jax.random.normal(ks[1], (6, 6)),
             "b": 0.1 * jax.random.normal(ks[2], (6,))})


def test_solution_accuracy_vs_tolerance():
    f = _f()
    u0, th = _problem()
    u_tight, info_t = odeint_adaptive(f, u0, th, t0=0.0, t1=2.0,
                                      rtol=1e-10, atol=1e-10)
    u_loose, info_l = odeint_adaptive(f, u0, th, t0=0.0, t1=2.0,
                                      rtol=1e-4, atol=1e-4)
    err = float(jnp.max(jnp.abs(u_tight - u_loose)))
    assert err < 1e-3
    assert int(info_l.n_accepted) < int(info_t.n_accepted)


def test_gradient_vs_finite_differences():
    f = _f()
    u0, th = _problem()

    def loss(u0):
        uf, _ = odeint_adaptive(f, u0, th, t0=0.0, t1=1.0,
                                rtol=1e-9, atol=1e-9)
        return jnp.sum(uf ** 2)

    g = jax.grad(loss)(u0)
    eps = 1e-6
    for i in range(3):
        e = jnp.zeros(6).at[i].set(eps)
        fd = (loss(u0 + e) - loss(u0 - e)) / (2 * eps)
        np.testing.assert_allclose(g[i], fd, rtol=5e-6)


def test_stiffness_increases_step_count():
    """Stiffer system -> more accepted steps at fixed tolerance (the Table-8
    phenomenon: explicit adaptive cost grows with stiffness)."""
    def f(u, th, t):
        return th * u

    u0 = jnp.ones(1)
    _, soft = odeint_adaptive(f, u0, jnp.float64(-2.0), t0=0.0, t1=1.0,
                              rtol=1e-7, atol=1e-7)
    _, stiff = odeint_adaptive(f, u0, jnp.float64(-200.0), t0=0.0, t1=1.0,
                               rtol=1e-7, atol=1e-7, max_steps=4096)
    assert int(stiff.n_accepted) > 3 * int(soft.n_accepted)


def test_jit_compatible():
    f = _f()
    u0, th = _problem()

    @jax.jit
    def run(u0, th):
        uf, info = odeint_adaptive(f, u0, th, t0=0.0, t1=1.0)
        return uf, info.n_accepted

    uf, n = run(u0, th)
    assert jnp.all(jnp.isfinite(uf)) and int(n) > 0
