"""Checkpoint-schedule properties: the DP optimum matches the paper's
Prop. 2 closed form; emitted schedules are executable and achieve the
optimum; peak slot usage never exceeds N_c (hypothesis property tests)."""
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # hermetic container: deterministic fallback examples
    from tests._hypothesis_stub import given, settings, st

from repro.core.revolve import (optimal_extra_steps,
                                prop2_optimal_extra_steps, reverse_schedule,
                                schedule_extra_steps,
                                sweep_checkpoint_positions)


@given(n_t=st.integers(2, 60), n_c=st.integers(1, 12))
@settings(max_examples=200, deadline=None)
def test_dp_matches_prop2(n_t, n_c):
    assert optimal_extra_steps(n_t, n_c) == prop2_optimal_extra_steps(n_t, n_c)


@pytest.mark.parametrize("n_t,n_c,expected_t", [
    # binom(c+t-1, t-1) < n <= binom(c+t, t): spot values from the paper
    (10, 3, 2),   # binom(4,1)=4 < 10 <= binom(5,2)=10 -> t=2
    (11, 3, 3),   # 10 < 11 <= binom(6,3)=20 -> t=3
])
def test_prop2_bracketing(n_t, n_c, expected_t):
    from math import comb
    t = expected_t
    assert comb(n_c + t - 1, t - 1) < n_t <= comb(n_c + t, t)
    assert prop2_optimal_extra_steps(n_t, n_c) \
        == (t - 1) * n_t - comb(n_c + t, t - 1) + 1


def _simulate(n_t, n_c):
    """Execute the schedule symbolically; returns (adjointed order,
    extra steps, peak extra slots held)."""
    held = {0}  # boundary
    for p in sweep_checkpoint_positions(n_t, n_c):
        held.add(p)
    assert len(held) - 1 <= n_c, "sweep placed too many checkpoints"
    peak = len(held)
    adjointed = []
    extra = 0
    for act in reverse_schedule(n_t, n_c):
        if act[0] == "advance":
            _, start, m = act
            assert start in held, f"advance from unheld {start}"
            held.add(start + m)
            extra += m
        elif act[0] == "adjoint":
            idx = act[1]
            assert idx in held, f"adjoint of unheld {idx}"
            held.discard(idx)
            adjointed.append(idx)
        elif act[0] == "free":
            held.discard(act[1])
        peak = max(peak, len(held))
    return adjointed, extra, peak


@given(n_t=st.integers(2, 40), n_c=st.integers(1, 8))
@settings(max_examples=150, deadline=None)
def test_schedule_is_valid_and_optimal(n_t, n_c):
    adjointed, extra, peak = _simulate(n_t, n_c)
    # every step adjointed exactly once, in reverse order
    assert adjointed == list(range(n_t - 1, -1, -1))
    # achieves the DP optimum
    assert extra == optimal_extra_steps(n_t, n_c)
    # never holds more than N_c checkpoints beyond the boundary
    assert peak <= n_c + 1


@given(n_t=st.integers(2, 40))
@settings(max_examples=50, deadline=None)
def test_all_checkpoints_means_no_recompute(n_t):
    """PNODE store-all: with n_c >= n_t - 1 there is zero recomputation."""
    assert optimal_extra_steps(n_t, n_t - 1) == 0
    _, extra, _ = _simulate(n_t, n_t - 1)
    assert extra == 0


@given(n_t=st.integers(2, 30), n_c=st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_monotone_in_budget(n_t, n_c):
    """More checkpoint slots never hurt."""
    assert optimal_extra_steps(n_t, n_c + 1) <= optimal_extra_steps(n_t, n_c)


def test_schedule_counter_matches_simulation():
    for n_t, n_c in [(13, 2), (29, 4), (40, 3)]:
        acts = reverse_schedule(n_t, n_c)
        _, extra, _ = _simulate(n_t, n_c)
        assert schedule_extra_steps(acts) == extra
