"""Fault injection + recovery (PR 8): the chaos harness itself, spill
integrity + recompute fallback, the tier-degradation ladder, Newton
divergence rescue, adaptive NaN survival, checkpoint crash recovery, and
the train-loop sentinel/rollback/preemption paths.

The load-bearing assertions are *bitwise*: recovery must reproduce the
fault-free bits, not merely something close (the paper's reproducibility
contract extends to recovered runs)."""
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, CheckpointWriteError,
                        available_steps, load_checkpoint, save_checkpoint)
from repro.core.adaptive import odeint_adaptive
from repro.core.implicit import RescueConfig, odeint_implicit
from repro.ft import FaultPlan, FaultSpec, SimulatedPreemption
from repro.ft.watchdog import TrainSupervisor
from repro.mem.offload import (effective_tier, reset_spill_stats,
                               spill_stats)
from repro.models.ode_nets import cnf_vf, cnf_vf_init
from repro.obs import MetricsRegistry
from repro.serve import AdmissionError, BucketSpec, ODEEngine

jax.config.update("jax_enable_x64", True)

# -- the shared solver problem (linear, stiff enough to need Newton) --------

N_STEPS, SEG, DT = 16, 4, 0.05
U0 = jnp.ones(3)
TH = jnp.asarray(0.7)


def _f(u, th, t):
    return -th * u


def _grad(theta, plan=None, rescue=None, resilient=False, **kw):
    def loss(th):
        uf = odeint_implicit(_f, U0, th, dt=DT, n_steps=N_STEPS,
                             method="cn", adjoint="pnode", offload="spill",
                             offload_segment=SEG, newton_iters=8,
                             newton_tol=1e-12, fault_plan=plan,
                             rescue=rescue, resilient=resilient, **kw)
        return jnp.sum(uf ** 2)

    return jax.jit(jax.grad(loss))(theta)


@pytest.fixture(scope="module")
def g_clean():
    return np.asarray(_grad(TH))


# -- the plan itself --------------------------------------------------------

def test_faultplan_tick_windows():
    plan = FaultPlan([FaultSpec("s", 2, "x"), FaultSpec("s", 5, "y",
                                                        count=3)])
    kinds = [getattr(plan.tick("s"), "kind", None) for _ in range(9)]
    assert kinds == [None, None, "x", None, None, "y", "y", "y", None]
    assert plan.calls("s") == 9
    assert plan.fired_count("s") == 4
    assert plan.fired_count("s", kind="y") == 3
    plan.reset()
    assert plan.calls("s") == 0 and plan.fired_count() == 0


def test_faultplan_traced_gate_static_false():
    plan = FaultPlan([FaultSpec("newton", 3, "nan")])
    # no matching (site, kind) => the Python constant False: dormant
    # callers stage zero ops
    assert plan.traced_gate("newton", "diverge", 3) is False
    assert plan.traced_gate("adaptive", "nan", 3) is False
    hit = plan.traced_gate("newton", "nan", jnp.arange(6))
    assert np.array_equal(np.asarray(hit),
                          [False, False, False, True, False, False])


def test_corrupt_arrays_deterministic_and_detectable():
    plan = FaultPlan(seed=7)
    a = np.zeros(8)  # all-zero payloads must corrupt too
    (bad,), (bad2,) = plan.corrupt_arrays([a], 3), plan.corrupt_arrays([a],
                                                                       3)
    assert np.array_equal(bad, bad2) and not np.array_equal(bad, a)


# -- spill integrity + recompute fallback -----------------------------------

def test_spill_corrupt_recompute_bitwise(g_clean):
    plan = FaultPlan([FaultSpec("spill.write", 1, "corrupt")])
    reset_spill_stats()
    g = _grad(TH, plan=plan, resilient=True)
    assert np.array_equal(np.asarray(g), g_clean)
    assert spill_stats()["integrity_fail"] >= 1
    assert plan.fired_count("spill.write") == 1


def test_spill_drop_vmap_bitwise():
    ths = jnp.array([0.5, 0.9])

    def batch(plan=None, resilient=False):
        def loss(th):
            uf = odeint_implicit(_f, U0, th, dt=DT, n_steps=N_STEPS,
                                 method="cn", adjoint="pnode",
                                 offload="spill", offload_segment=SEG,
                                 newton_iters=8, newton_tol=1e-12,
                                 fault_plan=plan, resilient=resilient)
            return jnp.sum(uf ** 2)

        return jax.jit(jax.vmap(jax.grad(loss)))(ths)

    g0 = np.asarray(batch())
    g1 = np.asarray(batch(FaultPlan([FaultSpec("spill.write", 2, "drop")]),
                          resilient=True))
    assert np.array_equal(g0, g1)


def test_spill_read_flake_transient_retries(g_clean):
    plan = FaultPlan([FaultSpec("spill.read", 0, "flake")])  # one attempt
    reset_spill_stats()
    g = _grad(TH, plan=plan, resilient=True)
    assert np.array_equal(np.asarray(g), g_clean)
    assert spill_stats()["retry_cb"] >= 1


def test_spill_read_flake_persistent_raises():
    # resilient=False reads have no recompute fallback: a read that still
    # flakes after every retry must raise, not return zeros
    plan = FaultPlan([FaultSpec("spill.read", 0, "flake", count=10_000)])
    with pytest.raises(Exception, match="retries"):
        # callback failures surface when the result is materialized, not
        # at dispatch
        jax.block_until_ready(_grad(TH, plan=plan))


# -- tier-degradation ladder ------------------------------------------------

def test_effective_tier_ladder():
    assert effective_tier("spill", None) == "spill"
    # spill outage lands on the file-backed disk tier first (same
    # callback protocol, scanned-capable), host/device only after it
    down = FaultPlan([FaultSpec("tier.spill", 0, "down")])
    assert effective_tier("spill", down) == "disk"
    assert effective_tier("spill", down, scanned=True) == "disk"
    spill_disk = FaultPlan([FaultSpec("tier.spill", 0, "down"),
                            FaultSpec("tier.disk", 0, "down")])
    assert effective_tier("spill", spill_disk) == "host"
    # the scanned sweeps cannot use the slot-addressed host tier
    assert effective_tier("spill", spill_disk, scanned=True) == "device"
    all_down = FaultPlan([FaultSpec("tier.spill", 0, "down"),
                          FaultSpec("tier.disk", 0, "down"),
                          FaultSpec("tier.host", 0, "down")])
    assert effective_tier("spill", all_down) == "device"


def test_tier_degrade_revolve_bitwise():
    def g(plan):
        def loss(th):
            uf = odeint_implicit(_f, U0, th, dt=DT, n_steps=N_STEPS,
                                 method="cn", adjoint="revolve", ncheck=4,
                                 offload="spill", newton_iters=8,
                                 newton_tol=1e-12, fault_plan=plan)
            return jnp.sum(uf ** 2)

        return np.asarray(jax.jit(jax.grad(loss))(TH))

    down = FaultPlan([FaultSpec("tier.spill", 0, "down")])
    assert np.array_equal(g(None), g(down))
    assert ("tier.disabled", "spill") in down.notes("tier.disabled")


# -- Newton divergence rescue ----------------------------------------------

def test_newton_diverge_rescued_bitwise(g_clean):
    plan = FaultPlan([FaultSpec("newton", 5, "diverge")])
    g = _grad(TH, plan=plan, rescue=True)
    assert np.array_equal(np.asarray(g), g_clean)


def test_newton_nan_rescued_bitwise(g_clean):
    plan = FaultPlan([FaultSpec("newton", 3, "nan")])
    g = _grad(TH, plan=plan, rescue=True)
    assert np.array_equal(np.asarray(g), g_clean)


def test_newton_rescue_stats():
    def stats(plan, rescue):
        _, st = jax.jit(lambda th: odeint_implicit(
            _f, U0, th, dt=DT, n_steps=N_STEPS, method="cn",
            newton_iters=8, newton_tol=1e-12, fault_plan=plan,
            rescue=rescue, return_stats=True))(TH)
        return st

    st = stats(FaultPlan([FaultSpec("newton", 5, "diverge")]), True)
    assert int(st.rescued) == 1 and not bool(st.diverged)
    st_no = stats(FaultPlan([FaultSpec("newton", 5, "diverge")]), None)
    assert bool(st_no.diverged)  # unrescued: the divergence is reported


def test_dt_halving_last_resort():
    # no retries allowed: the only escape from a forced divergence is the
    # two-half-steps branch — convergent but legitimately different bits
    plan = FaultPlan([FaultSpec("newton", 5, "diverge")])
    cfg = RescueConfig(max_retries=0, escalate=1, dt_halving=True)
    uf, st = jax.jit(lambda th: odeint_implicit(
        _f, U0, th, dt=DT, n_steps=N_STEPS, method="cn", newton_iters=8,
        newton_tol=1e-12, fault_plan=plan, rescue=cfg,
        return_stats=True))(TH)
    uf_clean = jax.jit(lambda th: odeint_implicit(
        _f, U0, th, dt=DT, n_steps=N_STEPS, method="cn", newton_iters=8,
        newton_tol=1e-12))(TH)
    assert int(st.rescued) == 1 and not bool(st.diverged)
    assert np.all(np.isfinite(np.asarray(uf)))
    assert np.allclose(np.asarray(uf), np.asarray(uf_clean), rtol=1e-5)


def test_rescue_dormant_is_bitwise_noop(g_clean):
    # rescue enabled but nothing fails: attempt 0 always converges, so the
    # chain takes its first branch and the result is the fault-free bits
    assert np.array_equal(np.asarray(_grad(TH, rescue=True)), g_clean)


# -- adaptive under poisoned attempts ---------------------------------------

def test_adaptive_nan_rejected_and_survives():
    plan = FaultPlan([FaultSpec("adaptive", 2, "nan", count=2)])
    uf, info = odeint_adaptive(_f, U0, TH, t0=0.0, t1=1.0, max_steps=64,
                               fault_plan=plan)
    uf_clean, _ = odeint_adaptive(_f, U0, TH, t0=0.0, t1=1.0, max_steps=64)
    assert np.all(np.isfinite(np.asarray(uf)))
    assert int(info.n_rejected) >= 2
    assert np.allclose(np.asarray(uf), np.asarray(uf_clean), rtol=1e-5)


def test_adaptive_persistent_nan_hits_attempt_cap():
    # every attempt poisoned: the controller must terminate (total-attempt
    # cap), not shrink dt forever in an unbounded while loop
    plan = FaultPlan([FaultSpec("adaptive", 0, "nan", count=10_000_000)])
    _, info = odeint_adaptive(_f, U0, TH, t0=0.0, t1=1.0, max_steps=8,
                              fault_plan=plan)
    assert int(info.n_accepted) == 0
    assert int(info.n_rejected) == 8 * 8


# -- checkpoint crash recovery ----------------------------------------------

def _tree():
    return {"w": jnp.arange(4.0), "b": jnp.zeros(2)}


def test_ckpt_async_commit_error_surfaces(tmp_path):
    mgr = CheckpointManager(tmp_path, fault_plan=FaultPlan(
        [FaultSpec("ckpt.write", 0, "error")]))
    mgr.save(0, _tree())
    with pytest.raises(CheckpointWriteError, match="disk full"):
        mgr.wait()
    mgr.wait()  # errors are cleared once raised
    mgr.save(1, _tree())  # the next commit is clean
    mgr.wait()
    assert available_steps(tmp_path) == [1]


def test_ckpt_shape_mismatch_names_leaf(tmp_path):
    save_checkpoint(tmp_path, 0, _tree())
    bad = {"w": jnp.zeros(5), "b": jnp.zeros(2)}
    with pytest.raises(ValueError, match=r"'w' has shape \(4,\).*\(5,\)"):
        load_checkpoint(tmp_path, bad)


def test_ckpt_crash_mid_write_recovery(tmp_path):
    save_checkpoint(tmp_path, 0, _tree())
    plan = FaultPlan([FaultSpec("ckpt.write", 0, "preempt")])
    with pytest.raises(SimulatedPreemption):
        save_checkpoint(tmp_path, 1, _tree(), fault_plan=plan)
    # the kill left an uncommitted tmp dir behind; restore ignores it
    stale = [p for p in Path(tmp_path).iterdir()
             if p.name.startswith(".tmp_step_")]
    assert len(stale) == 1
    assert available_steps(tmp_path) == [0]
    restored, step = load_checkpoint(tmp_path, _tree())
    assert step == 0
    assert np.array_equal(np.asarray(restored["w"]), np.arange(4.0))
    # the next job's manager init sweeps the stale dir
    CheckpointManager(tmp_path)
    assert not any(p.name.startswith(".tmp_step_")
                   for p in Path(tmp_path).iterdir())


# -- watchdog ---------------------------------------------------------------

def test_watchdog_raises_for_stall_during_step():
    import time
    sup = TrainSupervisor(heartbeat_timeout_s=0.1)
    sup.heartbeat.poll_s = 0.02
    with sup:
        sup.step(lambda: None, 0)
        with pytest.raises(TimeoutError, match="during step 1"):
            sup.step(lambda: time.sleep(0.5), 1)


# -- the train loop under chaos ---------------------------------------------

STEPS, CKPT_EVERY = 8, 4


@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs.base import ShapeCell, reduced
    from repro.configs.registry import get_arch
    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    return cfg, ShapeCell("chaos", 32, 2, "train")


def _train(lm_setup, tmp, name, **kw):
    from repro.launch.train import train
    cfg, cell = lm_setup
    kw.setdefault("ckpt_every", CKPT_EVERY)
    return train(cfg, cell, steps=STEPS, ckpt_dir=f"{tmp}/{name}",
                 log_fn=lambda *a, **k: None, **kw)


@pytest.fixture(scope="module")
def clean_losses(lm_setup, tmp_path_factory):
    tmp = tmp_path_factory.mktemp("chaos_clean")
    return _train(lm_setup, tmp, "clean")["losses"]


def test_train_sentinel_skip_bitwise(lm_setup, clean_losses, tmp_path):
    out = _train(lm_setup, tmp_path, "skip", fault_plan=FaultPlan(
        [FaultSpec("train.step", 3, "nan")]))
    assert out["skipped_steps"] == 1 and out["rollbacks"] == 0
    assert out["losses"] == clean_losses


def test_train_rollback_replay_bitwise(lm_setup, clean_losses, tmp_path):
    out = _train(lm_setup, tmp_path, "roll", sentinel_bad_steps=3,
                 fault_plan=FaultPlan([FaultSpec(
                     "train.step", CKPT_EVERY + 1, "nan", count=3)]))
    assert out["rollbacks"] == 1 and out["skipped_steps"] == 3
    assert out["losses"] == clean_losses


def test_train_divergent_run_raises(lm_setup, tmp_path):
    # no checkpoint to roll back to: a persistently-bad run must raise,
    # not spin forever
    with pytest.raises(FloatingPointError):
        _train(lm_setup, tmp_path, "div", fault_plan=FaultPlan(
            [FaultSpec("train.step", 0, "nan", count=10_000)]))


def test_train_preempt_drains_and_resumes(lm_setup, clean_losses,
                                          tmp_path):
    out = _train(lm_setup, tmp_path, "pre", ckpt_every=100,
                 fault_plan=FaultPlan(
                     [FaultSpec("train.step", 2, "preempt")]))
    assert out["preempted"] and out["losses"] == clean_losses[:3]
    assert available_steps(f"{tmp_path}/pre") == [3]
    res = _train(lm_setup, tmp_path, "pre")  # same dir: auto-resume
    assert res["resumed_from"] == 3
    assert out["losses"] + res["losses"] == clean_losses


# -- serve fault sites (PR 10) ----------------------------------------------

SERVE_DIM = 3


@pytest.fixture(autouse=True)
def _serve_f32(request):
    # the serve stack targets the f32 regime; this module runs with the
    # global x64 flag on, so pin it off for the serve tests only
    if "serve" not in request.node.name:
        yield
        return
    with jax.experimental.disable_x64():
        yield


def _serve_engine(plan=None, registry=None):
    theta = cnf_vf_init(jax.random.PRNGKey(0), SERVE_DIM, hidden=(8, 8))
    return ODEEngine(cnf_vf, theta, dim=SERVE_DIM, dt=0.05, n_steps=8,
                     offload="spill", offload_segment=4,
                     buckets=BucketSpec((4,)), fault_plan=plan,
                     registry=registry)


def test_serve_request_injected_malformed_and_oversize():
    """``serve.request`` faults are stopped at admission: the injected
    malformed and oversized arrivals raise ``AdmissionError`` (and count
    as rejections) while the clean request in between is served."""
    plan = FaultPlan([FaultSpec("serve.request", 0, "malformed"),
                      FaultSpec("serve.request", 2, "oversize")])
    reg = MetricsRegistry()
    eng = _serve_engine(plan, reg)
    x = np.zeros(SERVE_DIM, np.float32)
    with pytest.raises(AdmissionError, match="malformed"):
        eng.submit("density", x)
    tk = eng.submit("density", x)  # arrival index 1: admitted cleanly
    with pytest.raises(AdmissionError, match="oversize"):
        eng.submit("density", x)
    eng.run()
    assert np.isfinite(tk.result(5)).all()
    assert reg.counter("serve.rejected") == 2
    assert reg.counter("serve.completed") == 1


def test_serve_decode_nan_poisons_one_lane_only():
    """An injected decode NaN is a *request-level* fault: the poisoned
    lane's ticket errors, its three batch-mates resolve bitwise equal to
    the fault-free run, and the engine keeps serving afterwards."""
    rng = np.random.default_rng(3)
    xs = rng.normal(size=(4, SERVE_DIM)).astype(np.float32)

    def run(plan):
        reg = MetricsRegistry()
        eng = _serve_engine(plan, reg)
        ts = [eng.submit("density", x) for x in xs]
        assert eng.step() == 4  # all four share one bucket-4 batch
        return eng, reg, ts

    _, _, clean = run(None)
    clean_vals = [tk.result(5) for tk in clean]

    eng, reg, ts = run(FaultPlan([FaultSpec("serve.decode", 0, "nan")]))
    with pytest.raises(RuntimeError, match="non-finite"):
        ts[0].result(5)
    for tk, want in zip(ts[1:], clean_vals[1:]):
        assert np.array_equal(tk.result(5), want)
    assert reg.counter("serve.errors") == 1
    assert reg.counter("serve.completed") == 3
    census = eng.slot_census()
    assert not any(census.values()), census

    # the batch program is not poisoned: the next quantum serves cleanly
    after = eng.submit("density", xs[1])
    assert eng.step() == 1
    assert np.array_equal(after.result(5), clean_vals[1])
