"""Synthetic data pipeline: determinism (the FT replay contract), shape
correctness per family, and the Zipf-ish marginal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.data.pipeline import SyntheticLM

CELL = ShapeCell("t", 64, 4, "train")


def test_deterministic_in_step():
    cfg = reduced(get_arch("smollm-135m"))
    pipe = SyntheticLM(cfg, CELL, seed=3)
    b1 = pipe.batch(jnp.int32(17))
    b2 = pipe.batch(jnp.int32(17))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = pipe.batch(jnp.int32(18))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_seed_isolation():
    cfg = reduced(get_arch("smollm-135m"))
    a = SyntheticLM(cfg, CELL, seed=0).batch(jnp.int32(0))
    b = SyntheticLM(cfg, CELL, seed=1).batch(jnp.int32(0))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))


def test_tokens_in_vocab_and_zipfish():
    cfg = reduced(get_arch("smollm-135m"))
    cell = ShapeCell("t", 512, 8, "train")
    toks = np.asarray(SyntheticLM(cfg, cell).batch(jnp.int32(0))["tokens"])
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size
    # low ids should be much more frequent than high ids (u^3 concentration)
    low = (toks < cfg.vocab_size // 4).mean()
    assert low > 0.5


def test_traced_step_works_inside_jit():
    cfg = reduced(get_arch("smollm-135m"))
    pipe = SyntheticLM(cfg, CELL)

    @jax.jit
    def get(step):
        return pipe.batch(step)["tokens"]

    t1 = get(jnp.int32(4))
    t2 = pipe.batch(jnp.int32(4))["tokens"]
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


@pytest.mark.parametrize("arch,extra", [
    ("llava-next-mistral-7b", "patches"),
    ("whisper-medium", "frames"),
])
def test_modality_stub_fields(arch, extra):
    cfg = reduced(get_arch(arch))
    batch = SyntheticLM(cfg, CELL).batch(jnp.int32(0))
    assert extra in batch
    assert batch[extra].ndim == 3
    if extra == "patches":
        assert batch["tokens"].shape[1] == CELL.seq_len - cfg.n_patches
