"""Paper §5.2: FFJORD continuous normalizing flow for density estimation,
trained with the PNODE adjoint (synthetic two-moons-style 2-d target so the
example runs on CPU in minutes; the benchmark harness covers the tabular
POWER/MINIBOONE/BSDS300 shapes).

  PYTHONPATH=src python examples/cnf_density.py [--iters 300] \
      [--adjoint pnode|pnode2|revolve|aca|continuous|naive]

``--serve`` additionally stands up the continuous-batching engine
(``repro.serve``) over the trained field and acts as a client: it streams
density and score requests at the engine and prints per-request results
plus batching/callback stats.  Quick demo:

  PYTHONPATH=src python examples/cnf_density.py --iters 20 --serve
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnf import cnf_log_prob, cnf_sample
from repro.models.ode_nets import cnf_vf, cnf_vf_init
from repro.optim.adamw import AdamW


def two_moons(key, n):
    k1, k2, k3 = jax.random.split(key, 3)
    theta = jnp.pi * jax.random.uniform(k1, (n,))
    upper = jax.random.bernoulli(k2, 0.5, (n,))
    x = jnp.where(upper, jnp.cos(theta), 1 - jnp.cos(theta))
    y = jnp.where(upper, jnp.sin(theta), 0.5 - jnp.sin(theta))
    pts = jnp.stack([x, y], -1)
    return pts + 0.08 * jax.random.normal(k3, pts.shape)


def serve_client(theta, args):
    """Client mode: serve the trained field through ``repro.serve`` and
    stream a mixed density/score request load at it.  Every request runs
    through one compiled program per (kind, bucket) pair — the jit cache
    is bounded by len(kinds) x len(bucket sizes) no matter how the batch
    composition churns, because lane keys live outside the trace."""
    from repro.obs import MetricsRegistry
    from repro.serve import BucketSpec, ODEEngine

    reg = MetricsRegistry()
    eng = ODEEngine(cnf_vf, theta, dim=2, dt=1.0 / args.n_steps,
                    n_steps=args.n_steps, method=args.method,
                    offload="spill", offload_segment=4,
                    buckets=BucketSpec((1, 2, 4, 8)), registry=reg)
    t0 = time.time()
    eng.warmup()  # pay the per-bucket compiles off the serving path
    print(f"[serve] warmup (compiles) {time.time()-t0:.1f}s")

    pts = np.asarray(two_moons(jax.random.PRNGKey(9), 12), np.float32)
    t0 = time.time()
    tickets = [(("score" if i % 4 == 0 else "density"),
                eng.submit("score" if i % 4 == 0 else "density", p))
               for i, p in enumerate(pts)]
    eng.run()
    wall = time.time() - t0
    for kind, tk in tickets:
        out = np.asarray(tk.result(30))
        shown = (f"logp {float(out):+.4f}" if out.ndim == 0
                 else "grad-x " + np.array2string(out, precision=4))
        print(f"[serve] {tk.rid} {kind:8s} {shown} "
              f"({tk.latency_ticks} ticks queued+served)")
    occ = reg.histogram("serve.batch_occupancy") or {}
    cbs = reg.histogram("serve.callbacks_per_request") or {}
    print(f"[serve] {len(pts)} requests in {wall:.2f}s, "
          f"mean occupancy {occ.get('sum', 0)/max(occ.get('count', 1), 1):.2f}, "
          f"mean spill callbacks/request "
          f"{cbs.get('sum', 0)/max(cbs.get('count', 1), 1):.1f}, "
          f"census empty: {not any(eng.slot_census().values())}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--adjoint", default="pnode")
    ap.add_argument("--ncheck", type=int, default=4)
    ap.add_argument("--n-steps", type=int, default=12)
    ap.add_argument("--method", default="bosh3")
    ap.add_argument("--serve", action="store_true",
                    help="after training, serve the field through the "
                         "repro.serve continuous-batching engine")
    args = ap.parse_args()

    theta = cnf_vf_init(jax.random.PRNGKey(0), 2, hidden=(64, 64))
    opt = AdamW(lr=2e-3, weight_decay=1e-5, warmup_steps=20,
                total_steps=args.iters)
    kw = {"ncheck": args.ncheck} if args.adjoint.startswith("revolve") else {}

    def nll(theta, x):
        lp = cnf_log_prob(cnf_vf, x, theta, dt=1.0 / args.n_steps,
                          n_steps=args.n_steps, method=args.method,
                          adjoint=args.adjoint, **kw)
        return -lp.mean()

    g_fn = jax.jit(jax.value_and_grad(nll))
    state = opt.init(theta)
    key = jax.random.PRNGKey(42)
    t0 = time.time()
    for it in range(args.iters):
        key, sub = jax.random.split(key)
        x = two_moons(sub, 256)
        loss, g = g_fn(theta, x)
        theta, state, _ = opt.update(g, state, theta)
        if it % max(1, args.iters // 10) == 0:
            print(f"iter {it:4d} nll {float(loss):.4f} "
                  f"({(time.time()-t0)/(it+1)*1e3:.0f} ms/iter)")

    # held-out NLL + sample roundtrip
    x_test = two_moons(jax.random.PRNGKey(7), 1024)
    final_nll = float(nll(theta, x_test))
    print(f"final held-out NLL: {final_nll:.4f} (adjoint={args.adjoint})")
    z = jax.random.normal(jax.random.PRNGKey(8), (8, 2))
    samples = cnf_sample(cnf_vf, z, theta, dt=1.0 / args.n_steps,
                         n_steps=args.n_steps, method=args.method)
    print("samples:\n", samples)

    if args.serve:
        serve_client(theta, args)


if __name__ == "__main__":
    main()
