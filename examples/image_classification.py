"""Paper §5.1: ODE-block image classification (SqueezeNext-style block with
the conv vector field), trained with selectable adjoint policies on a
synthetic CIFAR-10 stand-in (the dataset is not available offline; shapes,
batch and class count match).

  PYTHONPATH=src python examples/image_classification.py [--steps 100] \
      [--adjoint pnode] [--method rk4] [--n-steps 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.depth_ode import ODEBlock
from repro.models.ode_nets import (classifier_apply, classifier_init,
                                   conv_vf, softmax_xent)
from repro.optim.adamw import AdamW


def synthetic_cifar(key, n, n_classes=10):
    """Class-conditional Gaussian blobs in image space: learnable but
    non-trivial (accuracy well above chance requires the conv features)."""
    kl, kx = jax.random.split(key)
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    base = jax.random.normal(
        jax.random.PRNGKey(0), (n_classes, 8, 8, 3))  # fixed class templates
    t = base[labels]
    t = jax.image.resize(t, (n, 32, 32, 3), "nearest")
    x = t + 0.6 * jax.random.normal(kx, (n, 32, 32, 3))
    return x, labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--adjoint", default="pnode")
    ap.add_argument("--method", default="rk4")
    ap.add_argument("--n-steps", type=int, default=2)
    ap.add_argument("--ncheck", type=int, default=2)
    ap.add_argument("--channels", type=int, default=8)
    args = ap.parse_args()

    kw = {"ncheck": args.ncheck} if args.adjoint.startswith("revolve") else {}
    block = ODEBlock(conv_vf, n_steps=args.n_steps, method=args.method,
                     adjoint=args.adjoint, **kw)
    params = classifier_init(jax.random.PRNGKey(0), channels=args.channels)
    opt = AdamW(lr=2e-3, warmup_steps=10, total_steps=args.steps)
    state = opt.init(params)

    def loss_fn(params, x, labels):
        logits = classifier_apply(
            params, x, odeint_fn=lambda vf, u, th: block(u, th))
        return softmax_xent(logits, labels), logits

    g_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for step in range(args.steps):
        key, sub = jax.random.split(key)
        x, labels = synthetic_cifar(sub, args.batch)
        (loss, logits), g = g_fn(params, x, labels)
        params, state, _ = opt.update(g, state, params)
        if step % max(1, args.steps // 10) == 0:
            acc = float((logits.argmax(-1) == labels).mean())
            print(f"step {step:4d} loss {float(loss):.4f} acc {acc:.3f} "
                  f"({(time.time()-t0)/(step+1)*1e3:.0f} ms/step)")

    x, labels = synthetic_cifar(jax.random.PRNGKey(99), 512)
    logits = jax.jit(lambda p, x: classifier_apply(
        p, x, odeint_fn=lambda vf, u, th: block(u, th)))(params, x)
    print(f"eval accuracy: {float((logits.argmax(-1) == labels).mean()):.3f} "
          f"(adjoint={args.adjoint}, method={args.method})")


if __name__ == "__main__":
    main()
