"""End-to-end driver: train a ~100M-param LM (the real smollm-135m config)
for a few hundred steps with the full production stack — sharding rules,
PNODE depth checkpointing, AdamW, deterministic data, async checkpoints,
watchdog + straggler detection.

On this CPU container the full 135M config at short sequence length is the
honest "100M model, few hundred steps" run:

  PYTHONPATH=src python examples/train_lm.py --steps 300 --seq 128 --batch 8

(--reduced swaps in the tiny config for a fast smoke run; --production
targets the 16x16 mesh on real hardware.)
"""
import argparse
import time

import jax

from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production", action="store_true")
    args = ap.parse_args()

    full = get_arch(args.arch)
    if args.production:
        cfg, mesh = full, make_production_mesh()
    elif args.reduced:
        cfg, mesh = reduced(full), make_host_mesh()
    else:
        cfg, mesh = full, make_host_mesh()
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"remat={cfg.remat}, mesh={dict(mesh.shape)}")

    cell = ShapeCell("cli", args.seq, args.batch, "train")
    t0 = time.time()
    out = train(cfg, cell, steps=args.steps, mesh=mesh,
                ckpt_dir=args.ckpt_dir, ckpt_every=100,
                accum=args.accum, lr=args.lr, log_every=10)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"[train_lm] {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"in {dt:.0f}s ({toks/dt:.0f} tok/s); "
          f"stragglers flagged: {out['stragglers']}")


if __name__ == "__main__":
    main()
