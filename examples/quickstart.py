"""Quickstart: the PNODE core in five minutes.

  PYTHONPATH=src python examples/quickstart.py

1. Solve a neural ODE with the high-level discrete adjoint (any policy).
2. Show reverse accuracy vs AD-through-the-solver.
3. Show the memory/recompute trade of binomial checkpointing.
4. Train an LM with PNODE depth-checkpointing (the framework path).
"""
import jax
import jax.numpy as jnp

from repro.core.adjoint import nfe_backward, nfe_forward, odeint
from repro.core.revolve import optimal_extra_steps

# --- 1. a neural ODE layer ---------------------------------------------
d = 16
key = jax.random.PRNGKey(0)
theta = {"W": 0.3 * jax.random.normal(key, (d, d))}
u0 = jax.random.normal(jax.random.PRNGKey(1), (d,))


def f(u, th, t):
    return jnp.tanh(th["W"] @ u)


u_final = odeint(f, u0, theta, dt=0.1, n_steps=10, method="dopri5",
                 adjoint="pnode")
print("u(t1) norm:", float(jnp.linalg.norm(u_final)))

# --- 2. reverse accuracy ------------------------------------------------


def loss(pol, **kw):
    def L(th):
        uf = odeint(f, u0, th, dt=0.1, n_steps=10, method="dopri5",
                    adjoint=pol, **kw)
        return jnp.sum(uf ** 2)
    return jax.grad(L)(theta)["W"]


g_pnode = loss("pnode")
g_naive = loss("naive")        # AD straight through the solver
g_cont = loss("continuous")    # the vanilla-neural-ODE adjoint
print("pnode vs naive max |dg|:", float(jnp.max(jnp.abs(g_pnode - g_naive))))
print("cont  vs naive max |dg|:", float(jnp.max(jnp.abs(g_cont - g_naive))))

# --- 3. checkpointing trade-off ----------------------------------------
for ncheck in (1, 3, 9):
    extra = optimal_extra_steps(10, ncheck)
    g_rev = loss("revolve", ncheck=ncheck)
    print(f"revolve ncheck={ncheck}: {extra} recomputed steps, "
          f"max |dg| vs naive = {float(jnp.max(jnp.abs(g_rev - g_naive))):.2e},"
          f" NFE-B = {nfe_backward('dopri5', 10, 'revolve', ncheck)}")

# --- 4. the LM path (PNODE as the depth-gradient policy) ----------------
from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.launch.train import train

cfg = reduced(get_arch("smollm-135m"))       # tiny same-family config
cell = ShapeCell("demo", 64, 4, "train")
out = train(cfg, cell, steps=20, log_every=5)
print("LM losses (first->last):", out["losses"][0], "->", out["losses"][-1])
