"""Paper §5.3: learning Robertson's stiff chemical kinetics with an
implicit Crank-Nicolson integrator and its discrete adjoint (the capability
PNODE uniquely enables) vs adaptive explicit Dopri5.

  PYTHONPATH=src python examples/stiff_robertson.py [--epochs 300]
  PYTHONPATH=src python examples/stiff_robertson.py --mem-budget 400000

Expected: CN trains stably to low loss; Dopri5's gradient norm is orders of
magnitude larger / the step count explodes as the learned model stiffens
(paper Fig. 5 and Table 8).

With --mem-budget BYTES the CN solves run through the memory planner
(`adjoint="auto"`): the chosen checkpoint policy / ncheck / offload tier
is printed up front, and every training step executes under it.  Budgets
below the smallest in-device candidate fall back to the callback spill
tier — gradients stay bitwise-identical, only the checkpoint bytes move.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core.adaptive import odeint_adaptive
from repro.core.implicit import odeint_implicit
from repro.models.ode_nets import mlp_vf, mlp_vf_init
from repro.optim.adamw import AdamW


def robertson_truth(n_pts=30):
    """Integrate the true Robertson system on a log-time grid (backward
    Euler with tiny steps — the reference trajectory)."""
    k1, k2, k3 = 0.04, 3e7, 1e4

    def rhs(u, _th, _t):
        u1, u2, u3 = u
        return jnp.array([
            -k1 * u1 + k3 * u2 * u3,
            k1 * u1 - k2 * u2 ** 2 - k3 * u2 * u3,
            k2 * u2 ** 2,
        ])

    ts = np.logspace(-5, 2, n_pts)
    u = jnp.array([1.0, 0.0, 0.0])
    traj = []
    t_prev = 0.0
    for t in ts:
        u = odeint_implicit(rhs, u, 0.0, dt=(float(t) - t_prev) / 40,
                            n_steps=40, t0=t_prev, method="beuler",
                            newton_iters=20)
        traj.append(np.asarray(u))
        t_prev = float(t)
    return ts, np.array(traj)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--mem-budget", type=int, default=None,
                    help="device-byte budget for the CN adjoint; routes "
                         "each solve through plan_odeint via "
                         "odeint_implicit(adjoint='auto')")
    args = ap.parse_args()

    ts, y = robertson_truth(20)
    # min-max feature scaling (paper eq. 16) — crucial: u2 is ~1e-5 scale
    lo, hi = y.min(axis=0), y.max(axis=0)
    y_s = (y - lo) / (hi - lo + 1e-12)
    y0, target = jnp.asarray(y_s[0]), jnp.asarray(y_s)

    theta = mlp_vf_init(jax.random.PRNGKey(0), 3, hidden=args.hidden,
                        n_hidden=3)
    opt = AdamW(lr=5e-3, weight_decay=0.0, warmup_steps=10,
                total_steps=args.epochs)

    n_obs = len(ts)

    cn_kw = dict(method="cn", newton_iters=6, gmres_iters=10)
    if args.mem_budget is not None:
        from repro.mem.planner import plan_odeint
        plan = plan_odeint(mlp_vf, y0, theta, dt=0.5, n_steps=2,
                           method="cn", mem_budget=args.mem_budget,
                           verify="model",
                           solver_opts=dict(newton_iters=6, gmres_iters=10))
        print(f"planner @ {args.mem_budget} bytes: policy={plan.policy} "
              f"ncheck={plan.ncheck} offload={plan.offload} "
              f"predicted_peak={plan.predicted.peak_bytes}B "
              f"NFE-B={plan.extra_fevals} fits={plan.fits}")
        cn_kw.update(adjoint="auto", mem_budget=args.mem_budget,
                     mem_verify="model")

    def loss_cn(theta):
        # fixed-step CN over the scaled pseudo-time horizon, matching the
        # n_obs observation points
        us = []
        u = y0
        for k in range(n_obs - 1):
            u = odeint_implicit(mlp_vf, u, theta, dt=0.5, n_steps=2,
                                t0=float(k), **cn_kw)
            us.append(u)
        pred = jnp.stack([y0] + us)
        return jnp.mean(jnp.abs(pred - target))          # MAE (paper eq. 15)

    def loss_dopri(theta):
        us = []
        u = y0
        for k in range(n_obs - 1):
            u, _ = odeint_adaptive(mlp_vf, u, theta, t0=float(k),
                                   t1=float(k + 1), rtol=1e-6, atol=1e-6,
                                   max_steps=512)
            us.append(u)
        pred = jnp.stack([y0] + us)
        return jnp.mean(jnp.abs(pred - target))

    for name, loss_fn in (("CN (implicit)", loss_cn),
                          ("Dopri5 (explicit adaptive)", loss_dopri)):
        print(f"\n=== training with {name} ===")
        state = opt.init(theta)
        params = theta
        g_fn = jax.jit(jax.value_and_grad(loss_fn))
        t0 = time.time()
        gnorms, losses = [], []
        for ep in range(args.epochs):
            l, g = g_fn(params)
            gn = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                                    for x in jax.tree_util.tree_leaves(g))))
            params, state, _ = opt.update(g, state, params)
            losses.append(float(l))
            gnorms.append(gn)
            if ep % max(1, args.epochs // 10) == 0:
                print(f"  epoch {ep:4d} loss {float(l):.5f} |g| {gn:.3e}")
        print(f"  final loss {losses[-1]:.5f}; max |g| {max(gnorms):.3e}; "
              f"{time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
