"""Sharded checkpointing with async writes, keep-N retention, and elastic
restore (a checkpoint written under one mesh restores onto any other mesh).

Format: one directory per step, ``step_<k>/``, containing
  * ``tree.json``   — pytree structure: flattened key paths, shapes, dtypes
  * ``arrays.npz``  — one entry per leaf, keyed by the flattened path
  * ``DONE``        — commit marker written last (atomic-rename pattern);
                      restore ignores directories without it, so a job killed
                      mid-write never corrupts the latest checkpoint.

Elasticity: leaves are saved as *global* arrays (fully addressable on this
single-process runtime; on a real multi-host pod each host writes its
addressable shards and the loader reassembles — the directory format keeps a
``shard_<i>.npz`` namespace for that). On restore, arrays are placed with
``jax.device_put(x, sharding)`` against whatever mesh the *new* job built, so
restoring a 512-chip checkpoint onto 256 chips (or 8 CPU devices) is just a
different placement of the same global data.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import tree_util as jtu

from repro.ft.inject import SimulatedPreemption

SEP = "::"


class CheckpointWriteError(RuntimeError):
    """A background checkpoint commit failed.  Raised on the training
    thread at the next ``save``/``wait``/restore — a full disk (or any
    other commit failure) must not silently disable checkpointing."""


def _flatten_with_paths(tree):
    leaves = jtu.tree_leaves_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = SEP.join(_path_part(p) for p in path)
        out[key] = leaf
    return out


def _path_part(p) -> str:
    if isinstance(p, jtu.DictKey):
        return str(p.key)
    if isinstance(p, jtu.SequenceKey):
        return str(p.idx)
    if isinstance(p, jtu.GetAttrKey):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    fault_plan=None) -> Path:
    """Synchronous sharded save.  Returns the committed checkpoint path.

    ``fault_plan=`` (a ``repro.ft.FaultPlan``) is the chaos hook: site
    ``"ckpt.write"`` fires after the data files are staged but before the
    DONE marker — kind ``preempt`` raises ``SimulatedPreemption`` and
    deliberately leaves the uncommitted ``.tmp_step_*`` directory behind
    (a real SIGKILL runs no cleanup), kind ``error`` raises ``OSError``
    (a full disk) through the normal cleanup path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=directory))
    try:
        flat = _flatten_with_paths(tree)
        arrays = {}
        meta = {"step": step, "leaves": {}, "treedef": None}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            meta["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "tree.json").write_text(json.dumps(meta))
        if fault_plan is not None:
            spec = fault_plan.tick("ckpt.write")
            if spec is not None and spec.kind == "preempt":
                raise SimulatedPreemption(
                    f"injected preemption mid-write of step {step}")
            if spec is not None and spec.kind == "error":
                raise OSError(f"injected commit failure at step {step} "
                              "(disk full)")
        (tmp / "DONE").write_text(str(time.time()))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except SimulatedPreemption:
        # a simulated SIGKILL runs no handlers: keep the stale tmp dir so
        # recovery (ignore it + clean on next manager init) gets exercised
        raise
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def load_checkpoint(directory: str | Path, template: Any,
                    step: Optional[int] = None,
                    shardings: Any = None) -> tuple[Any, int]:
    """Restore the latest (or a specific) committed checkpoint into the
    structure of ``template``; ``shardings`` (same tree shape, or None)
    reshards every leaf for the *current* mesh — the elastic-restore path."""
    directory = Path(directory)
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {directory}")
    if step is None:
        step = steps[-1]
    if step not in steps:
        raise FileNotFoundError(f"step {step} not in {steps}")
    path = directory / f"step_{step:010d}"
    data = np.load(path / "arrays.npz")

    flat_template = _flatten_with_paths(template)
    missing = set(flat_template) - set(data.files)
    extra = set(data.files) - set(flat_template)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    if extra:
        raise ValueError(f"checkpoint has unknown leaves: {sorted(extra)[:5]}")

    flat_shardings = (_flatten_with_paths(shardings)
                      if shardings is not None else {})

    def restore_leaf(path_, leaf):
        key = SEP.join(_path_part(p) for p in path_)
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)} but "
                f"the restore template expects {tuple(leaf.shape)} — the "
                "checkpoint was written by a different model config/mesh "
                "than this job is running")
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        sh = flat_shardings.get(key)
        if sh is not None:
            return jax.device_put(arr, sh)
        return jnp.asarray(arr)

    restored = jtu.tree_map_with_path(restore_leaf, template)
    return restored, step


def available_steps(directory: str | Path) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    out = []
    for p in sorted(directory.iterdir()):
        if p.name.startswith("step_") and (p / "DONE").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


class CheckpointManager:
    """Async keep-N checkpoint manager.

    ``save`` snapshots the tree to host memory on the caller thread (cheap —
    device->host copy) and commits to disk on a background thread, keeping
    the training step off the I/O critical path.  ``wait`` joins outstanding
    writes (call before exit/restore).  Retention keeps the newest ``keep_n``
    committed checkpoints.

    A failed background commit is NOT swallowed: the exception is captured
    per-thread and re-raised (wrapped in ``CheckpointWriteError``) on the
    next ``save``/``wait``/restore call, then cleared.  Stale
    ``.tmp_step_*`` directories from a previous job killed mid-write are
    cleaned up on init (restore already ignores them: no DONE marker).
    """

    def __init__(self, directory: str | Path, keep_n: int = 3,
                 async_write: bool = True, fault_plan=None):
        self.directory = Path(directory)
        self.keep_n = keep_n
        self.async_write = async_write
        self.fault_plan = fault_plan
        self._lock = threading.Lock()
        self._pending: list[threading.Thread] = []
        self._errors: list[tuple[int, BaseException]] = []
        self.saved_steps: list[int] = available_steps(self.directory)
        for stale in self.directory.glob(".tmp_step_*"):
            shutil.rmtree(stale, ignore_errors=True)

    def _raise_pending_errors(self) -> None:
        with self._lock:
            errs, self._errors = self._errors, []
        if errs:
            step, exc = errs[0]
            raise CheckpointWriteError(
                f"{len(errs)} background checkpoint commit(s) failed; "
                f"first failure at step {step}: {exc!r}") from exc

    def save(self, step: int, tree: Any) -> None:
        self._raise_pending_errors()
        host_tree = jtu.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def commit():
            save_checkpoint(self.directory, step, host_tree,
                            fault_plan=self.fault_plan)
            with self._lock:
                self.saved_steps.append(step)
                self.saved_steps = sorted(set(self.saved_steps))
                self._retain()

        if self.async_write:
            def commit_captured():
                try:
                    commit()
                except BaseException as exc:  # incl. SimulatedPreemption
                    with self._lock:
                        self._errors.append((step, exc))

            t = threading.Thread(target=commit_captured, daemon=True)
            t.start()
            self._pending = [th for th in self._pending if th.is_alive()]
            self._pending.append(t)
        else:
            commit()

    def _retain(self) -> None:
        while len(self.saved_steps) > self.keep_n:
            victim = self.saved_steps.pop(0)
            shutil.rmtree(self.directory / f"step_{victim:010d}",
                          ignore_errors=True)

    def wait(self) -> None:
        for t in self._pending:
            t.join()
        self._pending = []
        self._raise_pending_errors()

    def restore_latest(self, template: Any, shardings: Any = None):
        self.wait()
        return load_checkpoint(self.directory, template, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        self.wait()
        steps = available_steps(self.directory)
        return steps[-1] if steps else None
