from repro.ckpt.checkpoint import (CheckpointManager, CheckpointWriteError,
                                   available_steps, load_checkpoint,
                                   save_checkpoint)

__all__ = ["CheckpointManager", "CheckpointWriteError", "available_steps",
           "save_checkpoint", "load_checkpoint"]
