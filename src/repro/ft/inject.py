"""Deterministic fault injection: the chaos harness behind the recovery
stack (spill-store integrity + recompute fallback, Newton divergence
rescue, the train-loop sentinel, and checkpoint crash simulation).

Design constraints, in order:

* **Deterministic.**  No wall clock, no RNG draws at decision time.  Every
  fault is keyed by a *call index* at a named *site* — the Nth write
  callback, the Mth Newton step — so the same ``FaultPlan`` replayed
  against the same program fires the same faults in the same places.
  "Corrupt" payload bytes come from ``np.random.default_rng`` seeded by
  ``(plan.seed, site-salt)``: random-looking, reproducible.

* **Traceable where it must be.**  Host-side sites (spill callbacks,
  checkpoint writes, the train loop) consume faults with ``tick(site)`` —
  a lock-protected Python counter that advances once per *execution*.
  Solver-interior sites run inside jit-compiled ``lax`` control flow where
  a Python counter cannot see executions; those are keyed by the traced
  step index instead, via ``traced_gate(site, kind, idx)`` which builds a
  (tiny, constant-folded-when-empty) traced comparison.  Traced faults
  therefore re-fire deterministically when the adjoint recomputes a step —
  exactly what the bitwise-recovery contract needs: a recomputed segment
  replays its faults AND its rescues, reproducing the forward's bits.

* **Zero-cost when absent.**  ``traced_gate`` returns the Python constant
  ``False`` when the plan has no matching specs (callers skip staging any
  gate ops), and every recovery path in the codebase treats
  ``fault_plan=None`` as "trace nothing".

Sites currently consumed (see the subsystem modules for semantics):

  ``spill.write``   host, per write-callback chunk; kinds ``drop`` (payload
                    never stored) / ``corrupt`` (stored bytes flipped
                    *after* checksumming — corruption at rest).
  ``spill.read``    host, per read *attempt* (retries re-tick); kind
                    ``flake`` (attempt fails; the store retries with
                    backoff, so ``count`` spans transient vs persistent).
  ``ckpt.write``    host, per ``save_checkpoint`` commit, fired after data
                    is staged but before the DONE marker; kinds
                    ``preempt`` (raise ``SimulatedPreemption`` — models
                    SIGKILL mid-write, tmp dir left behind) / ``error``
                    (raise OSError — models a full disk).
  ``train.step``    host, per train-step *attempt*; kinds ``nan`` (poison
                    that step's loss/grads in-graph) / ``preempt``
                    (request shutdown after the step — drains checkpoints).
  ``newton``        traced, ``index`` = absolute step index; kinds
                    ``nan`` / ``inf`` (poison the exit state of that
                    step's first solve attempt — the result, not the
                    vector field, so clean steps compile to the exact
                    fault-free HLO) / ``diverge`` (force the convergence
                    flag false on the first attempt).
  ``adaptive``      traced, ``index`` = attempt counter (accepted +
                    rejected); kind ``nan`` poisons that attempt's f.
  ``tier.<name>``   consulted statically by ``mem.offload.effective_tier``;
                    kind ``down`` marks the tier unavailable so the store
                    factory walks the degradation ladder.
  ``serve.request`` host, per ``repro.serve`` queue admission; kinds
                    ``malformed`` / ``oversize`` (force the same
                    ``AdmissionError`` rejection path a genuinely bad
                    request takes — the request never occupies a lane).
  ``serve.decode``  host, per engine batch (ODE path) or decode step (LM
                    path); kind ``nan`` poisons exactly ONE lane's result,
                    resolving that request's ticket with an error while
                    its batch-mates stay bitwise-correct (batch isolation;
                    tested in tests/test_chaos.py).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class SimulatedPreemption(BaseException):
    """Injected mid-operation kill.  Deliberately a ``BaseException``:
    ``except Exception`` cleanup handlers do NOT see it, which is the
    point — a real SIGKILL runs no handlers, so simulated preemption must
    skip the tidy-up paths too (e.g. ``save_checkpoint`` leaves its
    uncommitted ``.tmp_step_*`` directory behind, and recovery must cope)."""


@dataclass(frozen=True)
class FaultSpec:
    """One planned fault: fire at ``site`` for call indices
    ``[index, index + count)`` (or, for traced sites, at traced step/attempt
    values in that window), with failure mode ``kind``."""
    site: str
    index: int
    kind: str
    count: int = 1

    def covers(self, i: int) -> bool:
        return self.index <= i < self.index + self.count


class FaultPlan:
    """A deterministic schedule of injected faults.

    Thread-safe: ``tick`` is called from XLA callback threads and
    checkpoint commit threads concurrently with the train loop.  One plan
    instance should drive one experiment; ``reset()`` rewinds the call
    counters (e.g. between a warmup and the measured run).
    """

    def __init__(self, faults: Sequence[FaultSpec] = (), seed: int = 0):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._calls: Dict[str, int] = {}
        self._fired: List[Tuple[str, int, FaultSpec]] = []
        self._notes: List[Tuple[str, Any]] = []
        by_site: Dict[str, List[FaultSpec]] = {}
        for s in self.faults:
            by_site.setdefault(s.site, []).append(s)
        self._by_site = by_site

    # -- host-side consumption ---------------------------------------------
    def tick(self, site: str) -> Optional[FaultSpec]:
        """Advance ``site``'s call counter; return the spec covering this
        call index (None = no fault here).  Each call to an instrumented
        operation — including a *retry* — ticks once, so a spec's
        ``count`` window distinguishes transient faults (retry escapes the
        window) from persistent ones (every retry still covered)."""
        with self._lock:
            i = self._calls.get(site, 0)
            self._calls[site] = i + 1
            for spec in self._by_site.get(site, ()):
                if spec.covers(i):
                    self._fired.append((site, i, spec))
                    return spec
        return None

    def calls(self, site: str) -> int:
        with self._lock:
            return self._calls.get(site, 0)

    # -- traced consumption -------------------------------------------------
    def traced_gate(self, site: str, kind: str, idx):
        """A traced boolean: does a (site, kind) spec cover traced index
        ``idx``?  Returns the Python constant ``False`` when no spec
        matches, so dormant callers stage zero ops.  The comparison is
        against static index windows — pure arithmetic on ``idx``, no
        callbacks, safe anywhere (scan/while/vmap bodies, fwd and bwd
        rules), and it re-fires identically when a step is recomputed."""
        windows = [(s.index, s.index + s.count)
                   for s in self._by_site.get(site, ()) if s.kind == kind]
        if not windows:
            return False
        import jax.numpy as jnp
        idx = jnp.asarray(idx)
        hit = jnp.zeros(jnp.shape(idx), jnp.bool_)
        for lo, hi in windows:
            hit = jnp.logical_or(hit, jnp.logical_and(idx >= lo, idx < hi))
        return hit

    def has(self, site: str, kind: str | None = None) -> bool:
        specs = self._by_site.get(site, ())
        return any(kind is None or s.kind == kind for s in specs)

    # -- static tier consultation -------------------------------------------
    def tier_disabled(self, tier: str) -> bool:
        """True if the plan marks storage tier ``tier`` unavailable
        (``FaultSpec(f"tier.{tier}", 0, "down")``).  Consulted by
        ``mem.offload.effective_tier`` when walking the degradation
        ladder; consultations are recorded as notes, not ticks."""
        down = self.has(f"tier.{tier}", "down")
        if down:
            self.note("tier.disabled", tier)
        return down

    # -- bookkeeping ---------------------------------------------------------
    def note(self, kind: str, data: Any) -> None:
        with self._lock:
            self._notes.append((kind, data))

    def fired(self, site: str | None = None) -> List[Tuple[str, int, FaultSpec]]:
        with self._lock:
            return [f for f in self._fired if site is None or f[0] == site]

    def fired_count(self, site: str | None = None,
                    kind: str | None = None) -> int:
        return sum(1 for s, _, spec in self.fired(site)
                   if kind is None or spec.kind == kind)

    def notes(self, kind: str | None = None) -> List[Tuple[str, Any]]:
        with self._lock:
            return [n for n in self._notes if kind is None or n[0] == kind]

    def reset(self) -> None:
        """Rewind call counters and the fired/notes logs (the plan's specs
        are immutable) — e.g. between a compile/warmup run and the
        measured run."""
        with self._lock:
            self._calls.clear()
            self._fired.clear()
            self._notes.clear()

    # -- deterministic corruption -------------------------------------------
    def corrupt_arrays(self, arrs: Sequence[np.ndarray],
                       salt: int) -> List[np.ndarray]:
        """Return corrupted copies of ``arrs``: every byte XOR'd with a
        stream from a ``(seed, salt)``-keyed generator — random-looking,
        bit-level, and exactly reproducible.  All-zero payloads corrupt
        too (XOR with a nonzero stream), so a checksum over the clean
        bytes always detects it."""
        rng = np.random.default_rng((self.seed, int(salt) & 0x7FFFFFFF))
        out = []
        for a in arrs:
            a = np.asarray(a)
            raw = a.tobytes()
            noise = rng.integers(1, 256, size=max(len(raw), 1),
                                 dtype=np.uint8)
            bad = (np.frombuffer(raw, np.uint8) ^ noise[:len(raw)]) \
                if raw else np.frombuffer(raw, np.uint8)
            out.append(np.frombuffer(bad.tobytes(), a.dtype)
                       .reshape(a.shape).copy())
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"FaultPlan(seed={self.seed}, faults={list(self.faults)}, "
                f"fired={len(self._fired)})")
