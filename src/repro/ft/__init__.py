from repro.ft.inject import FaultPlan, FaultSpec, SimulatedPreemption
from repro.ft.watchdog import (Heartbeat, StragglerDetector, TrainSupervisor,
                               elastic_remesh_plan)

__all__ = ["FaultPlan", "FaultSpec", "SimulatedPreemption",
           "Heartbeat", "StragglerDetector", "TrainSupervisor",
           "elastic_remesh_plan"]
