from repro.ft.watchdog import (Heartbeat, StragglerDetector, TrainSupervisor,
                               elastic_remesh_plan)

__all__ = ["Heartbeat", "StragglerDetector", "TrainSupervisor",
           "elastic_remesh_plan"]
