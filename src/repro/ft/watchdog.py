"""Fault tolerance for long multi-pod runs.

Three pieces, composed by ``TrainSupervisor`` (used in launch/train.py):

* ``Heartbeat`` — a watchdog thread that fires a callback if the training
  loop fails to check in within ``timeout_s``.  On a real cluster the
  callback escalates (kill the stuck step, checkpoint-restart the job); on
  this runtime it records the stall and raises in the loop thread.

* ``StragglerDetector`` — robust per-step timing statistics (median + MAD).
  A step slower than ``median + k*MAD`` (and over an absolute floor) is
  flagged.  The mitigation hook is pluggable: the default logs and, after
  ``evict_after`` consecutive flags, requests an elastic re-mesh (on real
  hardware: evict the slow host, shrink 'data').

* ``elastic_remesh_plan`` — given a failed/evicted device count, returns the
  largest (data, model) mesh that keeps the model axis intact (TP degree is
  load-bearing for memory; the data axis absorbs the loss).  A checkpoint
  written under the old mesh restores onto the new one via
  ``ckpt.load_checkpoint(..., shardings=new)`` — global arrays, new
  placement — so elastic shrink/grow is restore + continue.

Recovery invariant (tested): deterministic data (``data/pipeline.py`` keys
batches by step) + checkpointed (params, opt_state, step) means a restarted
job replays losses bit-identically from the restore point.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np


class Heartbeat:
    """Watchdog: ``beat()`` every step; if no beat for ``timeout_s`` the
    ``on_stall`` callback fires (once per stall)."""

    def __init__(self, timeout_s: float = 300.0,
                 on_stall: Optional[Callable[[float], None]] = None,
                 poll_s: float = 1.0):
        self.timeout_s = timeout_s
        self.on_stall = on_stall or (lambda age: None)
        self.poll_s = poll_s
        # _last/_stalled are touched by the loop thread (beat) and the
        # watchdog thread (_run) concurrently — lock both, so a beat
        # racing the poll can't leave _stalled latched after a fresh beat
        self._lock = threading.Lock()
        self._last = time.monotonic()
        self._stalled = False
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.stall_count = 0

    def start(self) -> "Heartbeat":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def beat(self) -> None:
        with self._lock:
            self._last = time.monotonic()
            self._stalled = False

    def _run(self) -> None:
        while not self._stop.wait(self.poll_s):
            fire = False
            with self._lock:
                age = time.monotonic() - self._last
                if age > self.timeout_s and not self._stalled:
                    self._stalled = True
                    self.stall_count += 1
                    fire = True
            if fire:  # callback outside the lock: it may call beat()
                self.on_stall(age)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)


@dataclasses.dataclass
class StragglerDetector:
    """Flags steps whose wall time exceeds median + k*MAD of the trailing
    window (robust to the compile-time spike of step 0)."""
    window: int = 50
    k_mad: float = 6.0
    min_abs_s: float = 0.05
    warmup: int = 3

    def __post_init__(self):
        self._times: list[float] = []
        self.flagged_steps: list[int] = []
        self._step = 0

    def record(self, dt_s: float) -> bool:
        """Record one step time; returns True if it is a straggler."""
        self._step += 1
        is_straggler = False
        if len(self._times) >= self.warmup:
            med = float(np.median(self._times))
            mad = float(np.median(np.abs(np.array(self._times) - med)))
            thresh = med + self.k_mad * max(mad, 0.01 * med)
            if dt_s > max(thresh, self.min_abs_s):
                is_straggler = True
                self.flagged_steps.append(self._step)
        # straggler samples pollute the baseline — exclude them
        if not is_straggler:
            self._times.append(dt_s)
            if len(self._times) > self.window:
                self._times.pop(0)
        return is_straggler

    @property
    def median_s(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


def elastic_remesh_plan(n_devices: int, model_axis: int,
                        lost: int = 0) -> tuple[int, int]:
    """Largest (data, model) mesh on ``n_devices - lost`` devices keeping
    the model axis fixed.  Returns (data, model); raises if even data=1
    does not fit."""
    avail = n_devices - lost
    if avail < model_axis:
        raise RuntimeError(
            f"cannot re-mesh: {avail} devices < model axis {model_axis}")
    data = avail // model_axis
    return data, model_axis


class TrainSupervisor:
    """Composes heartbeat + straggler detection around a step function and
    drives checkpoint-restart.  See launch/train.py for the integration."""

    def __init__(self, *, heartbeat_timeout_s: float = 600.0,
                 straggler: Optional[StragglerDetector] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.straggler = straggler or StragglerDetector()
        self.on_straggler = on_straggler or (lambda step, dt: None)
        self.stall_event = threading.Event()
        self.heartbeat = Heartbeat(
            timeout_s=heartbeat_timeout_s,
            on_stall=lambda age: self.stall_event.set())
        self.step_times: list[float] = []

    def __enter__(self) -> "TrainSupervisor":
        self.heartbeat.start()
        return self

    def __exit__(self, *exc) -> None:
        self.heartbeat.stop()

    def step(self, fn: Callable[[], None], step_idx: int) -> float:
        """Run one training step under supervision; returns its wall time."""
        if self.stall_event.is_set():
            raise TimeoutError(
                f"heartbeat watchdog fired before step {step_idx}")
        t0 = time.monotonic()
        fn()
        dt = time.monotonic() - t0
        # re-check AFTER fn() too: a stall during the final step of a run
        # would otherwise go unreported forever (no next step to notice)
        if self.stall_event.is_set():
            raise TimeoutError(
                f"heartbeat watchdog fired during step {step_idx} "
                f"({dt:.1f}s elapsed, timeout "
                f"{self.heartbeat.timeout_s:.0f}s)")
        self.heartbeat.beat()
        self.step_times.append(dt)
        if self.straggler.record(dt):
            self.on_straggler(step_idx, dt)
        return dt
