"""Minimal functional NN substrate (flax/optax are not available offline).

Convention: every module is a pair of functions
    init_<mod>(key, ...) -> params (dict pytree)
    <mod>(params, x, ...) -> y
Parameters carry a parallel "spec tree" (see dist/sharding.py) mapping each
leaf to logical axis names for FSDP/TP sharding.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    s = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    return {"w": (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)}


def dense(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embedding(params: Params, ids: jax.Array) -> jax.Array:
    return jnp.take(params["table"], ids, axis=0)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "tanh": jnp.tanh}[name]


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def glu_mlp_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * s_out).astype(dtype),
    }


def glu_mlp(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    g = act_fn(act)(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_up"].astype(x.dtype)
    return (g * u) @ params["w_down"].astype(x.dtype)


def mlp_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": (jax.random.normal(k1, (d, d_ff)) / jnp.sqrt(d)).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d)) / jnp.sqrt(d_ff)).astype(dtype),
    }


def mlp(params: Params, x: jax.Array, act: str = "gelu") -> jax.Array:
    return act_fn(act)(x @ params["w_in"].astype(x.dtype)) @ params["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) int. Rotates pairs (even, odd)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)
