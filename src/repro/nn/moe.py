"""Top-k token-choice MoE with GShard-style grouped einsum dispatch.

Tokens are split into contiguous *groups* (aligned with the data-parallel
sharding), routed to their top-k experts with a per-group capacity buffer
(``capacity_factor * k * group_size / n_experts`` slots), and dispatched /
combined with einsums against a (G, S, E, C) mask — the formulation GSPMD
can partition: the contraction over the group-local token dim never crosses
shards, so dispatch lowers to expert all-to-alls instead of global
(T*k, D) all-reduces (the scatter-based formulation measured 73% of all
collective bytes on the dbrx prefill_32k dry-run before this rewrite).

The (E, C, D) expert buffers put E on the 'model' axis (expert parallelism)
when E divides it, with groups on 'data'.  Overflowing tokens are dropped
per group (standard GShard behavior); the router uses softmax-then-top-k
with normalized weights (mixtral/dbrx convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import act_fn


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32):
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in, s_out = 1.0 / jnp.sqrt(d_model), 1.0 / jnp.sqrt(d_ff)
    return {
        "w_router": (jax.random.normal(kr, (d_model, n_experts)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(kg, (n_experts, d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (n_experts, d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (n_experts, d_ff, d_model)) * s_out).astype(dtype),
    }


def _group_size(t: int, requested: int) -> int:
    g = min(requested, t)
    while t % g:
        g -= 1
    return g


def moe_block(params, x: jax.Array, *, n_experts: int, top_k: int,
              act: str = "silu", capacity_factor: float = 1.25,
              group_size: int = 1024):
    """x: (B, S, D) -> (B, S, D), plus aux load-balancing loss."""
    from repro.dist.sharding import constrain_dims

    b, s, d = x.shape
    t = b * s
    e = n_experts
    xf = x.reshape(t, d)

    # --- routing
    logits = xf.astype(jnp.float32) @ params["w_router"]       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0) / (t * top_k)
    aux_loss = e * jnp.sum(me * ce)

    # --- per-group capacity assignment (k-major-in-token order)
    g_sz = _group_size(t, group_size)
    g = t // g_sz
    cap = int(max(top_k, capacity_factor * top_k * g_sz / e))

    cdt = x.dtype
    idx_g = gate_idx.reshape(g, g_sz * top_k)                  # (G, S*K)
    w_g = gate_vals.reshape(g, g_sz * top_k)
    # integer cumsum + narrow mask dtype: the (G, SK, E, C) masks are the
    # largest transients of the block (10+ GiB/device in f32 at 64k
    # tokens/device); int32 position math + compute-dtype masks keep them
    # within the HBM budget
    oh_i = jax.nn.one_hot(idx_g, e, dtype=jnp.int32)           # (G, SK, E)
    pos = jnp.cumsum(oh_i, axis=1) - oh_i
    pos_of = jnp.sum(pos * oh_i, axis=-1)                      # (G, SK)
    keep = pos_of < cap

    # dispatch mask (G, SK, E, C); fold the K slots back into tokens
    cap_oh = jax.nn.one_hot(pos_of, cap, dtype=cdt)            # (G, SK, C)
    oh = jnp.where(keep[..., None], oh_i, 0).astype(cdt)
    dm = oh[..., None] * cap_oh[:, :, None, :]
    dm = dm.reshape(g, g_sz, top_k, e, cap)
    combine = jnp.sum(dm * w_g.reshape(g, g_sz, top_k, 1, 1).astype(cdt),
                      axis=2)
    dispatch = jnp.sum(dm, axis=2)                             # (G, S, E, C)
    xg = xf.reshape(g, g_sz, d)
    # (G,S,E,C) x (G,S,D) -> (G,E,C,D): contraction is group-local; GSPMD
    # turns the G:data / E:model mismatch into the EP all-to-all.  When E
    # doesn't divide the model axis (mixtral 8e on 16) the experts run
    # TP-within-expert instead: pin the d_ff dim of the (G,E,C,F)
    # intermediates to 'model' — otherwise the w_down contraction all-
    # gathers the full F=14336 activations (measured ~50% of mixtral
    # train_4k collective bytes).
    pin_ecd = {0: "data", 1: "model"}
    pin_ecf = dict(pin_ecd)
    pin_ecf[3] = "model"  # constrain_dims drops non-divisible pins itself
    buf = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    buf = constrain_dims(buf, pin_ecd)

    gg = act_fn(act)(jnp.einsum("gecd,edf->gecf", buf,
                                params["w_gate"].astype(cdt)))
    uu = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(cdt))
    gg = constrain_dims(gg, pin_ecf)
    uu = constrain_dims(uu, pin_ecf)
    y = jnp.einsum("gecf,efd->gecd", gg * uu, params["w_down"].astype(cdt))
    y = constrain_dims(y, pin_ecd)

    out = jnp.einsum("gsec,gecd->gsd", combine, y)
    return out.reshape(b, s, d), aux_loss
