"""Composable transformer blocks for every assigned family, built for
homogeneous `lax.scan` over depth with the PNODE checkpointing policies.

A "layer" is (sequence-mix, channel-mix) with pre-norms and residuals:
  kind 'a' : GQA attention (per-layer sliding window scalar) + GLU-MLP / MoE
  kind 'w' : RWKV6 time-mix + RWKV channel-mix
  kind 'r' : RG-LRU recurrent block + GLU-MLP

Heterogeneous stacks (recurrentgemma's r,r,a pattern) scan over *pattern
units*; the remainder layers are unrolled.  Per-layer sliding windows ride
along the scan as an int array, so gemma3's 5:1 local:global stays one scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.depth_ode import checkpointed_scan
from repro.nn import attention as attn_mod
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod
from repro.nn.layers import (glu_mlp, glu_mlp_init, layernorm, layernorm_init,
                             rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


def _norm_init(cfg: ModelConfig):
    return layernorm_init(cfg.d_model) if cfg.norm == "layernorm" \
        else rmsnorm_init(cfg.d_model)


def _norm(cfg: ModelConfig, p, x):
    return layernorm(p, x) if cfg.norm == "layernorm" else rmsnorm(p, x)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# per-kind layer init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str, cross: bool = False) -> Params:
    dt = _pdtype(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": _norm_init(cfg), "norm2": _norm_init(cfg)}
    if kind == "a":
        p["attn"] = attn_mod.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh, dt)
        if cfg.n_experts:
            p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.n_experts, dt)
        else:
            p["mlp"] = glu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt)
        if cross:
            p["norm_x"] = _norm_init(cfg)
            p["xattn"] = attn_mod.init_attention(
                ks[2], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh, dt)
    elif kind == "w":
        p["tmix"] = ssm_mod.init_rwkv6(ks[0], cfg.d_model, cfg.n_heads, dt)
        p["cmix"] = ssm_mod.init_rwkv_channel_mix(ks[1], cfg.d_model,
                                                  cfg.d_ff, dt)
    elif kind == "r":
        p["rglru"] = ssm_mod.init_rglru_block(ks[0], cfg.d_model,
                                              cfg.d_rnn or cfg.d_model,
                                              dtype=dt)
        p["mlp"] = glu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt)
    else:
        raise ValueError(kind)
    return p


# ---------------------------------------------------------------------------
# per-kind layer apply (full-sequence / training)
# ---------------------------------------------------------------------------

def apply_layer(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                window, *, enc_out=None, causal: bool = True):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "a":
        h = _norm(cfg, p["norm1"], x)
        x = x + attn_mod.attention_block(
            p["attn"], h, n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
            causal=causal, window=window, impl=cfg.attn_impl)
        if enc_out is not None:
            hx = _norm(cfg, p["norm_x"], x)
            x = x + attn_mod.attention_block(
                p["xattn"], hx, n_heads=cfg.n_heads, rope_theta=0.0,
                causal=False, window=0, impl=cfg.attn_impl, kv_x=enc_out)
        h = _norm(cfg, p["norm2"], x)
        if cfg.n_experts:
            y, aux = moe_mod.moe_block(
                p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                act=cfg.act, capacity_factor=cfg.capacity_factor)
            x = x + y
        else:
            x = x + glu_mlp(p["mlp"], h, cfg.act)
    elif kind == "w":
        h = _norm(cfg, p["norm1"], x)
        if x.shape[1] > 256:
            y, _ = ssm_mod.rwkv6_mix_chunked(p["tmix"], h, cfg.n_heads)
        else:
            y, _ = ssm_mod.rwkv6_mix_scan(p["tmix"], h, cfg.n_heads)
        x = x + y
        h = _norm(cfg, p["norm2"], x)
        x = x + ssm_mod.rwkv_channel_mix(p["cmix"], h)
    elif kind == "r":
        h = _norm(cfg, p["norm1"], x)
        y, _ = ssm_mod.rglru_block(p["rglru"], h)
        x = x + y
        h = _norm(cfg, p["norm2"], x)
        x = x + glu_mlp(p["mlp"], h, cfg.act)
    else:
        raise ValueError(kind)
    return x, aux


# ---------------------------------------------------------------------------
# stack grouping: (scan groups, unrolled remainder)
# ---------------------------------------------------------------------------

def stack_plan(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """Returns (unit_kinds, n_units, remainder_kinds).  A 'unit' is the
    repeating pattern scanned over; remainder layers are unrolled.

    Periodicity is detected over (kind, window) PAIRS, not kinds alone, so a
    homogeneous-kind stack with a repeating window pattern (gemma3's
    5-local:1-global) scans a 6-layer unit whose windows are *static* —
    enabling trace-time sliding-window k-block skipping in attention."""
    kinds = cfg.kinds
    sig = tuple(zip(kinds, cfg.win))
    uniq = tuple(sorted(set(sig)))
    if len(uniq) == 1:
        return (kinds[0],), len(kinds), ()
    # find the shortest repeating pattern unit covering every distinct layer
    for ulen in range(2, len(sig) + 1):
        unit = sig[:ulen]
        n_units = len(sig) // ulen
        if unit * n_units == sig[:ulen * n_units] \
                and len(set(unit)) == len(uniq):
            rem = kinds[ulen * n_units:]
            return tuple(k for k, _ in unit), n_units, rem
    return tuple(kinds), 1, ()


def init_stack(key, cfg: ModelConfig, cross: bool = False) -> Params:
    unit, n_units, rem = stack_plan(cfg)
    keys = jax.random.split(key, n_units + len(rem))

    def unit_init(k):
        uks = jax.random.split(k, len(unit))
        return {f"{i}_{kind}": init_layer(uk, cfg, kind, cross)
                for i, (kind, uk) in enumerate(zip(unit, uks))}

    stacked = jax.vmap(unit_init)(keys[:n_units])
    rem_p = {f"rem{i}_{kind}": init_layer(keys[n_units + i], cfg, kind, cross)
             for i, kind in enumerate(rem)}
    return {"scan": stacked, "rem": rem_p}


def _unit_windows(cfg: ModelConfig):
    """Per-unit window arrays (n_units, ulen) + remainder windows.

    When every unit has the same window pattern (gemma3 5:1, mixtral SWA,
    recurrentgemma 1:2 — i.e. all assigned heterogenous stacks), the windows
    are returned as a STATIC python tuple instead of a scanned array: static
    windows let the chunked-attention path skip k-blocks outside the sliding
    window at trace time (16x less attention work for a 1024-window layer at
    4k context) instead of merely masking them."""
    unit, n_units, rem = stack_plan(cfg)
    ulen = len(unit)
    rows = [tuple(cfg.win[u * ulen:(u + 1) * ulen]) for u in range(n_units)]
    w_rem = tuple(cfg.win[ulen * n_units:])
    if all(r == rows[0] for r in rows):
        return rows[0] if rows else (), w_rem     # static pattern
    w = jnp.asarray(cfg.win[:ulen * n_units], jnp.int32).reshape(n_units, ulen)
    return w, w_rem


def apply_stack(cfg: ModelConfig, params: Params, x: jax.Array, *,
                enc_out=None, causal: bool = True):
    """Run the full depth stack with the configured PNODE remat policy.
    Returns (x, aux_loss_sum)."""
    unit, n_units, rem = stack_plan(cfg)
    w_scan, w_rem = _unit_windows(cfg)

    from repro.dist.sharding import constrain_batch

    static_w = isinstance(w_scan, tuple)

    def unit_fn(carry, scanned):
        xx, aux = carry
        up = scanned[0] if not static_w else scanned
        wins = w_scan if static_w else scanned[1]
        for i, kind in enumerate(unit):
            xx, a = apply_layer(cfg, kind, up[f"{i}_{kind}"], xx, wins[i],
                                enc_out=enc_out, causal=causal)
            aux = aux + a
        # keep activations batch-sharded at every layer boundary (else GSPMD
        # may replicate them to satisfy FSDP weight shards; see dist/sharding)
        xx = constrain_batch(xx)
        return xx, aux

    carry0 = (constrain_batch(x), jnp.zeros((), jnp.float32))
    scanned_in = params["scan"] if static_w else (params["scan"], w_scan)
    out = checkpointed_scan(unit_fn, carry0, scanned_in,
                            n_units, remat=cfg.remat, ncheck=cfg.ncheck)
    x, aux = out
    for i, kind in enumerate(rem):
        x, a = apply_layer(cfg, kind, params["rem"][f"rem{i}_{kind}"], x,
                           int(w_rem[i]),
                           enc_out=enc_out, causal=causal)
        aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# prefill (full prompt -> decode state), threading caches through the stack
# ---------------------------------------------------------------------------

def prefill_layer(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                  window, max_seq: int, *, enc_out=None):
    """Full-sequence layer pass that also returns the layer's decode state."""
    from repro.nn.layers import apply_rope
    b, s, _ = x.shape
    cache_dtype = jnp.dtype(cfg.compute_dtype)
    if kind == "a":
        h = _norm(cfg, p["norm1"], x)
        ap = p["attn"]
        q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, ap["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, ap["wv"].astype(h.dtype))
        pos = jnp.arange(s)[None, :]
        if cfg.rope_theta > 0:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        o = attn_mod.attention(q, k, v, causal=True, window=window,
                               impl=cfg.attn_impl)
        x = x + jnp.einsum("bshk,hkd->bsd", o, ap["wo"].astype(h.dtype))
        st = {
            "k": jnp.zeros((b, max_seq) + k.shape[2:], cache_dtype)
            .at[:, :s].set(k.astype(cache_dtype)),
            "v": jnp.zeros((b, max_seq) + v.shape[2:], cache_dtype)
            .at[:, :s].set(v.astype(cache_dtype)),
        }
        if enc_out is not None:
            hx = _norm(cfg, p["norm_x"], x)
            x = x + attn_mod.attention_block(
                p["xattn"], hx, n_heads=cfg.n_heads, rope_theta=0.0,
                causal=False, window=0, impl=cfg.attn_impl, kv_x=enc_out)
        h = _norm(cfg, p["norm2"], x)
        if cfg.n_experts:
            # inference is dropless: capacity covers the all-tokens-to-one-
            # expert worst case so prefill == decode == (dropless) forward
            y, _ = moe_mod.moe_block(
                p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                act=cfg.act, capacity_factor=max(cfg.capacity_factor,
                                                 float(cfg.n_experts)))
            x = x + y
        else:
            x = x + glu_mlp(p["mlp"], h, cfg.act)
        return x, st
    if kind == "w":
        h = _norm(cfg, p["norm1"], x)
        y, S = (ssm_mod.rwkv6_mix_chunked if s > 256
                else ssm_mod.rwkv6_mix_scan)(p["tmix"], h, cfg.n_heads)
        x = x + y
        h2 = _norm(cfg, p["norm2"], x)
        x = x + ssm_mod.rwkv_channel_mix(p["cmix"], h2)
        st = {"S": S, "tm_prev": h[:, -1:].astype(cache_dtype),
              "cm_prev": h2[:, -1:].astype(cache_dtype)}
        return x, st
    if kind == "r":
        h = _norm(cfg, p["norm1"], x)
        gate = jax.nn.gelu(h @ p["rglru"]["w_in_gate"].astype(h.dtype))
        z = h @ p["rglru"]["w_in_rnn"].astype(h.dtype)
        zc = ssm_mod._causal_conv1d(z, p["rglru"]["conv_w"].astype(z.dtype))
        hseq, h_last = ssm_mod.rglru(p["rglru"], zc)
        x = x + (gate * hseq) @ p["rglru"]["w_out"].astype(h.dtype)
        h2 = _norm(cfg, p["norm2"], x)
        x = x + glu_mlp(p["mlp"], h2, cfg.act)
        st = {"h": h_last, "conv": z[:, -3:].astype(cache_dtype)}
        return x, st
    raise ValueError(kind)


def prefill_stack(cfg: ModelConfig, params: Params, x: jax.Array,
                  max_seq: int, *, enc_out=None):
    """Plain scan (no remat — inference) producing hidden states + decode
    state for every layer."""
    unit, n_units, rem = stack_plan(cfg)
    w_scan, w_rem = _unit_windows(cfg)

    from repro.dist.sharding import constrain_batch

    static_w = isinstance(w_scan, tuple)

    def unit_fn(xx, scanned):
        up = scanned[0] if not static_w else scanned
        wins = w_scan if static_w else scanned[1]
        sts = {}
        for i, kind in enumerate(unit):
            xx, st = prefill_layer(cfg, kind, up[f"{i}_{kind}"], xx, wins[i],
                                   max_seq, enc_out=enc_out)
            sts[f"{i}_{kind}"] = st
        return constrain_batch(xx), sts

    x, scan_state = jax.lax.scan(
        unit_fn, x, params["scan"] if static_w else (params["scan"], w_scan))
    rem_state = {}
    for i, kind in enumerate(rem):
        key = f"rem{i}_{kind}"
        x, st = prefill_layer(cfg, kind, params["rem"][key], x,
                              int(w_rem[i]), max_seq,
                              enc_out=enc_out)
        rem_state[key] = st
    return x, {"scan": scan_state, "rem": rem_state}


# ---------------------------------------------------------------------------
# decode (single token, stateful)
# ---------------------------------------------------------------------------

def init_layer_state(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     cross: bool = False):
    dh = cfg.dh
    cache_dtype = jnp.dtype(cfg.compute_dtype)
    if kind == "a":
        st = {"k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, dh), cache_dtype),
              "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, dh), cache_dtype)}
        if cross:
            st["xk"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, dh),
                                 cache_dtype)
            st["xv"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, dh),
                                 cache_dtype)
        return st
    if kind == "w":
        return {
            "S": jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
            "tm_prev": jnp.zeros((batch, 1, cfg.d_model), cache_dtype),
            "cm_prev": jnp.zeros((batch, 1, cfg.d_model), cache_dtype),
        }
    if kind == "r":
        dr = cfg.d_rnn or cfg.d_model
        return {"h": jnp.zeros((batch, dr), jnp.float32),
                "conv": jnp.zeros((batch, 3, dr), cache_dtype)}
    raise ValueError(kind)


def decode_layer(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                 state, pos, window, *, enc_out=None):
    """One-token decode through a single layer.  x: (B,1,D)."""
    if kind == "a":
        h = _norm(cfg, p["norm1"], x)
        y, ck, cv = attn_mod.decode_attention_block(
            p["attn"], h, state["k"], state["v"], pos,
            n_heads=cfg.n_heads, rope_theta=cfg.rope_theta, window=window)
        state = dict(state, k=ck, v=cv)
        x = x + y
        if enc_out is not None:
            hx = _norm(cfg, p["norm_x"], x)
            y = attn_mod.attention_block(
                p["xattn"], hx, n_heads=cfg.n_heads, rope_theta=0.0,
                causal=False, window=0, impl="naive", kv_x=enc_out)
            x = x + y
        h = _norm(cfg, p["norm2"], x)
        if cfg.n_experts:
            y, _ = moe_mod.moe_block(
                p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                act=cfg.act, capacity_factor=max(cfg.capacity_factor,
                                                 float(cfg.n_experts)))
            x = x + y
        else:
            x = x + glu_mlp(p["mlp"], h, cfg.act)
        return x, state
    if kind == "w":
        h = _norm(cfg, p["norm1"], x)
        y, S = ssm_mod.rwkv6_mix_decode(p["tmix"], state["tm_prev"], h,
                                        state["S"], cfg.n_heads)
        x = x + y
        new_tm = h.astype(state["tm_prev"].dtype)
        h2 = _norm(cfg, p["norm2"], x)
        hh2 = jnp.concatenate([state["cm_prev"].astype(h2.dtype), h2], axis=1)
        y2 = ssm_mod.rwkv_channel_mix(p["cmix"], hh2)[:, 1:]
        x = x + y2
        state = dict(state, S=S, tm_prev=new_tm,
                     cm_prev=h2.astype(state["cm_prev"].dtype))
        return x, state
    if kind == "r":
        h = _norm(cfg, p["norm1"], x)
        gate = jax.nn.gelu(h @ p["rglru"]["w_in_gate"].astype(h.dtype))
        z = h @ p["rglru"]["w_in_rnn"].astype(h.dtype)
        zw = jnp.concatenate([state["conv"].astype(z.dtype), z], axis=1)
        z = ssm_mod._causal_conv1d(zw, p["rglru"]["conv_w"].astype(z.dtype))[:, -1:]
        hseq, h_last = ssm_mod.rglru(p["rglru"], z, state["h"])
        y = (gate * hseq) @ p["rglru"]["w_out"].astype(h.dtype)
        x = x + y
        h2 = _norm(cfg, p["norm2"], x)
        x = x + glu_mlp(p["mlp"], h2, cfg.act)
        state = dict(state, h=h_last,
                     conv=zw[:, 1:].astype(state["conv"].dtype))
        return x, state
    raise ValueError(kind)


def init_stack_state(cfg: ModelConfig, batch: int, max_seq: int,
                     cross: bool = False):
    unit, n_units, rem = stack_plan(cfg)

    def unit_state(_):
        return {f"{i}_{kind}": init_layer_state(cfg, kind, batch, max_seq, cross)
                for i, kind in enumerate(unit)}

    scan_state = jax.vmap(unit_state)(jnp.arange(n_units))
    rem_state = {f"rem{i}_{kind}": init_layer_state(cfg, kind, batch, max_seq,
                                                    cross)
                 for i, kind in enumerate(rem)}
    return {"scan": scan_state, "rem": rem_state}


def decode_stack(cfg: ModelConfig, params: Params, state, x: jax.Array,
                 pos, *, enc_out=None):
    unit, n_units, rem = stack_plan(cfg)
    w_scan, w_rem = _unit_windows(cfg)

    from repro.dist.sharding import constrain_batch

    static_w = isinstance(w_scan, tuple)

    def unit_fn(carry, scanned):
        xx = carry
        if static_w:
            up, ust = scanned
            wins = w_scan
        else:
            up, ust, wins = scanned
        new_st = {}
        for i, kind in enumerate(unit):
            xx, st = decode_layer(cfg, kind, up[f"{i}_{kind}"], xx,
                                  ust[f"{i}_{kind}"], pos, wins[i],
                                  enc_out=enc_out)
            new_st[f"{i}_{kind}"] = st
        return constrain_batch(xx), new_st

    x, scan_state = jax.lax.scan(
        unit_fn, x,
        (params["scan"], state["scan"]) if static_w
        else (params["scan"], state["scan"], w_scan))
    rem_state = {}
    for i, kind in enumerate(rem):
        key = f"rem{i}_{kind}"
        x, st = decode_layer(cfg, kind, params["rem"][key], x,
                             state["rem"][key], pos,
                             int(w_rem[i]), enc_out=enc_out)
        rem_state[key] = st
    return x, {"scan": scan_state, "rem": rem_state}
