"""Attention-free sequence mixers: RWKV6 (Finch) time-mix and RG-LRU
(recurrentgemma), in scan, chunked, and associative-scan forms.

RWKV6 recurrence (per head, dk key channels, dv value channels):

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t          (data-dependent decay w_t)
    o_t = r_t @ S_{t-1} + (r_t . (u . k_t)) v_t     (u: per-channel bonus)

Training uses the *chunked* form (intra-chunk product-form attention with a
per-channel midpoint renormalization + inter-chunk state propagation) so the
MXU sees dense matmuls instead of a length-S scan; the Pallas kernel
(kernels/rwkv6_scan.py) implements the same algorithm with VMEM tiling, and
the sequential scan here is the oracle.

RG-LRU:  h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . x_t), with
a_t = exp(-c * softplus(lam) * sigmoid(W_a x_t)) — a diagonal linear
recurrence, evaluated with `jax.lax.associative_scan` (log-depth on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import act_fn


# ---------------------------------------------------------------------------
# RWKV6 time-mix
# ---------------------------------------------------------------------------

def init_rwkv6(key, d_model: int, n_heads: int, dtype=jnp.float32):
    dh = d_model // n_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / jnp.sqrt(d_model)

    def proj(k):
        return (jax.random.normal(k, (d_model, d_model)) * s).astype(dtype)

    return {
        "w_r": proj(ks[0]), "w_k": proj(ks[1]), "w_v": proj(ks[2]),
        "w_g": proj(ks[3]), "w_o": proj(ks[4]),
        # data-dependent decay: w_t = exp(-exp(w_base + x @ w_lora))
        "w_base": (jnp.zeros((d_model,)) - 0.5).astype(jnp.float32),
        "w_lora": (jax.random.normal(ks[5], (d_model, d_model)) * s * 0.1).astype(dtype),
        "u_bonus": (jax.random.normal(ks[6], (n_heads, dh)) * 0.1).astype(jnp.float32),
        "mix": (0.5 * jnp.ones((5, d_model))).astype(jnp.float32),  # r,k,v,g,w shifts
        "ln_scale": jnp.ones((n_heads, dh), jnp.float32),
    }


def _token_shift(x):
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv6_projections(params, x: jax.Array, n_heads: int):
    """Shared projection code: returns r, k, v, g (B,S,H,dh) and logw (B,S,H,dh)."""
    b, s, d = x.shape
    dh = d // n_heads
    xs = _token_shift(x)
    mix = params["mix"].astype(x.dtype)
    xr = x + (xs - x) * mix[0]
    xk = x + (xs - x) * mix[1]
    xv = x + (xs - x) * mix[2]
    xg = x + (xs - x) * mix[3]
    xw = x + (xs - x) * mix[4]
    r = (xr @ params["w_r"].astype(x.dtype)).reshape(b, s, n_heads, dh)
    k = (xk @ params["w_k"].astype(x.dtype)).reshape(b, s, n_heads, dh)
    v = (xv @ params["w_v"].astype(x.dtype)).reshape(b, s, n_heads, dh)
    g = xg @ params["w_g"].astype(x.dtype)
    # data-dependent decay (Finch): log w_t in (-inf, 0)
    dd = (xw @ params["w_lora"].astype(x.dtype)).astype(jnp.float32)
    logw = -jnp.exp(params["w_base"] + dd)            # (B,S,D) fp32, < 0
    logw = logw.reshape(b, s, n_heads, dh)
    return r, k, v, g, logw


def rwkv6_mix_scan(params, x: jax.Array, n_heads: int,
                   state: jax.Array | None = None):
    """Sequential oracle.  x: (B,S,D).  state: (B,H,dk,dv) or None.
    Returns (y, new_state)."""
    b, s, d = x.shape
    dh = d // n_heads
    r, k, v, g, logw = rwkv6_projections(params, x, n_heads)
    u = params["u_bonus"]
    if state is None:
        state = jnp.zeros((b, n_heads, dh, dh), jnp.float32)

    def step(S, inp):
        rt, kt, vt, lw = inp     # (B,H,dh) each
        w = jnp.exp(lw)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, S) \
            + jnp.einsum("bhk,bhk,bhv->bhv", rt, u[None] * kt, vt)
        S_new = w[..., None] * S + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S_new, ot

    seq = (jnp.moveaxis(r.astype(jnp.float32), 1, 0),
           jnp.moveaxis(k.astype(jnp.float32), 1, 0),
           jnp.moveaxis(v.astype(jnp.float32), 1, 0),
           jnp.moveaxis(logw, 1, 0))
    state, outs = jax.lax.scan(step, state, seq)
    y = jnp.moveaxis(outs, 0, 1)                       # (B,S,H,dh)
    y = _rwkv_out(params, y, g, x.dtype, b, s, d)
    return y, state


def _rwkv_out(params, y, g, dtype, b, s, d):
    # per-head groupnorm, silu gate, output proj
    mu = y.mean(axis=-1, keepdims=True)
    var = y.var(axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5) * params["ln_scale"][None, None]
    y = y.reshape(b, s, d).astype(dtype) * jax.nn.silu(g)
    return y @ params["w_o"].astype(dtype)


def rwkv6_mix_chunked(params, x: jax.Array, n_heads: int,
                      state: jax.Array | None = None, chunk: int = 64):
    """Chunked-parallel form (matches the scan oracle; see module docstring)."""
    b, s, d = x.shape
    dh = d // n_heads
    r, k, v, g, logw = rwkv6_projections(params, x, n_heads)
    u = params["u_bonus"]
    if state is None:
        state = jnp.zeros((b, n_heads, dh, dh), jnp.float32)

    c = min(chunk, s)
    if s % c != 0:
        pad = c - s % c
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = r.shape[1] // c

    def resh(t):
        return jnp.moveaxis(
            t.reshape(b, nc, c, n_heads, dh).astype(jnp.float32), 1, 0)

    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(logw)

    def chunk_step(S, inp):
        rt, kt, vt, lw = inp                     # (B, C, H, dh)
        cum = jnp.cumsum(lw, axis=1)             # inclusive cumulative log-decay
        cum_prev = cum - lw                      # exclusive
        total = cum[:, -1:]                      # (B,1,H,dh)
        mid = cum[:, c // 2][:, None]            # midpoint renormalizer
        q_in = rt * jnp.exp(cum_prev)            # decay from chunk start (<=1)
        q_mid = rt * jnp.exp(cum_prev - mid)
        k_mid = kt * jnp.exp(mid - cum)
        k_out = kt * jnp.exp(total - cum)        # decay to chunk end (<=1)
        # inter-chunk: state contribution
        o_inter = jnp.einsum("bchk,bhkv->bchv", q_in, S)
        # intra-chunk: strictly-lower-triangular attention + u-bonus diagonal
        att = jnp.einsum("bqhk,bshk->bhqs", q_mid, k_mid)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        o_intra = jnp.einsum("bhqs,bshv->bqhv", att, vt)
        o_diag = jnp.einsum("bchk,bchk,bchv->bchv", rt, u[None, None] * kt, vt)
        # state update
        S_new = jnp.exp(total[:, 0])[..., None] * S + \
            jnp.einsum("bchk,bchv->bhkv", k_out, vt)
        return S_new, o_inter + o_intra + o_diag

    state, outs = jax.lax.scan(chunk_step, state, (rc, kc, vc, lwc))
    y = jnp.moveaxis(outs, 0, 1).reshape(b, nc * c, n_heads, dh)[:, :s]
    y = _rwkv_out(params, y, g, x.dtype, b, s, d)
    return y, state


def rwkv6_mix_decode(params, h_prev: jax.Array, h_cur: jax.Array,
                     state: jax.Array, n_heads: int):
    """Single-token decode.  h_prev/h_cur: (B,1,D) *normed* inputs of the
    previous and current token (prev feeds the token-shift mixing only);
    state: (B,H,dk,dv).  Returns (y (B,1,D), new_state)."""
    b, _, d = h_cur.shape
    dh = d // n_heads
    hh = jnp.concatenate([h_prev.astype(h_cur.dtype), h_cur], axis=1)
    r, k, v, g, logw = rwkv6_projections(params, hh, n_heads)
    # only the current position (index 1); its token-shift saw h_prev
    rt = r[:, 1].astype(jnp.float32)
    kt = k[:, 1].astype(jnp.float32)
    vt = v[:, 1].astype(jnp.float32)
    lw = logw[:, 1]
    g = g[:, 1:]
    u = params["u_bonus"]
    ot = jnp.einsum("bhk,bhkv->bhv", rt, state) \
        + jnp.einsum("bhk,bhk,bhv->bhv", rt, u[None] * kt, vt)
    S_new = jnp.exp(lw)[..., None] * state \
        + jnp.einsum("bhk,bhv->bhkv", kt, vt)
    y = _rwkv_out(params, ot[:, None], g, h_cur.dtype, b, 1, d)
    return y, S_new


def init_rwkv_channel_mix(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    s = 1.0 / jnp.sqrt(d_model)
    return {
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * s).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d_model))
                  / jnp.sqrt(d_ff)).astype(dtype),
        "mix": (0.5 * jnp.ones((d_model,))).astype(jnp.float32),
    }


def rwkv_channel_mix(params, x: jax.Array):
    xs = _token_shift(x)
    xk = x + (xs - x) * params["mix"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ params["w_in"].astype(x.dtype)))
    return h @ params["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma)
# ---------------------------------------------------------------------------

def init_rglru_block(key, d_model: int, d_rnn: int, conv_width: int = 4,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    s = 1.0 / jnp.sqrt(d_model)
    return {
        "w_in_gate": (jax.random.normal(ks[0], (d_model, d_rnn)) * s).astype(dtype),
        "w_in_rnn": (jax.random.normal(ks[1], (d_model, d_rnn)) * s).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (d_rnn, d_model))
                  / jnp.sqrt(d_rnn)).astype(dtype),
        "conv_w": (jax.random.normal(ks[3], (conv_width, d_rnn)) * 0.1).astype(dtype),
        "w_a": (jax.random.normal(ks[4], (d_rnn, d_rnn)) * (1.0 / jnp.sqrt(d_rnn)) * 0.1).astype(dtype),
        "w_i": (jax.random.normal(ks[5], (d_rnn, d_rnn)) * (1.0 / jnp.sqrt(d_rnn)) * 0.1).astype(dtype),
        "lam": jnp.full((d_rnn,), 0.6, jnp.float32),  # softplus param of decay
    }


def _causal_conv1d(x, w):
    """x: (B,S,D); w: (W,D) depthwise causal conv."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(width))
    return out


def rglru(params, z: jax.Array, h0: jax.Array | None = None, c: float = 8.0):
    """Diagonal gated linear recurrence via associative scan.
    z: (B,S,Dr).  Returns (y, h_last)."""
    b, s, dr = z.shape
    a_gate = jax.nn.sigmoid(z @ params["w_a"].astype(z.dtype)).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(z @ params["w_i"].astype(z.dtype)).astype(jnp.float32)
    log_a = -c * jax.nn.softplus(params["lam"]) * a_gate    # (B,S,Dr) < 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i_gate \
        * z.astype(jnp.float32)
    if h0 is not None:
        # fold the carry into the first element
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(z.dtype), h[:, -1]


def rglru_block(params, x: jax.Array, h0: jax.Array | None = None):
    """recurrentgemma recurrent block: gated branch x conv->RG-LRU branch."""
    gate = jax.nn.gelu(x @ params["w_in_gate"].astype(x.dtype))
    z = x @ params["w_in_rnn"].astype(x.dtype)
    z = _causal_conv1d(z, params["conv_w"].astype(x.dtype))
    h, h_last = rglru(params, z, h0)
    y = (gate * h) @ params["w_out"].astype(x.dtype)
    return y, h_last
