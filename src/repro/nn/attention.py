"""GQA attention: naive, chunked (flash-style online softmax in pure JAX),
and Pallas-kernel paths, plus KV-cache decode.

The chunked path is the TPU adaptation that keeps prefill memory O(S * block)
instead of O(S^2): queries are processed in blocks with a running
(max, sum, acc) online-softmax state — the same algorithm the Pallas kernel
implements with explicit VMEM tiling (kernels/flash_attention.py).

Masks: causal, causal + sliding window (``window > 0``), or bidirectional
(``causal=False``, for encoder stacks).  A per-layer scalar window lets
heterogeneous local/global stacks (gemma3's 5:1) stay inside one homogeneous
`lax.scan`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import apply_rope

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d_model)
    return {
        "wq": (jax.random.normal(kq, (d_model, n_heads, head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d_model, n_kv_heads, head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d_model, n_kv_heads, head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (n_heads, head_dim, d_model))
               * (1.0 / jnp.sqrt(n_heads * head_dim))).astype(dtype),
    }


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hkv, Dh) -> (B, S, H, Dh) by repeating each kv head."""
    hkv = k.shape[-2]
    if hkv == n_heads:
        return k
    rep = n_heads // hkv
    return jnp.repeat(k, rep, axis=-2)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: jax.Array | int) -> jax.Array:
    """Additive bias (Sq, Sk): 0 where attendable, NEG_INF elsewhere.
    window: 0 = unlimited; >0 = sliding window (causal only)."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        ok = dk <= dq
    w = jnp.asarray(window)
    ok = jnp.where(w > 0, jnp.logical_and(ok, dk > dq - w), ok)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_naive(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: jax.Array | int = 0,
                    q_offset: int = 0) -> jax.Array:
    """q: (B, Sq, H, Dh); k, v: (B, Sk, Hkv, Dh).  O(Sq*Sk) memory."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    bias = _mask_bias(jnp.arange(sq) + q_offset, jnp.arange(sk), causal, window)
    logits = logits + bias[None, None]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: jax.Array | int = 0,
                      q_block: int = 512, k_block: int = 512) -> jax.Array:
    """Flash-style online-softmax attention in pure JAX (O(S*block) memory).

    Scans key blocks inside a scan over query blocks, maintaining
    (running max, running sum, accumulator)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    q_block = min(q_block, sq)
    k_block = min(k_block, sk)
    nq = -(-sq // q_block)
    nk = -(-sk // k_block)
    # pad to multiples
    pad_q = nq * q_block - sq
    pad_k = nk * k_block - sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    kp_blocks = kp.reshape(b, nk, k_block, h, dh)
    vp_blocks = vp.reshape(b, nk, k_block, h, dh)

    def q_block_fn(qi, q_blk):
        q_pos = qi * q_block + jnp.arange(q_block)

        def k_body(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            k_pos = kj * k_block + jnp.arange(k_block)
            logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                                k_blk.astype(jnp.float32)) * scale
            bias = _mask_bias(q_pos, k_pos, causal, window)
            kvalid = (k_pos < sk)[None, :]
            bias = jnp.where(kvalid, bias, NEG_INF)
            logits = logits + bias[None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        acc0 = jnp.zeros((b, h, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, acc0),
            (jnp.arange(nk),
             jnp.moveaxis(kp_blocks, 1, 0), jnp.moveaxis(vp_blocks, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2)  # (b, q_block, h, dh)

    qp_blocks = jnp.moveaxis(qp.reshape(b, nq, q_block, h, dh), 1, 0)
    outs = jax.lax.map(lambda args: q_block_fn(*args),
                       (jnp.arange(nq), qp_blocks))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_block, h, dh)
    return out[:, :sq].astype(q.dtype)


def _shard_attention_inputs(q, k, v):
    """Pin the attention working set to the 'model' axis: heads when they
    divide it, else q's sequence dim (context parallelism).  Without this,
    archs whose head count doesn't divide the TP axis (smollm 9H, gemma3 8H
    on model=16) compute attention fully replicated across 'model' — 16x
    redundant FLOPs/bytes (measured on the smollm train_4k dry-run)."""
    from repro.dist.sharding import _current_mesh, batch_axes
    mesh = _current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return q, k, v
    import jax as _jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = mesh.shape["model"]
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    bspec = ba if (ba and q.shape[0] % nb == 0 and q.shape[0] >= nb) else None

    def cons(x, spec):
        sh = NamedSharding(mesh, spec) if hasattr(mesh, "devices") else spec
        return _jax.lax.with_sharding_constraint(x, sh)

    h, hkv = q.shape[2], k.shape[2]
    if h % n == 0 and hkv % n == 0:
        spec = P(bspec, None, "model", None)
        return cons(q, spec), cons(k, spec), cons(v, spec)
    if q.shape[1] % n == 0:
        # context parallelism: queries sharded over seq; k/v left to GSPMD
        # propagation (an explicit replication pin here segfaults the
        # XLA:CPU SPMD partitioner and buys nothing — k/v are gathered
        # against the seq-sharded q either way)
        q = cons(q, P(bspec, "model", None, None))
    return q, k, v




# ---------------------------------------------------------------------------
# flash-attention custom VJP (recompute-in-backward)
#
# Differentiating through the online-softmax scans makes JAX stack every
# k-block's probability matrix as a scan residual — O(S^2) backward traffic
# (measured: the dominant bytes of the smollm train_4k dry-run).  The
# textbook flash backward stores only (out, rowwise logsumexp) and
# recomputes each block's P in the reverse pass:
#     D   = rowsum(dO * O)
#     P   = exp(S - L)            (recomputed per block)
#     dV += P^T dO ;  dP = dO V^T ;  dS = P * (dP - D)
#     dQ += dS K * scale ;  dK += dS^T Q * scale
# ---------------------------------------------------------------------------

def _win_blocks(window_static, k_block: int, nk: int):
    """Static count of k-blocks a q-block can see under a sliding window
    (None = no static skip)."""
    if window_static is None or window_static <= 0:
        return None
    import math
    wb = min(math.ceil(window_static / k_block) + 1, nk)
    return wb


def _flash_core(q, k, v, window, *, causal: bool, q_block: int,
                k_block: int, window_static=None):
    """q/k/v: (B, S, H, Dh) (kv already head-repeated).  Returns
    (out (B,Sq,H,Dh), lse (B,H,Sq))."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    nq = -(-sq // q_block)
    nk = -(-sk // k_block)
    pad_q = nq * q_block - sq
    pad_k = nk * k_block - sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    kb_ = jnp.moveaxis(kp.reshape(b, nk, k_block, h, dh), 1, 0)
    vb_ = jnp.moveaxis(vp.reshape(b, nk, k_block, h, dh), 1, 0)
    # static sliding-window skip: a q-block only sees the last `wb` k-blocks
    wb = _win_blocks(window_static, k_block, nk) if causal else None

    def q_block_fn(qi, q_blk):
        q_pos = qi * q_block + jnp.arange(q_block)
        if wb is not None and wb < nk:
            start = jnp.clip(qi - (wb - 1), 0, nk - wb)
            kb_loc = jax.lax.dynamic_slice_in_dim(kb_, start, wb, axis=0)
            vb_loc = jax.lax.dynamic_slice_in_dim(vb_, start, wb, axis=0)
            kidx = start + jnp.arange(wb)
        else:
            kb_loc, vb_loc, kidx = kb_, vb_, jnp.arange(nk)

        def k_body(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            k_pos = kj * k_block + jnp.arange(k_block)
            logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                                k_blk.astype(jnp.float32)) * scale
            bias = _mask_bias(q_pos, k_pos, causal, window)
            bias = jnp.where((k_pos < sk)[None, :], bias, NEG_INF)
            logits = logits + bias[None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        acc0 = jnp.zeros((b, h, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, acc0), (kidx, kb_loc, vb_loc))
        lsafe = jnp.maximum(l, 1e-30)
        out = acc / lsafe[..., None]
        lse = m + jnp.log(lsafe)
        return jnp.moveaxis(out, 1, 2), lse      # (b,qb,h,dh), (b,h,qb)

    qb_ = jnp.moveaxis(qp.reshape(b, nq, q_block, h, dh), 1, 0)
    outs, lses = jax.lax.map(lambda a: q_block_fn(*a), (jnp.arange(nq), qb_))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_block, h, dh)
    lse = jnp.concatenate(jnp.unstack(lses, axis=0), axis=-1)
    return out[:, :sq].astype(q.dtype), lse[..., :sq]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash_attention(q, k, v, window, causal, q_block, k_block,
                     window_static=None):
    out, _ = _flash_core(q, k, v, window, causal=causal, q_block=q_block,
                         k_block=k_block, window_static=window_static)
    return out


def _flash_fwd(q, k, v, window, causal, q_block, k_block,
               window_static=None):
    out, lse = _flash_core(q, k, v, window, causal=causal, q_block=q_block,
                           k_block=k_block, window_static=window_static)
    return out, (q, k, v, window, out, lse)


def _flash_bwd(causal, q_block, k_block, window_static, res, dout):
    import numpy as _np
    q, k, v, window, out, lse = res
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    nq = -(-sq // q_block)
    nk = -(-sk // k_block)
    pad_q = nq * q_block - sq
    pad_k = nk * k_block - sk
    f32 = jnp.float32
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))).astype(f32)
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))).astype(f32)
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))).astype(f32)
    dop = jnp.pad(dout.astype(f32), ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    op = jnp.pad(out.astype(f32), ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)), constant_values=0.0)
    scale = 1.0 / jnp.sqrt(dh).astype(f32)
    # D_i = rowsum(dO * O): (b, h, sq_padded)
    dvec = jnp.einsum("bqhd,bqhd->bhq", dop, op)

    qb_ = jnp.moveaxis(qp.reshape(b, nq, q_block, h, dh), 1, 0)
    dob_ = jnp.moveaxis(dop.reshape(b, nq, q_block, h, dh), 1, 0)
    kb_ = jnp.moveaxis(kp.reshape(b, nk, k_block, h, dh), 1, 0)
    vb_ = jnp.moveaxis(vp.reshape(b, nk, k_block, h, dh), 1, 0)
    lse_b = jnp.moveaxis(lsep.reshape(b, h, nq, q_block), 2, 0)
    dvec_b = jnp.moveaxis(dvec.reshape(b, h, nq, q_block), 2, 0)
    wbq = _win_blocks(window_static, k_block, nk) if causal else None
    wbk = _win_blocks(window_static, q_block, nq) if causal else None

    def block_p(qi, kj, q_blk, k_blk, lse_blk):
        q_pos = qi * q_block + jnp.arange(q_block)
        k_pos = kj * k_block + jnp.arange(k_block)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk) * scale
        bias = _mask_bias(q_pos, k_pos, causal, window)
        bias = jnp.where((k_pos < sk)[None, :], bias, NEG_INF)
        logits = logits + bias[None, None]
        return jnp.exp(logits - lse_blk[..., None])     # (b,h,qb,kb)

    # pass 1: dq — scan q blocks, inner scan k blocks
    def dq_block(qi, q_blk, do_blk, lse_blk, d_blk):
        if wbq is not None and wbq < nk:
            start = jnp.clip(qi - (wbq - 1), 0, nk - wbq)
            kb_loc = jax.lax.dynamic_slice_in_dim(kb_, start, wbq, axis=0)
            vb_loc = jax.lax.dynamic_slice_in_dim(vb_, start, wbq, axis=0)
            kidx = start + jnp.arange(wbq)
        else:
            kb_loc, vb_loc, kidx = kb_, vb_, jnp.arange(nk)

        def k_body(dq_acc, inp):
            kj, k_blk, v_blk = inp
            p = block_p(qi, kj, q_blk, k_blk, lse_blk)
            dp = jnp.einsum("bqhd,bkhd->bhqk", do_blk, v_blk)
            ds = p * (dp - d_blk[..., None])
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds, k_blk) * scale
            return dq_acc, None
        dq0 = jnp.zeros((b, q_block, h, dh), f32)
        dq_blk, _ = jax.lax.scan(k_body, dq0, (kidx, kb_loc, vb_loc))
        return dq_blk

    dqs = jax.lax.map(lambda a: dq_block(*a),
                      (jnp.arange(nq), qb_, dob_, lse_b, dvec_b))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, nq * q_block, h, dh)[:, :sq]

    # pass 2: dk/dv — scan k blocks, inner scan q blocks
    def dkv_block(kj, k_blk, v_blk):
        if wbk is not None and wbk < nq:
            start = jnp.clip(kj, 0, nq - wbk)
            qb_loc = jax.lax.dynamic_slice_in_dim(qb_, start, wbk, axis=0)
            dob_loc = jax.lax.dynamic_slice_in_dim(dob_, start, wbk, axis=0)
            lse_loc = jax.lax.dynamic_slice_in_dim(lse_b, start, wbk, axis=0)
            dvec_loc = jax.lax.dynamic_slice_in_dim(dvec_b, start, wbk,
                                                    axis=0)
            qidx = start + jnp.arange(wbk)
        else:
            qb_loc, dob_loc, lse_loc, dvec_loc = qb_, dob_, lse_b, dvec_b
            qidx = jnp.arange(nq)

        def q_body(carry, inp):
            dk_acc, dv_acc = carry
            qi, q_blk, do_blk, lse_blk, d_blk = inp
            p = block_p(qi, kj, q_blk, k_blk, lse_blk)
            dv_acc = dv_acc + jnp.einsum("bhqk,bqhd->bkhd", p, do_blk)
            dp = jnp.einsum("bqhd,bkhd->bhqk", do_blk, v_blk)
            ds = p * (dp - d_blk[..., None])
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds, q_blk) * scale
            return (dk_acc, dv_acc), None
        z = jnp.zeros((b, k_block, h, dh), f32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            q_body, (z, z), (qidx, qb_loc, dob_loc, lse_loc, dvec_loc))
        return dk_blk, dv_blk

    dks, dvs = jax.lax.map(lambda a: dkv_block(*a),
                           (jnp.arange(nk), kb_, vb_))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, nk * k_block, h, dh)[:, :sk]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, nk * k_block, h, dh)[:, :sk]

    dwindow = _np.zeros((), jax.dtypes.float0) \
        if jnp.issubdtype(jnp.asarray(window).dtype, jnp.integer) \
        else jnp.zeros_like(jnp.asarray(window))
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            dwindow)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention(q, k, v, *, causal=True, window: jax.Array | int = 0,
              impl: str = "auto", q_offset: int = 0):
    """Dispatch: 'naive' | 'chunked' | 'pallas' | 'auto'."""
    sq, sk = q.shape[1], k.shape[1]
    q, k, v = _shard_attention_inputs(q, k, v)
    if impl == "auto":
        impl = "chunked" if max(sq, sk) > 2048 else "naive"
    if impl == "naive":
        return attention_naive(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if impl == "chunked":
        # custom-VJP flash path: identical forward to attention_chunked but
        # with a recompute-in-backward gradient (no stacked P residuals).
        # A static python window enables trace-time k-block skipping.
        h = q.shape[2]
        k = _repeat_kv(k, h)
        v = _repeat_kv(v, h)
        qb = min(512, q.shape[1])
        kb = min(512, k.shape[1])
        wstat = int(window) if isinstance(window, int) else None
        return _flash_attention(q, k, v, jnp.asarray(window), causal, qb, kb,
                                wstat)
    if impl == "chunked_ad":
        return attention_chunked(q, k, v, causal=causal, window=window)
    if impl == "pallas":
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=int(window))
    raise ValueError(impl)


def attention_block(params, x: jax.Array, *, n_heads: int, rope_theta: float,
                    causal: bool = True, window: jax.Array | int = 0,
                    impl: str = "auto", positions: Optional[jax.Array] = None,
                    kv_x: Optional[jax.Array] = None) -> jax.Array:
    """Full projection + attention + output.  kv_x enables cross-attention."""
    b, s, _ = x.shape
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(x.dtype))
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        kpos = positions if kv_x is None else jnp.arange(src.shape[1])[None, :]
        k = apply_rope(k, kpos, rope_theta)
    o = attention(q, k, v, causal=causal and kv_x is None, window=window,
                  impl=impl)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def decode_attention_block(params, x: jax.Array, cache_k: jax.Array,
                           cache_v: jax.Array, pos: jax.Array, *,
                           n_heads: int, rope_theta: float,
                           window: jax.Array | int = 0):
    """One-token decode.  x: (B, 1, D); cache_k/v: (B, S_max, Hkv, Dh);
    pos: scalar current position.  Returns (out, cache_k, cache_v)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    posb = jnp.full((x.shape[0], 1), pos)
    if rope_theta > 0:
        q = apply_rope(q, posb, rope_theta)
        k_new = apply_rope(k_new, posb, rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), pos, axis=1)
    s_max = cache_k.shape[1]
    h = q.shape[2]
    kk = _repeat_kv(cache_k.astype(jnp.float32), h)
    vv = _repeat_kv(cache_v.astype(jnp.float32), h)
    dh = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk)
    logits = logits / jnp.sqrt(dh)
    k_pos = jnp.arange(s_max)
    ok = k_pos <= pos
    w = jnp.asarray(window)
    ok = jnp.where(w > 0, jnp.logical_and(ok, k_pos > pos - w), ok)
    logits = jnp.where(ok[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, vv).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, cache_k, cache_v
