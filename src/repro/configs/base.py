"""Architecture config schema + input-shape cells.

Every assigned architecture is a frozen ``ModelConfig``; the four assigned
input shapes are ``ShapeCell``s.  ``layer_kinds`` describes the per-layer
pattern ('a' attention, 'r' RG-LRU recurrent, 'w' rwkv time-mix pair) and
``windows`` gives the per-attention-layer sliding window (0 = global) so
heterogeneous stacks (gemma3 5:1 local:global, recurrentgemma 1:2) stay in
homogeneous scans.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | rwkv | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    windows: Tuple[int, ...] = ()  # per-layer (0=global); () -> all global
    layer_kinds: Tuple[str, ...] = ()  # per-layer kind; () -> all 'a'
    rope_theta: float = 1e4
    act: str = "silu"
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # hybrid / ssm
    d_rnn: int = 0
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0
    # modality frontend stub
    frontend: str = "none"         # none | vision_stub | audio_stub
    n_patches: int = 0
    # depth-gradient policy (the paper's technique over layers)
    remat: str = "sqrt"            # none | full | sqrt | revolve
    ncheck: Optional[int] = None
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "auto"
    # notes
    source: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def kinds(self) -> Tuple[str, ...]:
        if self.layer_kinds:
            return self.layer_kinds
        return ("a",) * self.n_layers

    @property
    def win(self) -> Tuple[int, ...]:
        if self.windows:
            return self.windows
        return (0,) * self.n_layers

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dh, hf = self.d_model, self.dh, self.d_ff
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for kind in self.kinds:
            if kind == "a":
                n += d * self.n_heads * dh * 2 + d * self.n_kv_heads * dh * 2
                if self.n_experts:
                    n += d * self.n_experts + self.n_experts * 3 * d * hf
                else:
                    n += 3 * d * hf
                n += 2 * d
            elif kind == "w":
                n += 6 * d * d + d * hf + hf * d + 2 * d
            elif kind == "r":
                dr = self.d_rnn or d
                n += 2 * d * dr + dr * d + 2 * dr * dr + 3 * d * hf + 2 * d
        if self.family == "encdec":
            for _ in range(self.n_enc_layers):
                n += d * self.n_heads * dh * 2 + d * self.n_kv_heads * dh * 2
                n += 2 * d * hf + 2 * d
            # decoder cross-attention
            n += self.n_layers * (d * self.n_heads * dh * 2
                                  + d * self.n_kv_heads * dh * 2)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, hf = self.d_model, self.d_ff
        dense_expert = self.n_experts * 3 * d * hf
        active_expert = self.top_k * 3 * d * hf
        return self.param_count() - self.n_layers * (dense_expert - active_expert)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# archs for which long_500k runs (sub-quadratic sequence mixing); all others
# skip it with a note (see DESIGN.md §Arch-applicability)
LONG_CONTEXT_OK = ("gemma3-4b", "recurrentgemma-9b", "rwkv6-7b", "mixtral-8x7b")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    n_layers = overrides.pop("n_layers", min(cfg.n_layers, 4))
    kinds = cfg.kinds[:n_layers]
    wins = tuple(min(w, 8) if w else 0 for w in cfg.win[:n_layers])
    base = dict(
        name=cfg.name + "-smoke", family=cfg.family, n_layers=n_layers,
        d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=128, vocab_size=256,
        head_dim=16, windows=wins, layer_kinds=kinds,
        rope_theta=cfg.rope_theta, act=cfg.act, norm=cfg.norm,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_rnn=64 if cfg.d_rnn else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        frontend=cfg.frontend, n_patches=8 if cfg.n_patches else 0,
        remat=cfg.remat, ncheck=cfg.ncheck,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="naive", source=cfg.source,
    )
    base.update(overrides)
    return ModelConfig(**base)
