"""DBRX 132B [hf:databricks/dbrx-base] — fine-grained MoE: 16 experts top-4,
GQA kv=8, global attention."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100352, head_dim=128, rope_theta=5e5, act="silu",
    n_experts=16, top_k=4,
    source="hf:databricks/dbrx-base",
)
