"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

from repro.configs.base import (LONG_CONTEXT_OK, SHAPES, ModelConfig,
                                ShapeCell, reduced)
from repro.configs.dbrx_132b import CONFIG as DBRX
from repro.configs.gemma3_4b import CONFIG as GEMMA3
from repro.configs.llava_next_mistral_7b import CONFIG as LLAVA
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL
from repro.configs.phi3_mini_3_8b import CONFIG as PHI3
from repro.configs.recurrentgemma_9b import CONFIG as RECURRENTGEMMA
from repro.configs.rwkv6_7b import CONFIG as RWKV6
from repro.configs.smollm_135m import CONFIG as SMOLLM
from repro.configs.tinyllama_1_1b import CONFIG as TINYLLAMA
from repro.configs.whisper_medium import CONFIG as WHISPER

ARCHS = {c.name: c for c in (
    SMOLLM, PHI3, TINYLLAMA, GEMMA3, LLAVA, RECURRENTGEMMA, RWKV6, DBRX,
    MIXTRAL, WHISPER)}


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; available: {sorted(ARCHS)}") from None


def get_shape(name: str) -> ShapeCell:
    return SHAPES[name]


def cell_runnable(arch: str, shape: str) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and the skip reason if not."""
    cfg = get_arch(arch)
    cell = get_shape(shape)
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, ("pure full-attention (or <=448-token decoder): no "
                       "sub-quadratic path for a 512k KV cache; see DESIGN.md")
    if cell.kind == "decode" and cfg.family == "encdec" and shape == "long_500k":
        return False, "whisper decoder max context is 448"
    return True, ""


def all_cells():
    for a in ARCHS:
        for s in SHAPES:
            yield a, s
