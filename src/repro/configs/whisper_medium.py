"""Whisper-medium [arXiv:2212.04356] — encoder-decoder; the conv audio
frontend is a stub (input_specs() provides precomputed frame embeddings,
1500 frames x d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865, head_dim=64, rope_theta=0.0, act="gelu",
    norm="layernorm", n_enc_layers=24, enc_seq=1500,
    frontend="audio_stub",
    source="arXiv:2212.04356",
)
