"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].
Backbone only; the anyres vision tiling is a stub: input_specs() provides
precomputed patch embeddings (n_patches x d_model) prepended to the tokens."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128,
    windows=(4096,) * 32,          # mistral sliding-window attention
    rope_theta=1e4, act="silu",
    frontend="vision_stub", n_patches=576,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
