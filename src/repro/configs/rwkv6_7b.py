"""RWKV-6 (Finch) 7B [arXiv:2404.05892] — attention-free, data-dependent
decay time-mix + squared-relu channel-mix; head_dim 64."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=0, d_ff=14336,
    vocab_size=65536, head_dim=64,
    layer_kinds=("w",) * 32, rope_theta=0.0, act="relu",
    source="arXiv:2404.05892",
)
