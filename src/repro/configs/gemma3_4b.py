"""Gemma-3 4B [hf:google/gemma-3-1b-pt family] — 5:1 local:global attention,
huge vocab (262144), GQA kv=4, head_dim=256, 128k-class context."""
from repro.configs.base import ModelConfig

_N = 34
_WINDOWS = tuple(0 if (i + 1) % 6 == 0 else 1024 for i in range(_N))

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=_N, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab_size=262144, head_dim=256, windows=_WINDOWS,
    rope_theta=1e6, act="gelu", tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
