"""Mixtral 8x7B [arXiv:2401.04088] — 8 experts top-2 MoE, sliding-window
attention (4096), GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128, windows=(4096,) * 32,
    rope_theta=1e6, act="silu", n_experts=8, top_k=2,
    source="arXiv:2401.04088",
)
