"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427] — RG-LRU + local attention,
1 attention : 2 recurrent layers, GQA kv=1, head_dim=256."""
from repro.configs.base import ModelConfig

_N = 38
# pattern: (r, r, a) repeated; remainder layers are recurrent
_KINDS = tuple("a" if i % 3 == 2 else "r" for i in range(_N))
_WINDOWS = tuple(2048 if k == "a" else 0 for k in _KINDS)

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=_N, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab_size=256000, head_dim=256, layer_kinds=_KINDS, windows=_WINDOWS,
    rope_theta=1e4, act="gelu", d_rnn=4096,
    source="arXiv:2402.19427",
)
