"""Vector-field networks for the paper's experiments.

Three families, matching §5 of the paper:
  * ``mlp_vf``      — small MLP f(u, t): Robertson / stiff-dynamics learning
                      (5 hidden GELU layers, as in Kim et al. / the paper).
  * ``cnf_vf``      — concatsquash-style MLP used by FFJORD CNF density
                      estimation (hidden widths from the FFJORD configs).
  * ``conv_vf``     — 3x3 conv ODE block for image classification
                      (SqueezeNext-style channel mixing), NHWC layout.

All are pure ``init``/``apply`` pairs with the framework-wide vector-field
signature ``f(u, theta, t) -> du/dt``.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

_ACTS = {
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "silu": jax.nn.silu,
    "softplus": jax.nn.softplus,
    "relu": jax.nn.relu,
}


def _dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    w_key, _ = jax.random.split(key)
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return {"w": scale * jax.random.normal(w_key, (d_in, d_out), jnp.float32),
            "b": jnp.zeros((d_out,), jnp.float32)}


# ---------------------------------------------------------------------------
# MLP vector field (Robertson / stiff dynamics)
# ---------------------------------------------------------------------------

def mlp_vf_init(key, dim: int, hidden: int = 50, n_hidden: int = 5):
    ks = jax.random.split(key, n_hidden + 1)
    sizes = [dim] + [hidden] * n_hidden + [dim]
    layers = [_dense_init(ks[i], sizes[i], sizes[i + 1])
              for i in range(len(sizes) - 1)]
    # near-zero last layer: f ~ 0 at init so the ODE starts near-identity
    layers[-1]["w"] = layers[-1]["w"] * 1e-2
    return {"layers": layers}


def mlp_vf(u, theta, t, act: str = "gelu"):
    """f(u, theta, t) for a plain MLP; u may be (D,) or (B, D)."""
    a = _ACTS[act]
    x = u
    layers = theta["layers"]
    for lyr in layers[:-1]:
        x = a(x @ lyr["w"] + lyr["b"])
    lyr = layers[-1]
    return x @ lyr["w"] + lyr["b"]


# ---------------------------------------------------------------------------
# concatsquash MLP (FFJORD CNF)
# ---------------------------------------------------------------------------

def cnf_vf_init(key, dim: int, hidden: Sequence[int] = (64, 64, 64)):
    """FFJORD concatsquash layers: y = (Wx+b) * sigmoid(a_t t + c) + g_t t."""
    sizes = [dim] + list(hidden) + [dim]
    ks = jax.random.split(key, len(sizes) - 1)
    layers = []
    for i in range(len(sizes) - 1):
        k1, k2 = jax.random.split(ks[i])
        lyr = _dense_init(k1, sizes[i], sizes[i + 1])
        lyr["t_gate"] = jnp.zeros((sizes[i + 1],), jnp.float32)
        lyr["t_gate_b"] = jnp.zeros((sizes[i + 1],), jnp.float32)
        lyr["t_bias"] = jnp.zeros((sizes[i + 1],), jnp.float32)
        layers.append(lyr)
    layers[-1]["w"] = layers[-1]["w"] * 1e-2
    return {"layers": layers}


def cnf_vf(u, theta, t, act: str = "tanh"):
    a = _ACTS[act]
    x = u
    t = jnp.asarray(t, jnp.float32)
    layers = theta["layers"]
    for i, lyr in enumerate(layers):
        y = x @ lyr["w"] + lyr["b"]
        gate = jax.nn.sigmoid(lyr["t_gate"] * t + lyr["t_gate_b"])
        y = y * gate + lyr["t_bias"] * t
        x = a(y) if i < len(layers) - 1 else y
    return x


# ---------------------------------------------------------------------------
# conv vector field + classifier head (image classification, §5.1)
# ---------------------------------------------------------------------------

def _conv_init(key, kh: int, kw: int, c_in: int, c_out: int):
    scale = (1.0 / (kh * kw * c_in)) ** 0.5
    return {"w": scale * jax.random.normal(key, (kh, kw, c_in, c_out),
                                           jnp.float32),
            "b": jnp.zeros((c_out,), jnp.float32)}


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def conv_vf_init(key, channels: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"conv1": _conv_init(k1, 3, 3, channels + 1, channels),
         "conv2": _conv_init(k2, 3, 3, channels + 1, channels),
         "gn_scale": jnp.ones((channels,), jnp.float32),
         "gn_bias": jnp.zeros((channels,), jnp.float32)}
    p["conv2"]["w"] = p["conv2"]["w"] * 1e-2
    return p


def _group_norm(x, scale, bias, groups: int = 8):
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) / jnp.sqrt(var + 1e-5)
    return xg.reshape(b, h, w, c) * scale + bias


def conv_vf(u, theta, t):
    """ODE-block conv vector field with time concatenated as a channel
    (the standard Chen et al. 'concat' conv).  u: (B, H, W, C)."""
    b, h, w, _ = u.shape
    tt = jnp.broadcast_to(jnp.asarray(t, u.dtype), (b, h, w, 1))
    x = _group_norm(u, theta["gn_scale"], theta["gn_bias"])
    x = jax.nn.relu(x)
    x = _conv(theta["conv1"], jnp.concatenate([x, tt], axis=-1))
    x = jax.nn.relu(x)
    x = _conv(theta["conv2"], jnp.concatenate([x, tt], axis=-1))
    return x


def classifier_init(key, channels: int = 32, n_classes: int = 10,
                    in_channels: int = 3):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "stem": _conv_init(k1, 3, 3, in_channels, channels),
        "ode": conv_vf_init(k2, channels),
        "head": _dense_init(k3, channels, n_classes),
    }


def classifier_apply(params, images, *, odeint_fn):
    """stem conv -> ODE block (via the caller-supplied odeint closure)
    -> global average pool -> linear head.  images: (B, H, W, C_in)."""
    x = jax.nn.relu(_conv(params["stem"], images))
    x = odeint_fn(conv_vf, x, params["ode"])
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


def softmax_xent(logits, labels) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()
