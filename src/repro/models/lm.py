"""Causal LM (+ enc-dec, VLM/audio stubs): init / forward / loss / prefill /
decode for every assigned architecture family.

Batch dict conventions (see launch/dryrun.py input_specs):
  train:    {"tokens": (B, S) int32, "targets": (B, S) int32}
            VLM adds  {"patches": (B, P, D)}  (tokens are (B, S-P))
            enc-dec:  {"frames": (B, S_enc, D), "tokens"/"targets": (B, S)}
  prefill:  same minus targets
  decode:   {"token": (B, 1) int32, "pos": scalar int32} + decode state
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import transformer as tf
from repro.nn.layers import embedding, embedding_init

Params = Dict[str, Any]


MAX_ABS_POS = 32768  # learned positions for rope-free decoders (whisper)


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    pdt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model, pdt),
        "blocks": tf.init_stack(ks[1], cfg, cross=cfg.family == "encdec"),
        "final_norm": tf.rmsnorm_init(cfg.d_model) if cfg.norm == "rmsnorm"
        else tf.layernorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = embedding_init(ks[2], cfg.vocab_size, cfg.d_model, pdt)
    if cfg.family == "encdec":
        p["enc_blocks"] = tf.init_stack(ks[3], _enc_cfg(cfg), cross=False)
        p["enc_norm"] = (tf.rmsnorm_init(cfg.d_model)
                         if cfg.norm == "rmsnorm"
                         else tf.layernorm_init(cfg.d_model))
        if cfg.rope_theta == 0:
            p["pos_embed"] = embedding_init(ks[4], MAX_ABS_POS, cfg.d_model,
                                            pdt)
    return p


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, n_layers=cfg.n_enc_layers,
                               layer_kinds=("a",) * cfg.n_enc_layers,
                               windows=(0,) * cfg.n_enc_layers,
                               n_experts=0, top_k=0, family="dense")


def _norm(cfg, p, x):
    from repro.nn.layers import layernorm, rmsnorm
    return layernorm(p, x) if cfg.norm == "layernorm" else rmsnorm(p, x)


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    from repro.dist.sharding import constrain_batch
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["head"]["table"]
    out = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    # batch stays on data axes; vocab dim sharded over 'model'
    return constrain_batch(out, extra={2: "model"})


def _encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    ecfg = _enc_cfg(cfg)
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x, _ = tf.apply_stack(ecfg, params["enc_blocks"], x, causal=False)
    return _norm(cfg, params["enc_norm"], x)


def _embed_tokens(cfg: ModelConfig, params: Params, tokens, pos0=0):
    from repro.dist.sharding import constrain_batch
    cdt = jnp.dtype(cfg.compute_dtype)
    x = constrain_batch(embedding(params["embed"], tokens).astype(cdt))
    if "pos_embed" in params:
        pos = pos0 + jnp.arange(tokens.shape[1])
        x = x + embedding(params["pos_embed"], pos)[None].astype(cdt)
    return x


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.frontend == "vision_stub" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(cdt), x], axis=1)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"])
    x, aux = tf.apply_stack(cfg, params["blocks"], x, enc_out=enc_out,
                            causal=True)
    x = _norm(cfg, params["final_norm"], x)
    if cfg.frontend == "vision_stub" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]  # loss only over text positions
    return _logits(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    """Next-token cross-entropy (+ MoE aux).  Returns (loss, metrics).

    The logsumexp is computed from the compute-dtype logits with fp32
    accumulation inside the reduction (max-subtract form) instead of first
    materializing an fp32 copy of the (B, S, V) logits — at gemma3's 262k
    vocab that copy is 4+ GiB/device and several HBM passes."""
    logits, aux = forward(cfg, params, batch)
    targets = batch["targets"]
    logits = logits[:, :-1]
    tgt = targets[:, 1:]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    logz = m[..., 0].astype(jnp.float32) + jnp.log(
        jnp.sum(jnp.exp((logits - m).astype(jnp.float32)), axis=-1))
    gold = jnp.take_along_axis(logits, tgt[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)
    ce = (logz - gold).mean()
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    state = tf.init_stack_state(cfg, batch, max_seq,
                                cross=cfg.family == "encdec")
    if cfg.family == "encdec":
        state["enc_out"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model),
                                     jnp.dtype(cfg.compute_dtype))
    return state


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            max_seq: int):
    """Run the prompt through the model, threading decode state (KV caches /
    recurrent states) through every layer.  Returns (state, last_logits)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.frontend == "vision_stub" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(cdt), x], axis=1)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"])
    x, state = tf.prefill_stack(cfg, params["blocks"], x, max_seq,
                                enc_out=enc_out)
    if enc_out is not None:
        # decode needs cross-attention context: carry it in the state
        state["enc_out"] = enc_out
    x = _norm(cfg, params["final_norm"], x)
    return state, _logits(cfg, params, x[:, -1:])[:, 0]


def decode_step(cfg: ModelConfig, params: Params, state, token: jax.Array,
                pos, enc_out=None):
    """One decode step.  token: (B, 1) int32, pos: scalar int32.
    Returns (logits (B, V), new_state)."""
    if enc_out is None:
        enc_out = state.get("enc_out")  # stashed by prefill for enc-dec
    x = _embed_tokens(cfg, params, token, pos0=pos)
    inner = {"scan": state["scan"], "rem": state["rem"]}
    x, inner = tf.decode_stack(cfg, params["blocks"], inner, x, pos,
                               enc_out=enc_out)
    new_state = dict(state, **inner)
    x = _norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x)[:, 0], new_state
