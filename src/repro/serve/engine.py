"""Inference engines for ``repro.serve``: batched ODE evaluation under a
memory budget, and wave-based continuous batching for the LM decode path.

``ODEEngine`` is the paper workload as a service: CNF log-density
(``kind="density"``), score ``∇ₓ log p(x)`` (``"score"`` — the reverse
pass, i.e. the adjoint the paper is about), and ODE-classifier logits
(``"classify"``) over a caller-supplied vector field.  Batches come from
a ``RequestQueue``, are padded to a ``BucketSpec`` bucket (bounded jit
cache: one compiled program per (kind, bucket)), and every solve runs
through ``odeint(adjoint="pnode", offload="spill"|"disk")`` with a
caller-owned store whose ``lane_keys`` tie each checkpoint slot to the
request occupying that lane — slot key ``(request_id, step_index)``.
Because lane keys are consulted at callback *execution* time, the same
compiled bucket program serves every batch composition without retracing,
padding lanes store nothing, and ``store.free_request(rid)`` drops a
departing request's slots without touching its batch-mates.  Batched
offloaded solves are bitwise-identical to the unbatched per-request loop
(tests/test_serve.py asserts this across spill, disk, and the RAM/disk
split).

Memory budgets go through ``repro.mem.plan_odeint(batch=bucket)``: the
planner prices the *batched* working set (state and f-activation bytes
scale with the lane count, shared ``theta`` does not) and solves the
RAM/disk ``snaps_in_ram`` split the engine's stores then honor.

``adaptive=True`` selects the per-request loop path instead: adaptive
(dopri5) solves have data-dependent, per-lane-divergent step sequences,
so their staging-ring offload cannot be lane-keyed soundly (a batched
accept predicate under ``lax.cond`` would flush every lane on every
accept) — each request gets its own single-lane solve and store.  Same
queue, same tickets, same fault sites; throughput comes from the shared
compiled single-lane program rather than vmap.

``LMEngine`` is the token path: wave-based continuous batching honoring
the decode step's *scalar* position argument (all lanes of a wave share
``pos``), with the next wave's prefill interleaved between decode slices
of the active wave so admission never stalls the decode stream.

Fault sites (``repro.ft.inject``): ``serve.request`` (admission — see
``queue.py``) and ``serve.decode`` — an injected NaN poisons exactly one
lane's result, which resolves THAT ticket with an error while its
batch-mates' results stay bitwise-correct.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adjoint import odeint
from repro.core.adaptive import odeint_adaptive
from repro.core.cnf import exact_trace_vf
from repro.mem.offload import make_store
from repro.mem.planner import plan_odeint
from repro.serve.queue import BucketSpec, RequestQueue, Ticket

__all__ = ["ODEEngine", "LMEngine"]


class ODEEngine:
    """Continuous-batching ODE inference over one vector field.

    Parameters
    ----------
    f : vector field ``f(u, theta, t)`` on ``(dim,)`` states.
    theta : its parameters (shared across every request).
    dim : state dimension; request payloads are ``(dim,)`` float arrays.
    dt, n_steps, t0, method : the solve grid (fixed-step path).
    offload : "spill" | "disk" | None — checkpoint tier for the reverse
        pass.  Overridden by the planner when a budget is given.
    mem_budget / ram_budget / disk_budget : consult ``plan_odeint`` with
        ``batch=max bucket`` (the worst-case working set) — the plan's
        policy/offload/snaps_in_ram configure the engine; ``.plan`` keeps
        the full report.
    head : optional ``head(u_final) -> logits`` readout for
        ``kind="classify"`` (default: identity — logits are the final
        state).
    adaptive : per-request adaptive (dopri5) path, see module docstring.
    """

    KINDS = ("density", "score", "classify")

    def __init__(self, f: Callable, theta: Any, *, dim: int, dt: float,
                 n_steps: int, t0: float = 0.0, method: str = "rk4",
                 offload: Optional[str] = "spill",
                 offload_segment: Optional[int] = None,
                 snaps_in_ram: Optional[int] = None,
                 mem_budget: Optional[int] = None,
                 ram_budget: Optional[int] = None,
                 disk_budget: Optional[int] = None,
                 buckets: Optional[BucketSpec] = None,
                 head: Optional[Callable] = None,
                 adaptive: bool = False, rtol: float = 1e-6,
                 atol: float = 1e-6, max_steps: int = 512,
                 spool_dir: Optional[str] = None,
                 queue: Optional[RequestQueue] = None,
                 fault_plan=None, registry=None, obs=None,
                 max_payload_bytes: int = 1 << 20, aging: float = 1.0):
        self.f = f
        self.theta = theta
        self.dim = int(dim)
        self.dt = float(dt)
        self.n_steps = int(n_steps)
        self.t0 = float(t0)
        self.method = method
        self.offload = offload
        self.offload_segment = offload_segment
        self.snaps_in_ram = snaps_in_ram
        self.buckets = buckets or BucketSpec()
        self.head = head if head is not None else (lambda u: u)
        self.adaptive = bool(adaptive)
        self.rtol, self.atol, self.max_steps = rtol, atol, int(max_steps)
        self.spool_dir = spool_dir
        self.fault_plan = fault_plan
        self.registry = registry
        self.obs = obs
        self._aug = exact_trace_vf(f, self.dim)
        self.plan = None
        if mem_budget is not None or ram_budget is not None:
            proto = (jnp.zeros((self.dim,), jnp.float32),
                     jnp.zeros((), jnp.float32))
            self.plan = plan_odeint(
                self._aug, proto, theta, dt=self.dt, n_steps=self.n_steps,
                t0=self.t0, method=method, mem_budget=mem_budget,
                ram_budget=ram_budget, disk_budget=disk_budget,
                verify="model", batch=self.buckets.max_size)
            # the plan sizes the BATCHED working set; honor its tier and
            # RAM/disk split (offload=None => the policy fits on device)
            self.offload = self.plan.offload
            if self.plan.snaps_in_ram is not None:
                self.snaps_in_ram = self.plan.snaps_in_ram
        if self.offload not in (None, "spill", "disk"):
            raise ValueError(
                f"ODEEngine serves the lane-keyed spill/disk tiers (or "
                f"no offload); got offload={self.offload!r}")
        self.queue = queue if queue is not None else RequestQueue(
            kinds=self.KINDS, dim=self.dim,
            max_payload_bytes=max_payload_bytes, aging=aging,
            fault_plan=fault_plan, registry=registry, obs=obs)
        self._stores: Dict[int, Any] = {}
        self._fns: Dict[Tuple[str, int], Callable] = {}

    # -- stores / compiled programs -----------------------------------------
    def _store(self, bucket: int):
        """One caller-owned store per bucket (the compiled bucket program
        captures it; sharing across kinds is safe — ``step`` is
        sequential).  Per-bucket disk subdirs keep one store's stale-file
        sweep away from its siblings' segment files."""
        if self.offload is None:
            return None
        if bucket not in self._stores:
            sub = None
            if self.spool_dir is not None:
                import os
                sub = os.path.join(self.spool_dir, f"bucket{bucket}")
                os.makedirs(sub, exist_ok=True)
            st = make_store(self.offload, fault_plan=self.fault_plan,
                            snaps_in_ram=self.snaps_in_ram, disk_dir=sub)
            if self.obs is not None:
                st.bind_obs(self.obs)
            st.lane_keys = (None,) * bucket
            self._stores[bucket] = st
        return self._stores[bucket]

    def _solver_kw(self, store) -> dict:
        kw = dict(dt=self.dt, n_steps=self.n_steps, t0=self.t0,
                  method=self.method, adjoint="pnode")
        if store is not None:
            kw.update(offload=self.offload,
                      offload_segment=self.offload_segment,
                      snaps_in_ram=self.snaps_in_ram, offload_store=store)
        return kw

    def _logp_one(self, theta, x, store):
        kw = self._solver_kw(store)
        z, dlogdet = odeint(self._aug, (x, jnp.zeros((), x.dtype)), theta,
                            **kw)
        return (-0.5 * jnp.sum(z ** 2)
                - 0.5 * self.dim * jnp.log(2 * jnp.pi) + dlogdet)

    def _fn(self, kind: str, bucket: int) -> Callable:
        """Compiled (kind, bucket) program — at most
        ``len(KINDS) * len(buckets.sizes)`` ever exist (the bounded
        compile cache the README documents)."""
        key = (kind, bucket)
        if key in self._fns:
            return self._fns[key]
        store = self._store(bucket)

        def density(theta, xb):
            return jax.vmap(lambda x: self._logp_one(theta, x, store))(xb)

        def score(theta, xb):
            g = jax.grad(lambda x: self._logp_one(theta, x, store))
            return jax.vmap(g)(xb)

        def classify(theta, xb):
            def one(x):
                uT = odeint(self.f, x, theta, **self._solver_kw(store))
                return self.head(uT)
            return jax.vmap(one)(xb)

        fn = {"density": density, "score": score,
              "classify": classify}[kind]
        self._fns[key] = jax.jit(fn)
        return self._fns[key]

    # -- adaptive (per-request) path ----------------------------------------
    def _adaptive_kw(self) -> dict:
        kw = dict(t0=self.t0, t1=self.t0 + self.dt * self.n_steps,
                  rtol=self.rtol, atol=self.atol, max_steps=self.max_steps)
        if self.offload is not None:
            kw.update(offload=self.offload,
                      offload_segment=self.offload_segment)
            if self.offload == "spill":
                kw.update(snaps_in_ram=self.snaps_in_ram)
        return kw

    def _adaptive_fn(self, kind: str) -> Callable:
        key = (f"adaptive.{kind}", 1)
        if key in self._fns:
            return self._fns[key]
        kw = self._adaptive_kw()

        def logp_one(theta, x):
            (z, dlogdet), _ = odeint_adaptive(
                self._aug, (x, jnp.zeros((), x.dtype)), theta, **kw)
            return (-0.5 * jnp.sum(z ** 2)
                    - 0.5 * self.dim * jnp.log(2 * jnp.pi) + dlogdet)

        def density(theta, x):
            return logp_one(theta, x)

        def score(theta, x):
            return jax.grad(lambda xx: logp_one(theta, xx))(x)

        def classify(theta, x):
            uT, _ = odeint_adaptive(self.f, x, theta, **kw)
            return self.head(uT)

        fn = {"density": density, "score": score,
              "classify": classify}[kind]
        self._fns[key] = jax.jit(fn)
        return self._fns[key]

    # -- serving -------------------------------------------------------------
    def submit(self, kind: str, x, *, priority: float = 0.0,
               rid: Optional[str] = None) -> Ticket:
        return self.queue.submit(kind, x, priority=priority, rid=rid)

    def warmup(self, kinds=None, buckets=None) -> int:
        """Pre-compile (kind, bucket) programs with all-padding lane keys
        (stores nothing); returns the number compiled."""
        n = 0
        for kind in (kinds or self.KINDS):
            if self.adaptive:
                fn = self._adaptive_fn(kind)
                jax.block_until_ready(
                    fn(self.theta, jnp.zeros((self.dim,), jnp.float32)))
                n += 1
                continue
            for b in (buckets or self.buckets.sizes):
                store = self._store(b)
                if store is not None:
                    store.lane_keys = (None,) * b
                fn = self._fn(kind, b)
                jax.block_until_ready(
                    fn(self.theta, jnp.zeros((b, self.dim), jnp.float32)))
                n += 1
        return n

    def _resolve(self, batch, rows: List[np.ndarray], tick: int) -> None:
        for (req, ticket), row in zip(batch, rows):
            if not np.all(np.isfinite(row)):
                if self.registry is not None:
                    self.registry.inc("serve.errors")
                ticket.set_error(RuntimeError(
                    f"request {req.rid}: non-finite result "
                    f"(poisoned decode?)"), tick)
            else:
                if self.registry is not None:
                    self.registry.inc("serve.completed")
                ticket.set_result(row, tick)

    def step(self) -> int:
        """One scheduling quantum: claim a same-kind batch, pad it to a
        bucket, run the compiled program with the batch's lane keys, tick
        the ``serve.decode`` fault site, resolve tickets (a poisoned lane
        errors alone), free every request's slots.  Returns the number of
        requests served (0 = queue idle)."""
        batch = self.queue.next_batch(self.buckets.max_size)
        if not batch:
            return 0
        kind = batch[0][0].kind
        if self.adaptive:
            return self._step_adaptive(kind, batch)
        bucket = self.buckets.bucket_for(len(batch))
        xb = np.zeros((bucket, self.dim), np.float32)
        lanes: List[Optional[str]] = [None] * bucket
        for i, (req, _) in enumerate(batch):
            xb[i] = req.payload
            lanes[i] = req.rid
        store = self._store(bucket)
        stats0 = dict(store.stats) if store is not None else {}
        if store is not None:
            store.lane_keys = tuple(lanes)
        t_start = time.time()
        out = np.asarray(jax.block_until_ready(
            self._fn(kind, bucket)(self.theta, jnp.asarray(xb))))
        wall = time.time() - t_start
        out = out.copy()  # poisoning below must not alias a jax buffer
        if self.fault_plan is not None:
            spec = self.fault_plan.tick("serve.decode")
            if spec is not None and spec.kind == "nan":
                out[0] = np.nan  # first real lane: a request-level fault
        tick = self.queue.tick
        self._resolve(batch, [out[i] for i in range(len(batch))], tick)
        cbs = 0
        if store is not None:
            for req, _ in batch:
                store.free_request(req.rid)
            store.lane_keys = (None,) * bucket
            delta = {k: store.stats.get(k, 0) - stats0.get(k, 0)
                     for k in store.stats}
            cbs = (delta.get("write_cb", 0) + delta.get("read_cb", 0)
                   + delta.get("dispatch_cb", 0)
                   + delta.get("prefetch_hit_cb", 0))
        occ = len(batch) / bucket
        if self.registry is not None:
            self.registry.observe("serve.batch_occupancy", occ)
            self.registry.observe("serve.callbacks_per_request",
                                  cbs / len(batch))
            self.registry.observe("serve.batch_wall_s", wall)
        if self.obs is not None:
            self.obs.record("serve.batch", _runtime=True, req_kind=kind,
                            bucket=bucket, lanes=len(batch),
                            occupancy=occ, callbacks=cbs, wall_s=wall)
        return len(batch)

    def _step_adaptive(self, kind: str, batch) -> int:
        """Per-request loop: each request is its own single-lane adaptive
        solve (own store, built inside ``odeint_adaptive``) — trivially
        bitwise vs the unbatched reference, at batch occupancy 1."""
        fn = self._adaptive_fn(kind)
        rows = []
        t_start = time.time()
        for req, _ in batch:
            out = np.asarray(jax.block_until_ready(
                fn(self.theta, jnp.asarray(req.payload, jnp.float32))))
            out = np.atleast_1d(out).copy()
            if self.fault_plan is not None:
                spec = self.fault_plan.tick("serve.decode")
                if spec is not None and spec.kind == "nan":
                    out[...] = np.nan
            rows.append(out)
        wall = time.time() - t_start
        tick = self.queue.tick
        self._resolve(batch, rows, tick)
        if self.registry is not None:
            self.registry.observe("serve.batch_occupancy", 1.0)
            self.registry.observe("serve.batch_wall_s", wall)
        if self.obs is not None:
            self.obs.record("serve.batch", _runtime=True, req_kind=kind,
                            bucket=1, lanes=len(batch), occupancy=1.0,
                            adaptive=True, wall_s=wall)
        return len(batch)

    def run(self, max_steps: int = 10_000) -> int:
        """Drain the queue; returns requests served."""
        served = 0
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and self.queue.depth() == 0:
                break
            served += n
        return served

    def slot_census(self) -> Dict[str, int]:
        """Summed live slots across every bucket store (0 everywhere when
        no request is in flight — departures freed their slots)."""
        total = {"ram": 0, "disk": 0, "disk_files": 0}
        for st in self._stores.values():
            for k, v in st.slot_census().items():
                total[k] = total.get(k, 0) + v
        return total


class _Wave:
    """One cohort of lanes decoding in lockstep (shared scalar ``pos``)."""

    def __init__(self, batch, state, tok, pos0: int, max_gen: int,
                 lanes: int):
        self.batch = batch              # [(Request, Ticket)] real lanes
        self.state = state
        self.tok = tok                  # (lanes, 1) int32 — last sampled
        self.pos = 0                    # decode steps taken so far
        self.pos0 = int(pos0)
        self.max_gen = int(max_gen)
        self.lanes = int(lanes)
        self.emitted: List[np.ndarray] = []   # per-step (lanes,) tokens
        self.errored: set = set()       # lane indices poisoned mid-decode

    @property
    def done(self) -> bool:
        return len(self.emitted) >= self.max_gen


class LMEngine:
    """Wave-based continuous batching for the LM prefill/decode path.

    The decode step takes a *scalar* position (``lm.decode_step``'s KV /
    recurrent state contract), so lanes cannot be at different sequence
    offsets inside one batch: requests are grouped into *waves* that
    prefill together and decode in lockstep.  Interleaving happens at the
    scheduling level — between decode slices of the active wave the
    engine prefills the next wave (``_staged``), so when the active wave
    retires the next one starts decoding immediately instead of stalling
    on prefill + compile.

    ``call_log`` records every device call (op, wall seconds, tokens
    emitted, compile-or-not) — the accounting ``launch/serve.py`` uses to
    split warm-up from steady state.
    """

    def __init__(self, cfg, *, lanes: int, prompt_len: int, max_gen: int,
                 decode_slice: int = 4, temperature: float = 0.0,
                 seed: int = 0, mesh=None, shard: bool = False,
                 params=None, fault_plan=None, registry=None, obs=None,
                 aging: float = 1.0):
        from repro.configs.base import ShapeCell
        from repro.launch.mesh import make_host_mesh
        from repro.launch.steps import make_decode_step, make_prefill_step
        from repro.models import lm as lm_mod

        self.cfg = cfg
        self.lanes = int(lanes)
        self.prompt_len = int(prompt_len)
        self.max_gen = int(max_gen)
        self.decode_slice = max(1, int(decode_slice))
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.fault_plan = fault_plan
        self.registry = registry
        self.obs = obs
        self._lm = lm_mod
        self.max_seq = self.prompt_len + self.max_gen
        self.queue = RequestQueue(
            kinds=("lm",), dim=self.prompt_len,
            max_payload_bytes=max(1 << 20, 8 * self.prompt_len),
            aging=aging, fault_plan=fault_plan, registry=registry, obs=obs)
        self.call_log: List[Dict[str, Any]] = []
        self._active: Optional[_Wave] = None
        self._staged: Optional[_Wave] = None
        self._decode_calls = 0
        self._wave_seq = 0
        self.pos0 = self.prompt_len + (
            cfg.n_patches if cfg.frontend == "vision_stub" else 0)

        with self.mesh:
            if params is None:
                params = jax.jit(lambda k: lm_mod.init_params(cfg, k))(
                    jax.random.PRNGKey(self.seed))
            self.params = params
            prefill = make_prefill_step(cfg, max_seq=self.max_seq)
            decode = make_decode_step(cfg)
            if shard:
                # multi-replica serve: lanes sharded over the mesh's data
                # axes, decode state per repro.dist decode-state specs
                from jax.sharding import NamedSharding, PartitionSpec as P
                from repro.dist import sharding as shd
                cell = ShapeCell("serve", self.max_seq, self.lanes,
                                 "decode")
                pshape = jax.eval_shape(
                    lambda: lm_mod.init_params(cfg, jax.random.PRNGKey(0)))
                pshard = shd.to_shardings(
                    shd.param_specs(cfg, pshape, self.mesh), self.mesh)
                sshape = jax.eval_shape(
                    lambda: lm_mod.init_decode_state(cfg, self.lanes,
                                                     self.max_seq))
                sshard = shd.to_shardings(
                    shd.decode_state_specs(cfg, cell, sshape, self.mesh),
                    self.mesh)
                ba = shd.batch_axes(self.mesh)
                nd = 1
                for a in ba:
                    nd *= self.mesh.shape[a]
                bspec = ba if ba and self.lanes % max(1, nd) == 0 else None
                tshard = NamedSharding(self.mesh, P(bspec, None))
                scalar = NamedSharding(self.mesh, P())
                self._prefill_fn = jax.jit(prefill)
                # out state pinned to the same specs so the donated
                # decode->decode handoff never sees a sharding mismatch
                self._decode_fn = jax.jit(
                    decode, donate_argnums=(1,),
                    in_shardings=(pshard, sshard, tshard, scalar),
                    out_shardings=(tshard, sshard))
                self.params = jax.device_put(self.params, pshard)
                self._state_shard, self._tok_shard = sshard, tshard
            else:
                self._prefill_fn = jax.jit(prefill)
                self._decode_fn = jax.jit(decode, donate_argnums=(1,))
                self._state_shard = self._tok_shard = None

    # -- client API ----------------------------------------------------------
    def submit(self, prompt, *, gen: Optional[int] = None,
               priority: float = 0.0, rid: Optional[str] = None,
               extras: Optional[Dict[str, Any]] = None) -> Ticket:
        """Admit one prompt (``(prompt_len,)`` int tokens).  ``gen`` caps
        this request's emitted tokens (≤ engine ``max_gen``); ``extras``
        carries per-request frontend arrays (vision patches, enc-dec
        frames) stacked into the wave's prefill batch."""
        gen = self.max_gen if gen is None else min(int(gen), self.max_gen)
        meta = {"gen": gen}
        if extras:
            meta["extras"] = {k: np.asarray(v) for k, v in extras.items()}
        return self.queue.submit("lm", np.asarray(prompt, np.int32),
                                 priority=priority, rid=rid, meta=meta)

    # -- internals -----------------------------------------------------------
    def _sample(self, key, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1).astype(jnp.int32)

    def _prefill_next(self) -> Optional[_Wave]:
        batch = self.queue.next_batch(self.lanes, kind="lm")
        if not batch:
            return None
        self._wave_seq += 1
        toks = np.zeros((self.lanes, self.prompt_len), np.int32)
        for i, (req, _) in enumerate(batch):
            toks[i] = req.payload
        prompt: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        extras = batch[0][0].meta.get("extras") or {}
        for k, proto in extras.items():
            stack = np.zeros((self.lanes,) + proto.shape, proto.dtype)
            for i, (req, _) in enumerate(batch):
                stack[i] = req.meta.get("extras", {}).get(
                    k, np.zeros_like(proto))
            prompt[k] = jnp.asarray(stack)
        compile_ = not self.call_log  # first prefill pays the compile
        t_start = time.time()
        with self.mesh:
            state, logits = self._prefill_fn(self.params, prompt)
            jax.block_until_ready(logits)
        wall = time.time() - t_start
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1),
                                 self._wave_seq)
        tok = self._sample(key, logits)[:, None]
        if self._state_shard is not None:
            # prefill output is committed wherever GSPMD left it; move the
            # wave state/token onto the decode-state specs before the
            # donated decode loop (explicit in_shardings won't reshard
            # committed args)
            state = jax.device_put(state, self._state_shard)
            tok = jax.device_put(tok, self._tok_shard)
        max_gen = max(r.meta["gen"] for r, _ in batch)
        wave = _Wave(batch, state, tok, self.pos0, max_gen, self.lanes)
        # the prefill's sampled token is token #1 of every lane — it
        # COUNTS toward throughput (the old driver dropped it)
        wave.emitted.append(np.asarray(tok[:, 0]))
        self.call_log.append({"op": "prefill", "wall_s": wall,
                              "tokens": len(batch), "compile": compile_,
                              "lanes": len(batch)})
        if self.obs is not None:
            self.obs.record("serve.prefill", _runtime=True,
                            lanes=len(batch), wall_s=wall)
        if self.registry is not None:
            self.registry.observe("serve.batch_occupancy",
                                  len(batch) / self.lanes)
        return wave

    def _decode_slice(self, wave: _Wave) -> None:
        k = min(self.decode_slice, wave.max_gen - len(wave.emitted))
        if k <= 0:
            return
        compile_ = self._decode_calls == 0
        armed = self.fault_plan is not None
        t_start = time.time()
        with self.mesh:
            for _ in range(k):
                i = len(wave.emitted) - 1  # decode steps taken so far
                logits, wave.state = self._decode_fn(
                    self.params, wave.state, wave.tok,
                    jnp.int32(wave.pos0 + i))
                if armed:
                    spec = self.fault_plan.tick("serve.decode")
                    if spec is not None and spec.kind == "nan":
                        # poison exactly one lane's logits: a request-level
                        # fault, not a batch-level one
                        logits = logits.at[0].set(jnp.nan)
                    bad = np.asarray(jnp.any(~jnp.isfinite(logits), axis=-1))
                    wave.errored.update(int(j) for j in np.nonzero(bad)[0])
                key = jax.random.fold_in(
                    jax.random.PRNGKey(self.seed + 1),
                    (self._wave_seq << 16) + len(wave.emitted))
                wave.tok = self._sample(
                    key, jnp.nan_to_num(logits))[:, None]
                if self._tok_shard is not None:
                    wave.tok = jax.device_put(wave.tok, self._tok_shard)
                wave.emitted.append(np.asarray(wave.tok[:, 0]))
            jax.block_until_ready(wave.tok)
        wall = time.time() - t_start
        self._decode_calls += 1
        self.call_log.append({"op": "decode", "wall_s": wall,
                              "tokens": k * len(wave.batch),
                              "steps": k, "compile": compile_,
                              "lanes": len(wave.batch)})

    def _retire(self, wave: _Wave) -> None:
        tick = self.queue.tick
        grid = np.stack(wave.emitted, axis=1)  # (lanes, emitted)
        for i, (req, ticket) in enumerate(wave.batch):
            if i in wave.errored:
                if self.registry is not None:
                    self.registry.inc("serve.errors")
                ticket.set_error(RuntimeError(
                    f"request {req.rid}: poisoned decode (serve.decode)"),
                    tick)
                continue
            if self.registry is not None:
                self.registry.inc("serve.completed")
            ticket.set_result(grid[i, :req.meta["gen"]].copy(), tick)
        if self.obs is not None:
            self.obs.record("serve.retire", _runtime=True,
                            lanes=len(wave.batch),
                            tokens=len(wave.emitted) * len(wave.batch),
                            errored=len(wave.errored))

    def step(self) -> bool:
        """One scheduling quantum.  Activates a staged/new wave, decodes
        one slice, and interleaves the NEXT wave's prefill between slices
        of the active one.  Returns False when fully idle."""
        if self._active is None:
            self._active = self._staged or self._prefill_next()
            self._staged = None
            if self._active is None:
                return False
            return True
        self._decode_slice(self._active)
        if self._active.done:
            self._retire(self._active)
            self._active = None
            return True
        if self._staged is None and self.queue.depth() > 0:
            # prefill interleaved between decode slices: admission never
            # stalls the decode stream
            self._staged = self._prefill_next()
        return True

    def run(self, max_quanta: int = 100_000) -> None:
        """Drive until queue + waves drain."""
        for _ in range(max_quanta):
            busy = self.step()
            if not busy and self.queue.depth() == 0 \
                    and self._active is None and self._staged is None:
                return
        raise RuntimeError("LMEngine.run did not drain "
                           f"within {max_quanta} quanta")
