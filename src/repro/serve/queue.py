"""Request queue + continuous-batching scheduler for ``repro.serve``.

Deterministic by construction: scheduling state advances in logical
*ticks* (one per ``next_batch`` call), never on the wall clock, so a
replayed request stream schedules identically — the same property the
chaos harness (``repro.ft.inject``) relies on everywhere else.

Admission (``submit``) validates a request before it can occupy queue
space: known ``kind``, payload rank/width matching the engine's
contract, a byte cap on the payload, and finite values (a NaN/inf
payload would poison every other lane of the batch it joins — rejection
here is what makes the engine's batch-isolation guarantee cheap).  The
``serve.request`` fault site injects malformed/oversized arrivals on top
of real traffic: a ticked spec forces the same ``AdmissionError`` path a
genuinely bad request takes, kinds ``malformed``/``oversize``.

Scheduling (``next_batch``) is FIFO-with-aging: a request's effective
score is ``priority + aging * (tick - enqueue_tick)``, ties broken by
arrival order.  With ``aging > 0`` every waiting request's score grows
without bound, so any bounded-priority stream cannot starve it — the
no-starvation property ``tests/test_serve.py`` proves under sustained
high-priority load.  Batches are homogeneous in ``kind`` (one compiled
engine function per kind): the scheduler picks the top-scored request's
kind and fills the batch with same-kind requests in score order.

Batch buckets (``BucketSpec``): engines compile one program per bucket
size and pad the lane dimension up to the chosen bucket, so the jit
cache is bounded by ``len(sizes) * len(kinds)`` regardless of traffic —
the compile-cache contract documented in the README's Serving section.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["AdmissionError", "BucketSpec", "Request", "RequestQueue",
           "Ticket"]


class AdmissionError(ValueError):
    """Request rejected at the door (malformed, oversized, unknown kind,
    non-finite payload, or an injected ``serve.request`` fault)."""


@dataclass(frozen=True)
class BucketSpec:
    """Fixed set of batch shapes engines compile for.  ``bucket_for(n)``
    returns the smallest bucket holding ``n`` lanes (the largest bucket
    when ``n`` exceeds every size — the scheduler never hands out more
    than ``max(sizes)`` requests at once)."""

    sizes: Tuple[int, ...] = (1, 2, 4, 8)

    def __post_init__(self):
        sizes = tuple(sorted(set(int(s) for s in self.sizes)))
        if not sizes or sizes[0] < 1:
            raise ValueError(f"bucket sizes must be >= 1, got {self.sizes}")
        object.__setattr__(self, "sizes", sizes)

    @property
    def max_size(self) -> int:
        return self.sizes[-1]

    def bucket_for(self, n: int) -> int:
        for s in self.sizes:
            if s >= n:
                return s
        return self.sizes[-1]


@dataclass
class Request:
    rid: str
    kind: str
    payload: np.ndarray
    priority: float = 0.0
    enqueue_tick: int = 0
    meta: Dict[str, Any] = field(default_factory=dict)


class Ticket:
    """Caller-facing completion handle (a tiny future): ``result()``
    blocks until the engine resolves the request, re-raising a
    request-level error (e.g. an injected decode NaN) without implicating
    the rest of its batch."""

    def __init__(self, rid: str, enqueue_tick: int):
        self.rid = rid
        self.enqueue_tick = enqueue_tick
        self.complete_tick: Optional[int] = None
        self._event = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_ticks(self) -> Optional[int]:
        if self.complete_tick is None:
            return None
        return self.complete_tick - self.enqueue_tick

    def set_result(self, value: Any, tick: int) -> None:
        self._result = value
        self.complete_tick = tick
        self._event.set()

    def set_error(self, err: BaseException, tick: int) -> None:
        self._error = err
        self.complete_tick = tick
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} still pending")
        if self._error is not None:
            raise self._error
        return self._result


class RequestQueue:
    """Admission + FIFO-with-aging scheduling (see module docstring).

    ``dim``/``max_payload_bytes`` define the admission contract for array
    payloads; ``kinds`` the accepted request kinds; ``aging`` the
    ticks-to-priority exchange rate (0 disables aging — strict priority,
    which CAN starve; the default 1.0 cannot).  ``fault_plan`` arms the
    ``serve.request`` site; ``registry`` (a ``MetricsRegistry``) receives
    ``serve.submitted``/``serve.rejected`` counters and the
    ``serve.queue_depth`` gauge; ``obs`` (a ``FlightRecorder``) receives
    ``queue.submit``/``queue.reject``/``queue.schedule`` events."""

    def __init__(self, *, kinds: Sequence[str], dim: Optional[int] = None,
                 max_payload_bytes: int = 1 << 20, aging: float = 1.0,
                 fault_plan=None, registry=None, obs=None):
        self.kinds = tuple(kinds)
        self.dim = dim
        self.max_payload_bytes = int(max_payload_bytes)
        self.aging = float(aging)
        self.fault_plan = fault_plan
        self.registry = registry
        self.obs = obs
        self._lock = threading.Lock()
        self._pending: List[Tuple[Request, Ticket]] = []
        self._tick = 0
        self._seq = itertools.count()

    # -- introspection -------------------------------------------------------
    @property
    def tick(self) -> int:
        with self._lock:
            return self._tick

    def depth(self) -> int:
        with self._lock:
            return len(self._pending)

    # -- admission -----------------------------------------------------------
    def _validate(self, kind: str, payload) -> np.ndarray:
        if self.fault_plan is not None:
            spec = self.fault_plan.tick("serve.request")
            if spec is not None and spec.kind == "malformed":
                raise AdmissionError(
                    "rejected: injected malformed request (serve.request)")
            if spec is not None and spec.kind == "oversize":
                raise AdmissionError(
                    "rejected: injected oversized request (serve.request)")
        if kind not in self.kinds:
            raise AdmissionError(
                f"rejected: unknown kind {kind!r}; one of {self.kinds}")
        arr = np.asarray(payload)
        if not np.issubdtype(arr.dtype, np.floating) and \
                not np.issubdtype(arr.dtype, np.integer):
            raise AdmissionError(
                f"rejected: payload dtype {arr.dtype} is not numeric")
        if arr.nbytes > self.max_payload_bytes:
            raise AdmissionError(
                f"rejected: payload {arr.nbytes} B exceeds the "
                f"{self.max_payload_bytes} B cap")
        if self.dim is not None:
            if arr.ndim != 1 or arr.shape[0] != self.dim:
                raise AdmissionError(
                    f"rejected: payload shape {arr.shape} != ({self.dim},)")
        if np.issubdtype(arr.dtype, np.floating) and \
                not np.all(np.isfinite(arr)):
            raise AdmissionError(
                "rejected: non-finite payload would poison its batch")
        return arr

    def submit(self, kind: str, payload, *, priority: float = 0.0,
               rid: Optional[str] = None,
               meta: Optional[Dict[str, Any]] = None) -> Ticket:
        """Admit one request; raises ``AdmissionError`` on rejection.
        Returns a ``Ticket`` the engine resolves."""
        try:
            arr = self._validate(kind, payload)
        except AdmissionError:
            if self.registry is not None:
                self.registry.inc("serve.rejected")
            if self.obs is not None:
                self.obs.record("queue.reject", _runtime=True, req_kind=kind)
            raise
        with self._lock:
            n = next(self._seq)
            rid = rid if rid is not None else f"req-{n}"
            req = Request(rid, kind, arr, float(priority), self._tick,
                          dict(meta or {}))
            ticket = Ticket(rid, self._tick)
            self._pending.append((req, ticket))
            depth = len(self._pending)
        if self.registry is not None:
            self.registry.inc("serve.submitted")
            self.registry.set_gauge("serve.queue_depth", depth)
        if self.obs is not None:
            self.obs.record("queue.submit", _runtime=True, rid=rid,
                            req_kind=kind, priority=float(priority),
                            depth=depth)
        return ticket

    # -- scheduling ----------------------------------------------------------
    def _score(self, req: Request) -> float:
        return req.priority + self.aging * (self._tick - req.enqueue_tick)

    def next_batch(self, capacity: int,
                   kind: Optional[str] = None
                   ) -> List[Tuple[Request, Ticket]]:
        """Claim up to ``capacity`` same-kind requests by descending
        effective score (ties: arrival order).  ``kind=None`` uses the
        top-scored request's kind.  Advances the logical tick."""
        with self._lock:
            self._tick += 1
            if not self._pending:
                return []
            order = sorted(
                range(len(self._pending)),
                key=lambda i: (-self._score(self._pending[i][0]), i))
            if kind is None:
                kind = self._pending[order[0]][0].kind
            take = [i for i in order
                    if self._pending[i][0].kind == kind][:int(capacity)]
            taken = set(take)
            batch = [self._pending[i] for i in take]
            self._pending = [p for i, p in enumerate(self._pending)
                             if i not in taken]
            depth = len(self._pending)
            tick = self._tick
        if self.registry is not None:
            self.registry.set_gauge("serve.queue_depth", depth)
        if self.obs is not None and batch:
            self.obs.record("queue.schedule", _runtime=True, tick=tick,
                            req_kind=kind, batch=[r.rid for r, _ in batch],
                            waited=[tick - r.enqueue_tick for r, _ in batch],
                            depth=depth)
        return batch
