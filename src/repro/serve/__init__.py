"""``repro.serve`` — continuous-batching inference for the paper's ODE
workloads (CNF density/score, ODE classifiers) and the LM decode path,
with per-request checkpoint offload: each in-flight request's reverse-pass
checkpoint slots are keyed ``(request_id, step)`` in the spill/disk store,
written/prefetched/freed independently as requests join and leave the
batch.  See ``queue.py`` (admission + scheduling) and ``engine.py``
(ODEEngine / LMEngine); the README's "Serving" section has the tour.
"""
from repro.serve.engine import LMEngine, ODEEngine
from repro.serve.queue import (AdmissionError, BucketSpec, Request,
                               RequestQueue, Ticket)

__all__ = ["AdmissionError", "BucketSpec", "LMEngine", "ODEEngine",
           "Request", "RequestQueue", "Ticket"]
