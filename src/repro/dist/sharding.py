"""GSPMD sharding rules for every assigned architecture family.

The production layout on a v5e pod is a 2-D ``("data", "model")`` mesh
(multi-pod runs add a leading ``"pod"`` axis that behaves like extra data
parallelism for batches but keeps parameters pod-replicated, so the only
cross-pod traffic is the gradient all-reduce — see optim/compress.py).

Parameter rules (``param_specs``), per leaf role:

  embeddings / lm head   (V, D)       -> vocab on 'model', d_model on 'data'
                                         (matches the model-sharded vocab dim
                                         of the logits; see models/lm._logits)
  attention wq/wk/wv     (.., D, H, dh)-> heads on 'model' when H divides it
                                         (Megatron TP), else head_dim; d_model
                                         carries the FSDP 'data' shard
  attention wo           (.., H, dh, D)-> same, transposed
  dense FFN / channel-mix (.., D, F)   -> F on 'model' (column-parallel),
                          (.., F, D)   -> F on 'model' (row-parallel); the
                                         other dim carries 'data' (FSDP)
  MoE experts            (.., E, D, F) -> expert-parallel (E on 'model') when
                                         E divides the model axis (dbrx: 16
                                         experts on model=16), else
                                         TP-within-expert (F on 'model';
                                         mixtral: 8 experts on model=16)
  everything else        generic: largest divisible trailing dims get
                                         'data' then 'model'; small leaves
                                         (< _REPLICATE_MAX elems) replicate

Every pin is divisibility-guarded: a dim that the mesh axis product does not
divide is silently dropped (never an invalid spec), and each mesh axis is
used at most once per leaf.  Stacked-scan leaves (``blocks/scan/...``) never
shard their leading unit dim — ``lax.scan`` slices it every step.

``constrain_batch`` / ``constrain_dims`` are the in-graph counterparts: they
apply ``lax.with_sharding_constraint`` under an active mesh and are exact
no-ops outside one, so model code stays mesh-agnostic.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
from jax import tree_util as jtu
from jax.sharding import NamedSharding, PartitionSpec as P

# mesh axes that carry the global batch, outermost first
BATCH_AXES = ("pod", "data")
# leaves smaller than this replicate under the generic rule (norm scales,
# biases, decay params): sharding them saves nothing and costs collectives
_REPLICATE_MAX = 65536


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

_warned_no_mesh_api = False


def _current_mesh():
    """The ambient physical mesh (``with mesh:``), or None outside one."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover - jax internals moved
        # warn loudly ONCE instead of silently degrading every sharding
        # constraint to a no-op (which would compile models fully replicated)
        global _warned_no_mesh_api
        if not _warned_no_mesh_api:
            _warned_no_mesh_api = True
            import warnings
            warnings.warn(
                "repro.dist.sharding could not read the ambient mesh from "
                "jax internals; all sharding constraints are no-ops. "
                "Update _current_mesh for this jax version.")
    return None


def _axis_size(mesh, name: str) -> int:
    if mesh is None or name not in mesh.axis_names:
        return 1
    return int(mesh.shape[name])


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is split over, in outer-to-inner order."""
    if mesh is None:
        return ()
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def _batch_spec_entry(mesh, batch: int):
    """The PartitionSpec entry for a batch dim: tuple for multi-pod meshes,
    plain axis name for single-pod, None when the batch doesn't divide."""
    ba = batch_axes(mesh)
    n = math.prod(_axis_size(mesh, a) for a in ba)
    if not ba or n <= 1 or batch % n != 0:
        return None
    return ba if len(ba) > 1 else ba[0]


def to_shardings(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jtu.tree_map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------

def _spec_from_pins(shape: Sequence[int], pins: Mapping[int, Any], mesh) -> P:
    """Build a PartitionSpec from {dim: axis-or-axes} pins, dropping any pin
    whose axis product does not divide the dim (and any axis already used —
    GSPMD allows each mesh axis at most once per spec)."""
    out: list = [None] * len(shape)
    used: set = set()
    for d, ax in pins.items():
        if ax is None or not (0 <= d < len(shape)):
            continue
        axes = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        axes = tuple(a for a in axes
                     if a in getattr(mesh, "axis_names", ()) and a not in used)
        if not axes:
            continue
        n = math.prod(int(mesh.shape[a]) for a in axes)
        if n <= 1 or shape[d] % n != 0:
            continue
        used.update(axes)
        out[d] = axes if len(axes) > 1 else axes[0]
    return P(*out)


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", p)) for p in path)


def _generic_pins(shp: Sequence[int], keys: Sequence[str], mesh) -> Dict[int, str]:
    """Fallback rule: 'data' on the largest divisible dim, 'model' on the
    next; never the stacked-scan unit dim."""
    start = 1 if "scan" in keys and len(shp) > 1 else 0
    dims = sorted(range(start, len(shp)), key=lambda d: -shp[d])
    pins: Dict[int, str] = {}
    for ax in ("data", "model"):
        n = _axis_size(mesh, ax)
        if n <= 1:
            continue
        for d in dims:
            if d not in pins and shp[d] % n == 0:
                pins[d] = ax
                break
    return pins


_COL_NAMES = ("w_gate", "w_up", "w_in", "w_in_gate", "w_in_rnn",
              "w_r", "w_k", "w_v", "w_g", "w_lora", "w_a", "w_i")
_ROW_NAMES = ("w_down", "w_out", "w_o")


def param_specs(cfg, shapes, mesh):
    """Per-leaf PartitionSpec tree for ``lm.init_params(cfg, ...)`` shapes.

    ``shapes`` is the eval_shape pytree; the returned tree has the identical
    structure with a PartitionSpec at every array leaf.
    """
    del cfg  # rules key off leaf paths/shapes; kept for per-family overrides
    nm = _axis_size(mesh, "model")

    def rule(path, leaf):
        keys = _path_keys(path)
        name = keys[-1]
        shp = tuple(leaf.shape)
        nd = len(shp)

        # ---- MoE expert banks: (.., E, D, F) / (.., E, F, D)
        if "moe" in keys and name in ("w_gate", "w_up", "w_down") and nd >= 3:
            n_exp = shp[nd - 3]
            if nm > 1 and n_exp % nm == 0:
                # expert parallelism: one (or more) experts per model shard
                pins = {nd - 3: "model", nd - 2: "data"}
            elif name == "w_down":          # TP within expert: F on 'model'
                pins = {nd - 2: "model", nd - 1: "data"}
            else:
                pins = {nd - 1: "model", nd - 2: "data"}
        # ---- attention projections
        elif name in ("wq", "wk", "wv") and nd >= 3:
            # (.., D, Hx, dh): heads on 'model' when divisible, else head_dim
            pins = {nd - 3: "data"}
            pins[nd - 2 if nm > 1 and shp[nd - 2] % nm == 0 else nd - 1] = \
                "model"
        elif name == "wo" and nd >= 3:
            # (.., H, dh, D)
            pins = {nd - 1: "data"}
            pins[nd - 3 if nm > 1 and shp[nd - 3] % nm == 0 else nd - 2] = \
                "model"
        # ---- embeddings / lm head / learned positions: (V, D)
        elif name == "table" and nd == 2:
            pins = {0: "model", 1: "data"}
        # ---- dense 2-D projections (FFN, channel-mix, rwkv/rglru mixers)
        elif name in _COL_NAMES and nd >= 2:
            pins = {nd - 1: "model", nd - 2: "data"}
        elif name in _ROW_NAMES and nd >= 2:
            pins = {nd - 2: "model", nd - 1: "data"}
        # ---- everything else
        else:
            if math.prod(shp) < _REPLICATE_MAX:
                return P(*(None,) * nd)
            pins = _generic_pins(shp, keys, mesh)
        return _spec_from_pins(shp, pins, mesh)

    return jtu.tree_map_with_path(rule, shapes)


def opt_state_specs(pspecs, opt_shape):
    """Optimizer-state specs: AdamW moments mirror the param tree leaf-for-
    leaf (the FSDP shards of a param apply to its m and v), scalars
    replicate."""
    from repro.optim.adamw import AdamWState
    del opt_shape  # structure is fixed by AdamWState; kept for call-site symmetry
    return AdamWState(step=P(), m=pspecs, v=pspecs)


def batch_specs(cfg, cell, mesh) -> Dict[str, P]:
    """Input-batch specs: the global batch dim is split over every batch
    axis present (multi-pod: ``("pod", "data")``); everything else stays
    unsharded (the token dims are consumed by batch-parallel ops)."""
    b = _batch_spec_entry(mesh, cell.global_batch)
    specs = {"tokens": P(b, None), "targets": P(b, None)}
    if cfg.frontend == "vision_stub":
        specs["patches"] = P(b, None, None)
    if cfg.family == "encdec":
        specs["frames"] = P(b, None, None)
    return specs


def decode_state_specs(cfg, cell, state_shape, mesh):
    """Decode-state (KV cache / recurrent state) specs.

    Batched decode shards the batch dim over the data axes.  B=1 long-context
    decode cannot — there the KV cache sequence dim is sharded over 'data'
    instead (sequence parallelism), which is what makes a 512k cache fit.
    KV head (or head_dim) carries 'model' when divisible, mirroring the
    attention TP of param_specs.
    """
    nm = _axis_size(mesh, "model")
    batch_ok = _batch_spec_entry(mesh, cell.global_batch) is not None

    def rule(path, leaf):
        keys = _path_keys(path)
        shp = tuple(leaf.shape)
        nd = len(shp)
        bdim = 1 if "scan" in keys else 0
        pins: Dict[int, Any] = {}
        if batch_ok:
            pins[bdim] = batch_axes(mesh)
        else:
            # sequence parallelism over the max_seq dim (KV caches only)
            for d in range(nd):
                if d != bdim and shp[d] == cell.seq_len:
                    pins[d] = "data"
                    break
        if keys[-1] in ("k", "v", "xk", "xv") and nd >= 2:
            # (.., B, S, Hkv, dh): model on kv heads, else head_dim
            pins[nd - 2 if nm > 1 and shp[nd - 2] % nm == 0 else nd - 1] = \
                "model"
        return _spec_from_pins(shp, pins, mesh)

    return jtu.tree_map_with_path(rule, state_shape)


# ---------------------------------------------------------------------------
# in-graph constraints (no-ops outside a mesh)
# ---------------------------------------------------------------------------

def constrain_dims(x, pins: Mapping[int, Any]):
    """``lax.with_sharding_constraint`` pinning {dim: mesh-axis(es)} under the
    ambient mesh; drops non-divisible pins; identity outside a mesh."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = _spec_from_pins(x.shape, pins, mesh)
    if all(s is None for s in spec):
        return x  # a trivial constraint would force full replication
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(x, extra: Optional[Mapping[int, Any]] = None):
    """Keep dim 0 split over the batch axes (plus optional extra dim pins:
    e.g. the model-sharded vocab dim of the logits).  No-op outside a mesh."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    pins: Dict[int, Any] = {}
    ba = batch_axes(mesh)
    if ba:
        pins[0] = ba
    if extra:
        pins.update(extra)
    return constrain_dims(x, pins)
