"""Distribution layer: GSPMD sharding rules + pipeline parallelism.

``sharding``  — per-family PartitionSpec rules for params / optimizer state /
                batches / decode state, and in-graph sharding constraints
                (``constrain_batch`` / ``constrain_dims``) that are no-ops
                outside a mesh context.
``pipeline``  — microbatched pipeline parallelism over a ``pod`` mesh axis
                via ``shard_map`` (GPipe schedule, exact vs. the sequential
                reference).
"""
from repro.dist import pipeline, sharding

__all__ = ["pipeline", "sharding"]
