"""Microbatched pipeline parallelism over a ``pod`` mesh axis (GPipe
schedule) via ``shard_map``.

The layer stack's leading axis is split across the ``pod`` axis so each
stage holds ``n_layers / n_stages`` consecutive layers.  The batch is cut
into ``n_micro`` microbatches that stream through the stages: at every tick
each stage applies its local layers to its current microbatch and passes the
result to the next stage with ``ppermute``; the last stage accumulates
finished microbatches.  Total ticks = ``n_micro + n_stages - 1`` (the usual
bubble).  Because every microbatch traverses the same per-layer ops in the
same order as a sequential sweep, the result is exact (not just close) —
tested against the unsharded reference in tests/test_dist.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import tree_util as jtu
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _apply_layers(layer_fn, params, h, n_layers):
    """Sequentially apply ``n_layers`` stacked layers (leading-axis params)."""
    def body(carry, p):
        return layer_fn(p, carry), None

    out, _ = jax.lax.scan(body, h, params, length=n_layers)
    return out


def pipeline_apply(layer_fn, params, x, *, mesh, n_micro: int,
                   axis: str = "pod"):
    """Apply a stacked layer pytree to ``x`` with pipeline parallelism.

    layer_fn(p, h) -> h  must preserve h's shape (residual blocks).
    ``params`` leaves carry the layer index on dim 0; ``n_layers`` must be a
    multiple of ``mesh.shape[axis]`` and ``x.shape[0]`` of ``n_micro``.
    """
    n_stages = int(mesh.shape[axis])
    n_layers = jtu.tree_leaves(params)[0].shape[0]
    if n_stages == 1:
        return _apply_layers(layer_fn, params, x, n_layers)
    if n_layers % n_stages != 0:
        raise ValueError(f"n_layers={n_layers} not divisible by "
                         f"{axis}={n_stages}")
    b = x.shape[0]
    if b % n_micro != 0:
        raise ValueError(f"batch {b} not divisible by n_micro={n_micro}")
    mb = b // n_micro
    per_stage = n_layers // n_stages
    fwd = [(j, j + 1) for j in range(n_stages - 1)]

    def stage(local_params, xg):
        i = jax.lax.axis_index(axis)
        micro = xg.reshape((n_micro, mb) + xg.shape[1:])
        n_ticks = n_micro + n_stages - 1

        def tick(t, carry):
            cur, outbuf = carry
            feed = jax.lax.dynamic_index_in_dim(
                micro, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
            h = jnp.where(i == 0, feed, cur)
            h = _apply_layers(layer_fn, local_params, h, per_stage)
            # the last stage finishes microbatch t - (n_stages - 1) at tick t
            w = t - (n_stages - 1)
            wc = jnp.clip(w, 0, n_micro - 1)
            write = (i == n_stages - 1) & (w >= 0)
            slot = jax.lax.dynamic_index_in_dim(outbuf, wc, 0, keepdims=False)
            outbuf = jax.lax.dynamic_update_index_in_dim(
                outbuf, jnp.where(write, h, slot), wc, 0)
            cur = jax.lax.ppermute(h, axis, fwd)
            return cur, outbuf

        cur0 = jnp.zeros_like(micro[0])
        out0 = jnp.zeros_like(micro)
        _, outbuf = jax.lax.fori_loop(0, n_ticks, tick, (cur0, out0))
        # only the last stage holds real outputs; psum replicates them
        outbuf = jax.lax.psum(
            jnp.where(i == n_stages - 1, outbuf, jnp.zeros_like(outbuf)),
            axis)
        return outbuf.reshape((b,) + xg.shape[1:])

    pspecs = jtu.tree_map(lambda _: P(axis), params)
    fn = shard_map(stage, mesh=mesh, in_specs=(pspecs, P()),
                   out_specs=P(), check_rep=False)
    return fn(params, x)
