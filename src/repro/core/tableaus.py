"""Butcher tableaus for the time integrators used in the paper.

Explicit methods: euler, midpoint, heun, bosh3, rk4, dopri5 (with embedded
4th-order solution for adaptivity).  Implicit methods: beuler (backward
Euler), cn (Crank-Nicolson / trapezoid), expressed as theta-methods.

A tableau is a small frozen dataclass of numpy arrays; everything here is
trace-time constant so plain numpy (not jnp) is deliberate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ButcherTableau:
    name: str
    a: np.ndarray          # (s, s) stage coefficients (strictly lower triangular if explicit)
    b: np.ndarray          # (s,) solution weights
    c: np.ndarray          # (s,) stage times
    b_err: Optional[np.ndarray] = None  # (s,) embedded-solution weights (for adaptivity)
    order: int = 1
    fsal: bool = False     # first-same-as-last (dopri5): stage s of step n == stage 1 of step n+1

    @property
    def num_stages(self) -> int:
        return len(self.b)

    @property
    def explicit(self) -> bool:
        return bool(np.allclose(self.a, np.tril(self.a, -1)))


def _tab(name, a, b, c, b_err=None, order=1, fsal=False):
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    if b_err is not None:
        b_err = np.asarray(b_err, dtype=np.float64)
    return ButcherTableau(name=name, a=a, b=b, c=c, b_err=b_err, order=order, fsal=fsal)


EULER = _tab("euler", [[0.0]], [1.0], [0.0], order=1)

MIDPOINT = _tab(
    "midpoint",
    [[0.0, 0.0], [0.5, 0.0]],
    [0.0, 1.0],
    [0.0, 0.5],
    order=2,
)

HEUN = _tab(
    "heun",
    [[0.0, 0.0], [1.0, 0.0]],
    [0.5, 0.5],
    [0.0, 1.0],
    order=2,
)

# Bogacki-Shampine 3(2)
BOSH3 = _tab(
    "bosh3",
    [
        [0.0, 0.0, 0.0, 0.0],
        [1 / 2, 0.0, 0.0, 0.0],
        [0.0, 3 / 4, 0.0, 0.0],
        [2 / 9, 1 / 3, 4 / 9, 0.0],
    ],
    [2 / 9, 1 / 3, 4 / 9, 0.0],
    [0.0, 1 / 2, 3 / 4, 1.0],
    b_err=[7 / 24, 1 / 4, 1 / 3, 1 / 8],
    order=3,
    fsal=True,
)

RK4 = _tab(
    "rk4",
    [
        [0.0, 0.0, 0.0, 0.0],
        [0.5, 0.0, 0.0, 0.0],
        [0.0, 0.5, 0.0, 0.0],
        [0.0, 0.0, 1.0, 0.0],
    ],
    [1 / 6, 1 / 3, 1 / 3, 1 / 6],
    [0.0, 0.5, 0.5, 1.0],
    order=4,
)

# Dormand-Prince 5(4)
DOPRI5 = _tab(
    "dopri5",
    [
        [0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        [1 / 5, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        [3 / 40, 9 / 40, 0.0, 0.0, 0.0, 0.0, 0.0],
        [44 / 45, -56 / 15, 32 / 9, 0.0, 0.0, 0.0, 0.0],
        [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729, 0.0, 0.0, 0.0],
        [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656, 0.0, 0.0],
        [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0],
    ],
    [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0],
    [0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0],
    b_err=[5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200, 187 / 2100, 1 / 40],
    order=5,
    fsal=True,
)

# Theta methods (implicit): u_{n+1} = u_n + h*[(1-theta) f(u_n) + theta f(u_{n+1})]
# theta=1   -> backward Euler
# theta=1/2 -> Crank-Nicolson (trapezoid)
BEULER_THETA = 1.0
CN_THETA = 0.5

EXPLICIT_TABLEAUS = {
    "euler": EULER,
    "midpoint": MIDPOINT,
    "heun": HEUN,
    "bosh3": BOSH3,
    "rk4": RK4,
    "dopri5": DOPRI5,
}

IMPLICIT_METHODS = ("beuler", "cn")


def get_tableau(name: str) -> ButcherTableau:
    try:
        return EXPLICIT_TABLEAUS[name]
    except KeyError:
        raise ValueError(
            f"unknown explicit method {name!r}; available: {sorted(EXPLICIT_TABLEAUS)}"
        ) from None
