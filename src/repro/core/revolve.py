"""Binomial (revolve-style) checkpoint scheduling for multistage integrators.

Implements the checkpointing model of the paper (Zhang & Constantinescu,
"Revolve-based adjoint checkpointing for multistage time integration"):

* a checkpoint stores the step state AND the step's stage derivatives
  (N_s + 1 vectors), so the adjoint of a checkpointed step needs no
  recomputation at all;
* during the *forward sweep* up to N_c checkpoints may be placed for free;
* during the *reverse sweep*, freed slots are re-placed while re-advancing.

``optimal_extra_steps(n, c)`` computes the minimal number of recomputed
(extra forward) steps by exact dynamic programming, and Prop. 2 of the paper
gives the closed form it must match (tested in tests/test_revolve.py):

    p~(N_t, N_c) = (t-1) N_t - binom(N_c + t, t - 1) + 1,
    with t the unique integer s.t. binom(N_c+t-1, t-1) < N_t <= binom(N_c+t, t).

The schedule is produced at *trace time* (N_t and N_c are Python ints), so
the reverse pass is unrolled into segments of `lax.scan` — XLA sees a graph
whose live set is exactly the checkpoint set.
"""
from __future__ import annotations

import functools
from math import comb
from typing import List, Tuple

_INF = float("inf")


# ---------------------------------------------------------------------------
# Prop. 2 closed form
# ---------------------------------------------------------------------------

def prop2_optimal_extra_steps(n_t: int, n_c: int) -> int:
    """The paper's Prop. 2 closed form for the minimal recomputation count."""
    if n_t <= 1 or n_c >= n_t - 1:
        return 0
    if n_c == 0:
        # degenerate: only the segment-boundary state is held; classic
        # quadratic sweep (not covered by the binomial formula's domain).
        return n_t * (n_t - 1) // 2 - (n_t - 1)
    t = 1
    while not (comb(n_c + t - 1, t - 1) < n_t <= comb(n_c + t, t)):
        t += 1
        if t > 10_000:  # pragma: no cover
            raise RuntimeError("failed to bracket t in Prop. 2")
    return (t - 1) * n_t - comb(n_c + t, t - 1) + 1


# ---------------------------------------------------------------------------
# exact DP
#
# REV(n, c): segment of n steps whose boundary checkpoint (state + stages of
#   the segment's first step) is held; the forward sweep through the segment
#   has already happened and placed nothing inside; c slots are free.
#   Value = minimal extra forward steps to adjoint the whole segment.
#
# SWEEP(n, c): same, but the forward sweep through the segment has NOT yet
#   happened and may place checkpoints for free as it goes.  This is the
#   top-level problem for the initial forward pass of the ODE solve.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _rev(n: int, c: int) -> float:
    if n <= 1:
        return 0.0
    if c <= 0:
        # classic Revolve accounting (and the paper's): re-advancing needs a
        # free slot to hold the advanced-to state, so a segment longer than
        # one step is infeasible with zero free checkpoints.
        return _INF
    best = _INF
    for m in range(1, n):
        cand = m + _rev(n - m, c - 1) + _rev(m, c)
        if cand < best:
            best = cand
    return best


@functools.lru_cache(maxsize=None)
def _rev_argmin(n: int, c: int) -> int:
    best, arg = _INF, 1
    for m in range(1, n):
        cand = m + _rev(n - m, c - 1) + _rev(m, c)
        if cand < best:
            best, arg = cand, m
    return arg


@functools.lru_cache(maxsize=None)
def _sweep(n: int, c: int) -> float:
    if n <= 1:
        return 0.0
    if c <= 0:
        return _INF
    best = _rev(n, c)  # place nothing during the sweep
    for m in range(1, n):
        cand = _sweep(n - m, c - 1) + _rev(m, c)
        if cand < best:
            best = cand
    return best


@functools.lru_cache(maxsize=None)
def _sweep_argmin(n: int, c: int) -> int:
    """0 means 'place nothing'; m>=1 means first sweep checkpoint at m."""
    best, arg = _rev(n, c), 0
    if c >= 1:
        for m in range(1, n):
            cand = _sweep(n - m, c - 1) + _rev(m, c)
            if cand < best:
                best, arg = cand, m
    return arg


def optimal_extra_steps(n_t: int, n_c: int) -> int:
    """Minimal recomputed forward steps (exact DP; == Prop. 2 on its domain)."""
    v = _sweep(n_t, n_c)
    if v == _INF:
        raise ValueError(f"infeasible: n_t={n_t}, n_c={n_c}")
    return int(v)


def sweep_checkpoint_positions(n_t: int, n_c: int) -> List[int]:
    """Positions (step indices) at which the initial forward sweep stores
    checkpoints (state + stages of the step starting there).  Position 0 is
    the segment boundary and is always held implicitly."""
    pos: List[int] = []
    off, n, c = 0, n_t, n_c
    while n > 1:
        m = _sweep_argmin(n, c)
        if m == 0:
            break
        pos.append(off + m)
        off, n, c = off + m, n - m, c - 1
    return pos


# ---------------------------------------------------------------------------
# schedule actions for the reverse pass
# ---------------------------------------------------------------------------
# The reverse executor works on segments between sweep checkpoints, right to
# left.  Within a segment it follows the REV policy recursively.  Actions:
#   ("advance", start, n)   re-run n forward steps from `start`, keeping the
#                           arrival state+stages as a new checkpoint
#   ("adjoint", idx)        adjoint one step at index idx (state+stages held)
# The executor in core/adjoint.py interprets these with traced values; this
# module only decides *what* to do (pure Python ints).


def reverse_schedule(n_t: int, n_c: int) -> List[Tuple]:
    """Full reverse schedule given the sweep placed checkpoints per
    ``sweep_checkpoint_positions``.  Returns a flat action list."""
    actions: List[Tuple] = []

    def rev_segment(start: int, n: int, c: int) -> None:
        # boundary checkpoint at `start` is held (with stages)
        if n <= 0:
            return
        if n == 1:
            actions.append(("adjoint", start))
            return
        if c == 0:  # pragma: no cover — the DP never schedules this
            raise RuntimeError(
                f"infeasible reverse segment: n={n} steps, 0 free slots")
        m = _rev_argmin(n, c)
        actions.append(("advance", start, m))
        rev_segment(start + m, n - m, c - 1)
        actions.append(("free", start + m))
        rev_segment(start, m, c)

    # segments defined by sweep checkpoints
    pos = [0] + sweep_checkpoint_positions(n_t, n_c)
    free_slots = n_c - (len(pos) - 1)  # slots not consumed by the sweep
    # process segments right to left; after each segment its boundary slot frees
    for i in range(len(pos) - 1, -1, -1):
        start = pos[i]
        end = pos[i + 1] if i + 1 < len(pos) else n_t
        rev_segment(start, end - start, free_slots)
        if i > 0:
            actions.append(("free", start))
        free_slots += 1
    return actions


def schedule_extra_steps(actions) -> int:
    """Count recomputed steps in an action list (for tests)."""
    return sum(a[2] for a in actions if a[0] == "advance")
