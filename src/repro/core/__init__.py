"""PNODE core: high-level discrete adjoint ODE solves with checkpointing."""
from repro.core.adjoint import (POLICIES, checkpoint_floats, nfe_backward,
                                nfe_forward, odeint)
from repro.core.adaptive import AdaptiveInfo, odeint_adaptive
from repro.core.depth_ode import ODEBlock, checkpointed_scan
from repro.core.implicit import (IMPLICIT_METHODS, IMPLICIT_POLICIES,
                                 ImplicitStats, implicit_checkpoint_floats,
                                 implicit_nfe_backward, implicit_nfe_forward,
                                 implicit_step, is_implicit_method,
                                 odeint_implicit)
from repro.core.integrators import solve_fixed, solve_fixed_trajectory
from repro.core.revolve import (optimal_extra_steps,
                                prop2_optimal_extra_steps, reverse_schedule,
                                sweep_checkpoint_positions)

__all__ = [
    "POLICIES", "odeint", "odeint_implicit", "odeint_adaptive", "ODEBlock",
    "checkpointed_scan", "solve_fixed", "solve_fixed_trajectory",
    "optimal_extra_steps", "prop2_optimal_extra_steps", "reverse_schedule",
    "sweep_checkpoint_positions", "nfe_forward", "nfe_backward",
    "checkpoint_floats", "implicit_step", "AdaptiveInfo",
    "IMPLICIT_METHODS", "IMPLICIT_POLICIES", "ImplicitStats",
    "is_implicit_method", "implicit_nfe_forward", "implicit_nfe_backward",
    "implicit_checkpoint_floats",
]
