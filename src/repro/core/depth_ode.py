"""PNODE checkpointing applied over *depth*: the LM layer-stack scan.

A residual stack  u_{l+1} = u_l + F(u_l, theta_l)  is forward Euler with
h = 1 and a layer-indexed vector field — the ResNet<->ODE duality the paper
builds on.  This module provides ``checkpointed_scan``: a scan over stacked
per-layer parameters whose *gradient strategy* is selectable, mirroring the
paper's adjoint policies at the depth level:

  remat='none'     NODE-naive analogue — XLA stores every layer's residuals.
  remat='full'     ACA analogue — every layer recomputed in the reverse pass
                   (jax.checkpoint around the layer body).
  remat='sqrt'     two-level scan-of-scans: sqrt(N_l) segment boundaries live,
                   one recompute per layer — binomial checkpointing's sweet
                   spot for XLA (segment boundaries are the checkpoints).
  remat='revolve'  trace-time binomial schedule over layers (N_c slots); the
                   paper's Prop-2-optimal recompute at a given memory budget.
                   Implemented with jax.checkpoint on unrolled segments.

For true continuous-depth blocks (shared weights, arbitrary RK scheme) use
``ODEBlock`` which delegates to core.adjoint.odeint.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.core.adjoint import odeint
from repro.core.integrators import PyTree

LayerFn = Callable[[PyTree, PyTree], PyTree]  # (carry, layer_params) -> carry


def _plain_scan(layer_fn: LayerFn, u0: PyTree, stacked: PyTree) -> PyTree:
    def body(c, p):
        return layer_fn(c, p), None

    out, _ = jax.lax.scan(body, u0, stacked)
    return out


def checkpointed_scan(layer_fn: LayerFn, u0: PyTree, stacked_params: PyTree,
                      n_layers: int, remat: str = "sqrt",
                      ncheck: int | None = None) -> PyTree:
    """Run u <- layer_fn(u, params_l) for l = 0..n_layers-1 with the chosen
    depth-checkpointing policy.  ``stacked_params`` has a leading N_l axis."""
    if remat == "none":
        return _plain_scan(layer_fn, u0, stacked_params)

    if remat == "full":
        def body(c, p):
            return jax.checkpoint(layer_fn)(c, p), None

        out, _ = jax.lax.scan(body, u0, stacked_params)
        return out

    if remat == "sqrt":
        seg = max(1, int(math.sqrt(n_layers)))
        n_seg = math.ceil(n_layers / seg)
        if n_seg * seg != n_layers:
            # fall back to the largest divisor <= sqrt for clean reshapes
            seg = 1
            for d in range(int(math.sqrt(n_layers)), 0, -1):
                if n_layers % d == 0:
                    seg = d
                    break
            n_seg = n_layers // seg
        resh = jtu.tree_map(
            lambda p: p.reshape((n_seg, seg) + p.shape[1:]), stacked_params)

        @jax.checkpoint
        def segment(c, ps):
            return _plain_scan(layer_fn, c, ps)

        def outer(c, ps):
            return segment(c, ps), None

        out, _ = jax.lax.scan(outer, u0, resh)
        return out

    if remat == "revolve":
        if ncheck is None:
            raise ValueError("remat='revolve' requires ncheck")
        from repro.core.revolve import sweep_checkpoint_positions

        positions = [0] + sweep_checkpoint_positions(n_layers, ncheck) + [n_layers]
        u = u0
        for a, b in zip(positions[:-1], positions[1:]):
            seg_params = jtu.tree_map(lambda p: p[a:b], stacked_params)

            @jax.checkpoint
            def segment(c, ps):
                return _plain_scan(layer_fn, c, ps)

            u = segment(u, seg_params)
        return u

    raise ValueError(f"unknown remat policy {remat!r}")


class ODEBlock:
    """Continuous-depth block: integrates du/dt = F(u, theta, t) with any
    explicit method and any PNODE adjoint policy (shared weights over depth)."""

    def __init__(self, vf, *, n_steps: int = 4, method: str = "rk4",
                 adjoint: str = "pnode", ncheck: int | None = None,
                 t0: float = 0.0, t1: float = 1.0):
        self.vf = vf
        self.n_steps = n_steps
        self.method = method
        self.adjoint = adjoint
        self.ncheck = ncheck
        self.t0 = t0
        self.dt = (t1 - t0) / n_steps

    def __call__(self, u0: PyTree, theta: PyTree) -> PyTree:
        return odeint(self.vf, u0, theta, dt=self.dt, n_steps=self.n_steps,
                      t0=self.t0, method=self.method, adjoint=self.adjoint,
                      ncheck=self.ncheck)
