"""High-level discrete adjoint ODE solves with checkpointing (the paper's core).

``odeint(f, u0, theta, ...)`` integrates du/dt = f(u, theta, t) for a fixed
number of steps and differentiates with a selectable *adjoint policy*.  Every
baseline of the paper's Table 2 is implemented:

  naive       NODE-naive: differentiate straight through the `lax.scan`
              (deepest graph; XLA stores per-step residuals: O(N_t N_s N_l)).
  continuous  NODE-cont (vanilla neural ODE): integrate the continuous
              adjoint ODE backward in time, re-solving the state backward.
              NOT reverse-accurate (O(h^2) per-step discrepancy, Prop. 1).
  anode       ANODE: checkpoint only the block input; in the reverse pass,
              recompute the whole forward and backprop through it.
  aca         ACA: checkpoint the state at every step; reverse pass
              re-executes each step under low-level AD (jax.vjp of the step).
  pnode       the paper's method: checkpoint states AND stage values at every
              step; reverse pass uses the high-level per-stage adjoint
              (rk_adjoint_step) — no recomputation, graph depth O(N_l).
  pnode2      PNODE2 variant: checkpoint solutions only; one step recompute
              per reverse step.
  revolve     PNODE with the binomial checkpointing schedule of Prop. 2
              (`ncheck` slots), trading recomputation for memory.

Gradients are returned w.r.t. ``u0`` and ``theta``.  ``t0``/``dt`` are static.

mem — Table-2 cost model and budget planning
--------------------------------------------
Each policy is one point on the paper's memory/recompute curve; the mapping
to Table 2 (checkpoint storage in state-vectors, NFE-B in f evaluations) is
implemented analytically by ``checkpoint_floats`` / ``nfe_backward`` below
and, in byte units with working-set terms, by ``repro.mem.model``.  Two
knobs select the point automatically instead of by hand:

  ``adjoint="auto", mem_budget=B``  the ``repro.mem.planner`` solves for
      the cheapest reverse-accurate policy (and the minimal-recompute
      ``ncheck`` via Prop. 2) whose reverse pass fits in B bytes, verifying
      the choice against the lowered HLO by default (``mem_verify``).
  ``offload="host" | "spill"``      checkpoints are written through a
      ``repro.mem.offload`` store instead of riding the custom_vjp
      residuals: "host" moves revolve's trace-time checkpoints to
      pinned-host memory, "spill" streams scanned pnode / revolve
      checkpoints into a host-side callback store so device-live memory is
      O(ncheck) (revolve) or O(1) state copies (pnode) regardless of N_t.
      Gradients are bitwise-identical to the in-device policies.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.obs.profile import scope
from repro.core import revolve as revolve_mod
from repro.core.integrators import (
    PyTree,
    VectorField,
    rk_adjoint_step,
    rk_combine,
    rk_stages,
    rk_step,
    solve_fixed,
    tree_add,
    tree_scale,
    tree_stack,
    tree_unstack,
    tree_zeros_like,
)
from repro.core.tableaus import get_tableau

POLICIES = ("naive", "continuous", "anode", "aca", "pnode", "pnode2",
            "revolve", "revolve2")


def _t_of(t0: float, dt: float, n) -> Any:
    return t0 + dt * n


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

_OFFLOAD_TIERS = (None, "device", "host", "spill", "disk")


def _validate_ncheck(adjoint: str, ncheck, n_steps: int) -> int:
    if ncheck is None:
        raise ValueError(
            f"adjoint={adjoint!r} requires ncheck (the number of checkpoint "
            "slots); pass it explicitly, or use adjoint='auto' with "
            "mem_budget=<bytes> and the planner will pick the minimal-"
            "recompute ncheck for the budget (Prop. 2)")
    ncheck = int(ncheck)
    if ncheck <= 0:
        raise ValueError(
            f"ncheck must be a positive number of checkpoint slots, got "
            f"{ncheck} (the reverse sweep needs at least one free slot to "
            "re-advance a segment)")
    if ncheck >= n_steps:
        raise ValueError(
            f"ncheck={ncheck} must be < n_steps={n_steps}: with a slot for "
            "every step there is nothing to recompute — that point of the "
            "memory/compute curve is adjoint='pnode' (or let "
            "adjoint='auto' choose)")
    return ncheck


#: policies whose reverse pass never differentiates *through* a step graph
#: (states/stages are checkpointed, the adjoint is the explicit per-stage
#: recursion) — the only ones the fused Pallas stage kernels apply to:
#: Pallas calls have no AD rules, so policies that jax.vjp through the
#: step (naive/continuous/anode/aca) must keep the unfused chain.
_FUSED_POLICIES = ("pnode", "pnode2", "revolve", "revolve2")


def _reject_vmap_offload(u0: PyTree, theta: PyTree, where: str) -> None:
    """vmap over a SLOT-ADDRESSED offload path fails deep inside the
    callback machinery with an opaque trace error (or, worse, aliases
    host-dict slots and returns wrong gradients); detect it up front.
    Only the trace-time slot-addressed paths (revolve/revolve2, and the
    host tier they imply) still reject: the scanned pnode spill/disk path
    composes with vmap — its segment-batched callbacks broadcast the
    mapped axes and each slot stores the full batch block (see the vmap
    notes in ``repro.mem.offload``).

    Leaves may be BatchTracers directly (vmap(odeint)) or wrap one deeper
    in the tracer stack (vmap(grad(...)): JVPTracers whose primals are
    BatchTracers), so unwrap nested tracers before testing.
    """
    try:
        from jax.interpreters.batching import BatchTracer
    except ImportError:  # pragma: no cover - future jax moved it
        return

    def has_batch_tracer(x, depth=0) -> bool:
        if isinstance(x, BatchTracer):
            return True
        if isinstance(x, jax.core.Tracer) and depth < 8:
            return any(
                sub is not None and has_batch_tracer(sub, depth + 1)
                for sub in (getattr(x, "primal", None),
                            getattr(x, "tangent", None),
                            getattr(x, "val", None)))
        return False

    if any(has_batch_tracer(x) for x in jtu.tree_leaves((u0, theta))):
        raise NotImplementedError(
            f"vmap over {where} with a slot-addressed offload store is not "
            "supported: the store's host-side dict sees one logical slot "
            "index for the entire batch, so per-example checkpoints would "
            "alias.  Workarounds: adjoint='pnode' with offload='spill'/"
            "'disk' (the scanned segment-batched path composes with vmap), "
            "offload='device' (checkpoints ride the residual pytree, which "
            "vmap understands), or fold the mapped axis into u0's leading "
            "batch dimension instead of vmapping.")


def odeint(f: VectorField, u0: PyTree, theta: PyTree, *, dt: float,
           n_steps: int, t0: float = 0.0, method: str = "rk4",
           adjoint: str = "pnode", ncheck: int | None = None,
           offload: str | None = None, offload_segment: int | None = None,
           snaps_in_ram: int | None = None,
           offload_dir: str | None = None,
           offload_store=None,
           mem_budget: int | None = None,
           ram_budget: int | None = None,
           disk_budget: int | None = None,
           mem_verify: str = "measure",
           fused_stages: bool = False,
           obs=None) -> PyTree:
    """Fixed-step ODE solve, differentiable with the selected adjoint policy.

    ``adjoint="auto"`` with ``mem_budget=<bytes>`` delegates the policy (and
    ``ncheck``/``offload``) choice to ``repro.mem.planner``; ``mem_verify``
    selects how the planner checks the budget ("measure": against the
    lowered HLO's peak live bytes, compiled once and cached; "model": the
    analytic Table-2 model only, no compilation).  ``offload`` routes the
    policy's checkpoints through a ``repro.mem.offload`` store tier
    ("disk" is the file-backed spill tier — same callbacks and bitwise
    contract, payloads in segment files); ``offload_segment`` sets the
    spill/disk tiers' checkpoint-segment length (one host callback per
    segment; default ceil(sqrt(n_steps)) — see
    ``repro.mem.offload.default_segment``).  ``snaps_in_ram`` caps the
    spill tier's RAM-resident slot count (overflow sinks to disk files —
    the dolfin-adjoint multistage split, applying to scanned pnode
    segments and revolve slots alike); ``offload_dir`` pins the disk
    tier's segment files to a caller-owned directory (stale files swept
    on store init).  ``offload_store`` (advanced; scanned pnode
    spill/disk only) supplies a caller-OWNED ``SpillStore``/``DiskStore``
    instead of the per-call store ``odeint`` would build: the serving
    engine uses this to key checkpoint slots per request
    (``store.lane_keys``) and free them as requests leave the batch
    (``store.free_request``) — the caller then owns the store's lifetime
    and must not share it between concurrently traced solves.  With
    ``adjoint="auto"``, ``ram_budget``/
    ``disk_budget`` bound the spill fallback's RAM and disk footprints
    (the planner solves the ``snaps_in_ram`` split; see
    ``repro.mem.planner``).

    ``fused_stages=True`` lowers the RK stage-update chain (forward) and
    the per-stage adjoint recursion (reverse) to single Pallas
    linear-combination kernels (``kernels.ops.fused_lincomb``;
    interpret-mode on CPU, like the other kernels).  Gradients are
    bitwise-identical to the unfused path under jit.  Only the
    checkpointing policies (pnode/pnode2/revolve/revolve2) support it —
    the low-level-AD policies differentiate through the step graph and
    Pallas calls have no AD rules; ``adjoint="auto"`` drops the flag
    silently if the planner picks such a policy.

    ``obs=`` attaches a ``repro.obs.FlightRecorder``: the solve records a
    trace-time ``odeint.solve`` configuration event and binds the
    checkpoint store to the recorder, so every store put/get/free
    (trace-time schedule, device/host tiers) and every spill callback
    (runtime, with payload bytes) lands in the trace.  ``obs=None``
    (default) is zero-overhead — the traced program is identical, so
    gradients with a recorder attached are bitwise-identical to the
    unobserved solve.
    """
    n_steps = int(n_steps)
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")
    from_auto = adjoint == "auto"
    if from_auto:
        from repro.mem.planner import plan_odeint  # deferred: import cycle
        plan = plan_odeint(f, u0, theta, dt=float(dt), n_steps=n_steps,
                           t0=float(t0), method=method,
                           mem_budget=mem_budget, ram_budget=ram_budget,
                           disk_budget=disk_budget, verify=mem_verify)
        adjoint, ncheck = plan.policy, plan.ncheck
        offload = plan.offload if plan.offload is not None else offload
        if plan.snaps_in_ram is not None and snaps_in_ram is None:
            snaps_in_ram = plan.snaps_in_ram
    elif mem_budget is not None:
        raise ValueError(
            "mem_budget is only meaningful with adjoint='auto' (the planner "
            f"chooses the policy); got adjoint={adjoint!r}")
    elif ram_budget is not None or disk_budget is not None:
        raise ValueError(
            "ram_budget/disk_budget are only meaningful with adjoint='auto' "
            "(the planner solves the snaps_in_ram split); with an explicit "
            "policy pass offload='spill'/'disk' and snaps_in_ram directly; "
            f"got adjoint={adjoint!r}")
    if adjoint not in POLICIES:
        raise ValueError(f"unknown adjoint policy {adjoint!r}; one of "
                         f"{POLICIES} (or 'auto' with mem_budget)")
    if offload not in _OFFLOAD_TIERS:
        raise ValueError(f"unknown offload tier {offload!r}; one of "
                         f"{_OFFLOAD_TIERS}")
    if fused_stages and adjoint not in _FUSED_POLICIES:
        if from_auto:
            fused_stages = False
        else:
            raise ValueError(
                f"fused_stages=True is not supported for "
                f"adjoint={adjoint!r}: that policy differentiates through "
                "the step graph and the Pallas stage kernels have no AD "
                f"rules; use one of {_FUSED_POLICIES}")
    fused = bool(fused_stages)
    offloaded = offload in ("host", "spill", "disk")
    if offloaded and adjoint not in ("pnode", "revolve", "revolve2"):
        raise ValueError(
            f"offload={offload!r} is not supported for adjoint={adjoint!r}: "
            "only policies with explicit per-step checkpoints (pnode, "
            "revolve, revolve2) write through the store")
    if offload_segment is not None:
        if offload not in ("spill", "disk"):
            raise ValueError(
                "offload_segment only applies to the callback spill/disk "
                f"tiers; got offload={offload!r}")
        if adjoint != "pnode":
            raise ValueError(
                "offload_segment only applies to the scanned pnode sweep "
                f"(adjoint='pnode'); adjoint={adjoint!r} checkpoints are "
                "slot-addressed at trace time and already pay one callback "
                "per checkpoint-schedule action, so the knob would be "
                "silently ignored")
        offload_segment = int(offload_segment)
        if offload_segment < 1:
            raise ValueError(
                f"offload_segment must be >= 1, got {offload_segment}")
    if snaps_in_ram is not None:
        if offload != "spill":
            raise ValueError(
                "snaps_in_ram is the spill tier's RAM/disk split "
                "(offload='spill'; offload='disk' is already the "
                f"snaps_in_ram=0 corner); got offload={offload!r}")
        snaps_in_ram = int(snaps_in_ram)
        if snaps_in_ram < 0:
            raise ValueError(
                f"snaps_in_ram must be >= 0, got {snaps_in_ram}")
    if offload_dir is not None and offload not in ("spill", "disk"):
        raise ValueError(
            "offload_dir pins the disk tier's segment files "
            f"(offload='spill'/'disk'); got offload={offload!r}")
    if offload_store is not None and not (
            adjoint == "pnode" and offload in ("spill", "disk")):
        raise ValueError(
            "offload_store supplies a caller-owned store to the scanned "
            "pnode spill/disk path only (adjoint='pnode', "
            f"offload='spill'/'disk'); got adjoint={adjoint!r}, "
            f"offload={offload!r}")
    if offloaded and (adjoint in ("revolve", "revolve2")
                      or offload == "host"):
        # slot-addressed stores see one logical slot for the whole batch —
        # vmap would alias per-example checkpoints.  The scanned pnode
        # spill/disk path below composes with vmap: its segment-batched
        # callbacks broadcast the mapped axes, so each slot stores the
        # full batch block (or per-lane keyed rows under lane_keys).
        _reject_vmap_offload(u0, theta, "odeint")
    if obs is not None:
        obs.record("odeint.solve", method=method, adjoint=adjoint,
                   n_steps=n_steps, dt=float(dt), t0=float(t0),
                   ncheck=None if ncheck is None else int(ncheck),
                   offload=offload, fused=fused,
                   planned=from_auto)
    if adjoint == "naive":
        u_final, _ = solve_fixed(f, method, u0, theta, t0, dt, n_steps)
        return u_final
    if adjoint in ("revolve", "revolve2"):
        ncheck = _validate_ncheck(adjoint, ncheck, n_steps)
        from repro.mem.offload import make_store  # deferred: import cycle
        store = make_store(offload, snaps_in_ram=snaps_in_ram,
                           disk_dir=offload_dir)
        if obs is not None:
            store.bind_obs(obs)
        impl = _odeint_revolve if adjoint == "revolve" else _odeint_revolve2
        return impl(f, method, float(t0), float(dt), n_steps, ncheck,
                    store, fused, u0, theta)
    if adjoint == "pnode" and offloaded:
        if offload == "host":
            raise ValueError(
                "offload='host' applies to trace-time checkpoint sites "
                "(revolve/revolve2); the scanned pnode sweep offloads "
                "through offload='spill' or 'disk'")
        from repro.mem.offload import (batch_scale, default_segment,
                                       make_store)
        segment = (offload_segment if offload_segment is not None
                   else default_segment(n_steps))
        if offload_store is not None:
            store = offload_store
            if getattr(store, "tier", None) not in ("spill", "disk"):
                raise ValueError(
                    "offload_store must be a spill/disk-tier store "
                    f"(make_store('spill'|'disk')); got "
                    f"{type(store).__name__}")
        else:
            store = make_store(offload, snaps_in_ram=snaps_in_ram,
                               disk_dir=offload_dir)
        if obs is not None:
            store.bind_obs(obs)
        # mapped axes are only visible HERE (as BatchTracers on the args);
        # the custom_vjp fwd is retraced at logical shapes, so the store's
        # payload-cap chunking needs the batch factor handed to it
        store.payload_scale = batch_scale((u0, theta))
        return _odeint_pnode_spill(f, method, float(t0), float(dt), n_steps,
                                   store, min(segment, n_steps),
                                   fused, u0, theta)
    return _odeint_cv(f, method, float(t0), float(dt), int(n_steps),
                      adjoint, fused, u0, theta)


def nfe_forward(method: str, n_steps: int) -> int:
    return get_tableau(method).num_stages * n_steps


def adjoint_stages(method: str) -> int:
    """Stages the discrete adjoint actually linearizes: stage i is skipped
    when b_i == 0 and no later stage depends on it (e.g. dopri5's 7th/FSAL
    stage), so NFE-B can be below N_s per step."""
    tab = get_tableau(method)
    s = tab.num_stages
    return sum(
        1 for i in range(s)
        if float(tab.b[i]) != 0.0
        or any(float(tab.a[j, i]) != 0.0 for j in range(i + 1, s)))


def nfe_backward(method: str, n_steps: int, adjoint: str,
                 ncheck: int | None = None) -> int:
    """Analytic NFE-B (f evaluations in the reverse pass), Table-2 accounting.

    A transposed JVP of f costs one f evaluation (linearization); a recomputed
    step costs N_s evaluations.
    """
    s = get_tableau(method).num_stages
    sa = adjoint_stages(method)
    if adjoint == "naive":
        return 0
    if adjoint == "continuous":
        # backward solve of the augmented system: one f linearization per stage
        return s * n_steps
    if adjoint == "anode":
        # full forward recompute + backprop through it
        return 2 * s * n_steps
    if adjoint == "aca":
        # re-execute each step (s evals) + backprop its graph (s evals)
        return 2 * s * n_steps
    if adjoint == "pnode":
        return sa * n_steps
    if adjoint == "pnode2":
        # recompute stages of each step + per-stage vjps
        return s * n_steps + sa * n_steps
    if adjoint == "revolve":
        extra = revolve_mod.optimal_extra_steps(n_steps, ncheck)
        return s * extra + sa * n_steps
    if adjoint == "revolve2":
        # each non-boundary step re-advanced exactly once
        n_bound = len(revolve_mod.sweep_checkpoint_positions(n_steps,
                                                             ncheck)) + 1
        return s * (n_steps - n_bound) + sa * n_steps
    raise ValueError(adjoint)


def checkpoint_floats(method: str, n_steps: int, adjoint: str, state_size: int,
                      ncheck: int | None = None) -> int:
    """Analytic checkpoint storage (in state-vector units x state_size)."""
    s = get_tableau(method).num_stages
    if adjoint in ("naive",):
        return 0
    if adjoint == "continuous":
        return 0
    if adjoint == "anode":
        return state_size
    if adjoint == "aca":
        return n_steps * state_size
    if adjoint == "pnode":
        return n_steps * (s + 1) * state_size
    if adjoint == "pnode2":
        return n_steps * state_size
    if adjoint == "revolve":
        return (ncheck + 1) * (s + 1) * state_size  # +1: segment boundary
    if adjoint == "revolve2":
        # boundary states + one in-flight segment of states+stages
        bounds = [0] + revolve_mod.sweep_checkpoint_positions(n_steps, ncheck)
        seg = max(b - a for a, b in zip(bounds, bounds[1:] + [n_steps]))
        return (len(bounds) + seg * (s + 1)) * state_size
    raise ValueError(adjoint)


# ---------------------------------------------------------------------------
# custom_vjp core (continuous / anode / aca / pnode / pnode2)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6))
def _odeint_cv(f, method, t0, dt, n_steps, policy, fused, u0, theta):
    u_final, _ = solve_fixed(f, method, u0, theta, t0, dt, n_steps,
                             fused=fused)
    return u_final


@scope("adjoint/fwd")
def _odeint_cv_fwd(f, method, t0, dt, n_steps, policy, fused, u0, theta):
    if policy == "continuous":
        u_final, _ = solve_fixed(f, method, u0, theta, t0, dt, n_steps)
        return u_final, (u_final, theta)
    if policy == "anode":
        u_final, _ = solve_fixed(f, method, u0, theta, t0, dt, n_steps)
        return u_final, (u0, theta)
    if policy == "aca" or policy == "pnode2":
        u_final, saved = solve_fixed(f, method, u0, theta, t0, dt, n_steps,
                                     save_states=True, fused=fused)
        return u_final, (saved["states"], theta)
    if policy == "pnode":
        u_final, saved = solve_fixed(f, method, u0, theta, t0, dt, n_steps,
                                     save_states=True, save_stages=True,
                                     fused=fused)
        return u_final, (saved["states"], saved["stages"], theta)
    raise ValueError(policy)


@scope("adjoint/bwd")
def _odeint_cv_bwd(f, method, t0, dt, n_steps, policy, fused, res, g):
    tab = get_tableau(method)

    if policy == "continuous":
        u_final, theta = res
        lam0 = g
        mu0 = tree_zeros_like(theta)

        def aug_f(state, th, t):
            u, lam, _ = state
            fval, vjp_fn = jax.vjp(lambda uu, tt: f(uu, tt, t), u, th)
            u_bar, th_bar = vjp_fn(lam)
            # integrated backward in time with negative dt below, so signs
            # follow d(lam)/dt = -f_u^T lam, d(mu)/dt = -f_th^T lam
            return (fval, tree_scale(-1.0, u_bar), tree_scale(-1.0, th_bar))

        state0 = (u_final, lam0, mu0)
        tF = t0 + dt * n_steps
        state_final, _ = solve_fixed(aug_f, method, state0, theta, tF, -dt,
                                     n_steps)
        _, lam, mu = state_final
        return lam, mu

    if policy == "anode":
        u0, theta = res

        def full(u0_, th_):
            uf, _ = solve_fixed(f, method, u0_, th_, t0, dt, n_steps)
            return uf

        _, vjp_fn = jax.vjp(full, u0, theta)
        return vjp_fn(g)

    if policy == "aca":
        states, theta = res  # states: pre-step states u_0..u_{N-1}, stacked

        def step_fn(u, th, t):
            u_next, _ = rk_step(f, tab, u, th, t, dt)
            return u_next

        def body(carry, inp):
            lam, mu = carry
            u_n, n = inp
            t_n = _t_of(t0, dt, n)
            _, vjp_fn = jax.vjp(lambda uu, th: step_fn(uu, th, t_n), u_n, theta)
            lam, th_bar = vjp_fn(lam)
            return (lam, tree_add(mu, th_bar)), None

        (lam, mu), _ = jax.lax.scan(
            body, (g, tree_zeros_like(theta)),
            (states, jnp.arange(n_steps)), reverse=True)
        return lam, mu

    if policy == "pnode":
        states, stages, theta = res

        def body(carry, inp):
            lam, mu = carry
            u_n, k_n, n = inp
            t_n = _t_of(t0, dt, n)
            lam, th_bar = rk_adjoint_step(f, tab, u_n, k_n, theta, t_n, dt,
                                          lam, fused=fused)
            return (lam, tree_add(mu, th_bar)), None

        (lam, mu), _ = jax.lax.scan(
            body, (g, tree_zeros_like(theta)),
            (states, stages, jnp.arange(n_steps)), reverse=True)
        return lam, mu

    if policy == "pnode2":
        states, theta = res

        def body(carry, inp):
            lam, mu = carry
            u_n, n = inp
            t_n = _t_of(t0, dt, n)
            ks = rk_stages(f, tab, u_n, theta, t_n, dt,  # recompute stages
                           fused=fused)
            lam, th_bar = rk_adjoint_step(f, tab, u_n, tree_stack(ks), theta,
                                          t_n, dt, lam, fused=fused)
            return (lam, tree_add(mu, th_bar)), None

        (lam, mu), _ = jax.lax.scan(
            body, (g, tree_zeros_like(theta)),
            (states, jnp.arange(n_steps)), reverse=True)
        return lam, mu

    raise ValueError(policy)


_odeint_cv.defvjp(_odeint_cv_fwd, _odeint_cv_bwd)


# ---------------------------------------------------------------------------
# revolve policy (binomial checkpointing, trace-time schedule)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6, 7))
def _odeint_revolve(f, method, t0, dt, n_steps, ncheck, store, fused, u0,
                    theta):
    u_final, _ = solve_fixed(f, method, u0, theta, t0, dt, n_steps,
                             fused=fused)
    return u_final


def _advance_segment(f, tab, u, theta, t_start_idx, n, t0, dt, fused=False):
    """Run n plain RK steps from u starting at step index t_start_idx."""
    if n <= 0:
        return u

    def body(carry, k):
        t = _t_of(t0, dt, t_start_idx + k)
        u_next, _ = rk_step(f, tab, carry, theta, t, dt, fused=fused)
        return u_next, None

    u_out, _ = jax.lax.scan(body, u, jnp.arange(n))
    return u_out


@scope("revolve/fwd")
def _odeint_revolve_fwd(f, method, t0, dt, n_steps, ncheck, store, fused, u0,
                        theta):
    tab = get_tableau(method)
    positions = [0] + revolve_mod.sweep_checkpoint_positions(n_steps, ncheck)
    u = u0
    bounds = positions + [n_steps]
    for a, b in zip(bounds[:-1], bounds[1:]):
        # execute step a explicitly to capture its stages for the checkpoint
        t_a = _t_of(t0, dt, a)
        u_next, stages_a = rk_step(f, tab, u, theta, t_a, dt, fused=fused)
        store.put(a, (u, stages_a))
        u = _advance_segment(f, tab, u_next, theta, a + 1, b - a - 1, t0, dt,
                             fused=fused)
    return u, (store.pack(), theta)


@scope("revolve/bwd")
def _odeint_revolve_bwd(f, method, t0, dt, n_steps, ncheck, store, fused, res,
                        g):
    tab = get_tableau(method)
    ckpt_res, theta = res
    positions = [0] + revolve_mod.sweep_checkpoint_positions(n_steps, ncheck)
    store.unpack(ckpt_res, positions)

    lam = g
    mu = tree_zeros_like(theta)
    for act in revolve_mod.reverse_schedule(n_steps, ncheck):
        kind = act[0]
        if kind == "advance":
            _, start, m = act
            u_s, st_s = store.get(start)
            # stage-combine restart: u_{start+1} with zero f evaluations
            u = rk_combine(tab, u_s, tree_unstack(st_s, tab.num_stages), dt,
                           fused=fused)
            u = _advance_segment(f, tab, u, theta, start + 1, m - 1, t0, dt,
                                 fused=fused)
            t_tgt = _t_of(t0, dt, start + m)
            _, stages_tgt = rk_step(f, tab, u, theta, t_tgt, dt, fused=fused)
            store.put(start + m, (u, stages_tgt))
        elif kind == "adjoint":
            _, idx = act
            u_i, st_i = store.get(idx)
            store.free(idx)
            t_i = _t_of(t0, dt, idx)
            lam, th_bar = rk_adjoint_step(f, tab, u_i, st_i, theta, t_i, dt,
                                          lam, fused=fused)
            mu = tree_add(mu, th_bar)
            # the schedule is unrolled at trace time; without a barrier XLA
            # may hoist every step's theta-sized stage gradients and keep
            # them live simultaneously (O(N_t N_s |theta|) temp instead of
            # O(|theta|)).  Serialize the chain explicitly.
            lam, mu = jax.lax.optimization_barrier((lam, mu))
        elif kind == "free":
            store.free(act[1])
        else:  # pragma: no cover
            raise ValueError(act)
    return lam, mu


_odeint_revolve.defvjp(_odeint_revolve_fwd, _odeint_revolve_bwd)


# ---------------------------------------------------------------------------
# revolve2: two-level binomial checkpointing with SCANNED per-segment adjoint
#
# The recursive `revolve` schedule above achieves the exact Prop-2 recompute
# optimum but unrolls one subgraph per action; XLA:CPU's parallel scheduler
# then refuses to overlap the per-step theta-gradient buffers, inflating
# compiled temp memory to O(N_t |theta|) even though true liveness is O(1)
# (see EXPERIMENTS.md SPerf).  revolve2 trades a small amount of recompute
# optimality for a *scanned* executor whose compiled liveness is bounded on
# every backend: the forward sweep stores only the `ncheck` boundary states
# chosen by the optimal sweep placement; the reverse pass re-advances each
# segment once (saving its states+stages inside a scan) and then scans the
# high-level stage adjoint backward over it.  Memory: ncheck states +
# max_segment*(N_s+1) states + O(|theta|).  Recompute: N_t - ncheck - 1
# steps (the t<=2 regime of Prop. 2, where it matches the optimum up to one
# step per segment).  This is the production default for LM-scale training.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6, 7))
def _odeint_revolve2(f, method, t0, dt, n_steps, ncheck, store, fused, u0,
                     theta):
    u_final, _ = solve_fixed(f, method, u0, theta, t0, dt, n_steps,
                             fused=fused)
    return u_final


def _segment_bounds(n_steps: int, ncheck: int):
    positions = [0] + revolve_mod.sweep_checkpoint_positions(n_steps, ncheck)
    return list(zip(positions, positions[1:] + [n_steps]))


@scope("revolve2/fwd")
def _odeint_revolve2_fwd(f, method, t0, dt, n_steps, ncheck, store, fused, u0,
                         theta):
    bounds = _segment_bounds(n_steps, ncheck)
    u = u0
    for a, b in bounds:
        store.put(a, u)
        u = _advance_segment(f, get_tableau(method), u, theta, a, b - a,
                             t0, dt, fused=fused)
    return u, (store.pack(), theta)


@scope("revolve2/bwd")
def _odeint_revolve2_bwd(f, method, t0, dt, n_steps, ncheck, store, fused,
                         res, g):
    tab = get_tableau(method)
    ckpt_res, theta = res
    bounds = _segment_bounds(n_steps, ncheck)
    store.unpack(ckpt_res, [a for a, _ in bounds])

    lam = g
    mu = tree_zeros_like(theta)
    for a, b in reversed(bounds):
        m = b - a
        u_a = store.get(a)
        store.free(a)
        # re-advance the segment, saving states and stages (scan)
        _, saved = solve_fixed(f, method, u_a, theta, t0 + dt * a, dt, m,
                               save_states=True, save_stages=True,
                               fused=fused)

        def body(carry, inp):
            lam_, mu_ = carry
            u_n, k_n, n = inp
            t_n = t0 + dt * (a + n)
            lam_, th_bar = rk_adjoint_step(f, tab, u_n, k_n, theta, t_n, dt,
                                           lam_, fused=fused)
            return (lam_, tree_add(mu_, th_bar)), None

        (lam, mu), _ = jax.lax.scan(
            body, (lam, mu),
            (saved["states"], saved["stages"], jnp.arange(m)), reverse=True)
    return lam, mu


_odeint_revolve2.defvjp(_odeint_revolve2_fwd, _odeint_revolve2_bwd)


# ---------------------------------------------------------------------------
# pnode with spill offload: the scanned forward sweep streams (state, stages)
# checkpoints into the host-side store instead of stacking them in device
# residual buffers; the reverse scan streams them back.  The residual is a
# single token scalar, so compiled device-live memory is O(segment) state
# copies regardless of N_t while the adjoint math — and therefore the
# gradients, bitwise — is exactly pnode's (tests/test_mem.py).
#
# I/O is SEGMENT-BATCHED: an inner scan stages `segment` consecutive steps'
# checkpoints in a small device buffer, then one `write_batch` callback
# ships the whole segment; the reverse sweep mirrors it with one `prefetch`
# callback per segment.  Host round-trips per reverse pass drop from
# 2*N_t to 2*ceil(N_t/segment) (BENCH_3), at a device cost of
# segment*(N_s+1) staged state vectors — sublinear with the default
# segment = ceil(sqrt(N_t)) (repro.mem.offload.default_segment).
#
# The reverse sweep is additionally SOFTWARE-PIPELINED: right after waiting
# on segment k's prefetch it issues the background gather of segment k-1
# (`prefetch_issue` — a token-only callback that queues the host/disk read
# on the store's executor), so segment I/O overlaps the adjoint compute of
# the segment in hand.  Works for the RAM dict and the disk tier alike;
# `prefetch_hit_cb` counts how many waits were actually served from the
# pipeline.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6, 7))
def _odeint_pnode_spill(f, method, t0, dt, n_steps, store, segment, fused,
                        u0, theta):
    u_final, _ = solve_fixed(f, method, u0, theta, t0, dt, n_steps,
                             fused=fused)
    return u_final


@scope("pnode_spill/fwd")
def _odeint_pnode_spill_fwd(f, method, t0, dt, n_steps, store, segment,
                            fused, u0, theta):
    tab = get_tableau(method)
    n_full, rem = divmod(n_steps, segment)

    def run_segment(u, tok, base, m):
        # base: first step index of the segment (traced or static); m static
        def step(carry, i):
            u = carry
            n = base + i
            t = t0 + n.astype(jnp.result_type(float)) * dt  # = solve_fixed
            u_next, stages = rk_step(f, tab, u, theta, t, dt, fused=fused)
            return u_next, (u, stages)

        u, staged = jax.lax.scan(step, u, jnp.arange(m))
        tok = store.write_batch(tok, base, staged)  # ONE callback, m slots
        return u, tok

    u, tok = u0, store.init_token()
    if n_full:
        def seg_body(carry, s_idx):
            u, tok = carry
            u, tok = run_segment(u, tok, s_idx * segment, segment)
            return (u, tok), None

        (u, tok), _ = jax.lax.scan(seg_body, (u, tok), jnp.arange(n_full))
    if rem:
        u, tok = run_segment(u, tok, jnp.asarray(n_full * segment), rem)
    return u, (tok, theta)


@scope("pnode_spill/bwd")
def _odeint_pnode_spill_bwd(f, method, t0, dt, n_steps, store, segment,
                            fused, res, g):
    tab = get_tableau(method)
    tok, theta = res
    n_full, rem = divmod(n_steps, segment)

    def run_segment_bwd(lam, mu, tok, base, m):
        tok, staged = store.prefetch(tok, base, m)  # ONE callback, m slots
        # software pipelining: with this segment's data in hand, dispatch
        # the background gather of the NEXT segment to be consumed (the
        # earlier one — the sweep runs in reverse), so its host/disk I/O
        # overlaps the adjoint compute below.  The issue rides the token
        # chain, so it cannot reorder around the read it follows.
        nb = base - segment
        tok = jax.lax.cond(
            nb >= 0,
            lambda t: store.prefetch_issue(t, jnp.maximum(nb, 0), segment),
            lambda t: t, tok)

        def step(carry, i):
            lam, mu = carry
            u_n, k_n = jtu.tree_map(lambda b: b[i], staged)
            t_n = _t_of(t0, dt, base + i)
            lam, th_bar = rk_adjoint_step(f, tab, u_n, k_n, theta, t_n, dt,
                                          lam, fused=fused)
            return (lam, tree_add(mu, th_bar)), None

        (lam, mu), _ = jax.lax.scan(step, (lam, mu), jnp.arange(m),
                                    reverse=True)
        return lam, mu, tok

    lam, mu = g, tree_zeros_like(theta)
    if rem:  # the trailing partial segment is adjointed first
        lam, mu, tok = run_segment_bwd(lam, mu, tok,
                                       jnp.asarray(n_full * segment), rem)
    elif n_full:  # no remainder: warm the pipeline for the first read
        tok = store.prefetch_issue(tok, jnp.asarray((n_full - 1) * segment),
                                   segment)
    if n_full:
        def seg_body(carry, s_idx):
            lam, mu, tok = carry
            lam, mu, tok = run_segment_bwd(lam, mu, tok, s_idx * segment,
                                           segment)
            return (lam, mu, tok), None

        (lam, mu, tok), _ = jax.lax.scan(seg_body, (lam, mu, tok),
                                         jnp.arange(n_full), reverse=True)
    return lam, mu


_odeint_pnode_spill.defvjp(_odeint_pnode_spill_fwd, _odeint_pnode_spill_bwd)


# ---------------------------------------------------------------------------
# trajectory-loss support (the paper's eq. 2 integral term)
# ---------------------------------------------------------------------------

def odeint_with_quadrature(f: VectorField, q, u0: PyTree, theta: PyTree, *,
                           dt: float, n_steps: int, t0: float = 0.0,
                           method: str = "rk4", adjoint: str = "pnode",
                           ncheck: int | None = None,
                           offload: str | None = None,
                           fused_stages: bool = False):
    """Integrate du/dt = f AND the loss quadrature dQ/dt = q(u, theta, t)
    jointly (eq. 2's integral term: running costs / Tikhonov / kinetic
    regularizers a la Finlay et al.).  Returns (u_final, Q).

    The augmented system is just another vector field, so every adjoint
    policy — including revolve checkpointing — applies unchanged, and the
    gradient of any function of (u_final, Q) is reverse-accurate."""
    def aug(state, th, t):
        u, _ = state
        return (f(u, th, t), q(u, th, t))

    q0 = jnp.zeros((), jnp.result_type(float))
    u_final, Q = odeint(aug, (u0, q0), theta, dt=dt, n_steps=n_steps, t0=t0,
                        method=method, adjoint=adjoint, ncheck=ncheck,
                        offload=offload, fused_stages=fused_stages)
    return u_final, Q
