"""Implicit time integration with discrete adjoints (paper §3.3).

Theta-method family:  u_{n+1} = u_n + h [ (1-theta) f(u_n) + theta f(u_{n+1}) ]
  theta = 1.0  -> backward Euler   (paper eq. 12)
  theta = 0.5  -> Crank-Nicolson   (used for the stiff Robertson system, §5.3)

Forward pass: Newton iterations; each Newton step solves the linear system
(I - h*theta*J) dv = -r with matrix-free GMRES, the action of J = df/du
supplied by ``jax.jvp`` — exactly the paper's "matrix-free iterative method
whose matrix action comes from AD" design.

Reverse pass (discrete adjoint, paper eq. 13 generalized to theta-methods):
    (I - h*theta*f_u(u_{n+1}))^T lam_s = lam_{n+1}          (transposed GMRES,
                                                             action by jax.vjp)
    lam_n  = (I + h*(1-theta)*f_u(u_n))^T lam_s
    mu_n  += h * [ (1-theta) f_th(u_n) + theta f_th(u_{n+1}) ]^T lam_s

The nonlinear/linear solvers never enter the backpropagation graph — only
``f`` is differentiated (one vjp per GMRES/adjoint application), which is the
paper's key memory argument for implicit schemes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import tree_util as jtu
from jax.scipy.sparse.linalg import gmres

from repro.core.integrators import (
    PyTree,
    VectorField,
    tree_add,
    tree_axpy,
    tree_norm,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)


def _mass_apply(mass):
    if mass is None:
        return lambda u: u
    if callable(mass):
        return mass
    return lambda u: jtu.tree_map(lambda x: mass @ x, u)


def _mass_apply_t(mass):
    if mass is None:
        return lambda u: u
    if callable(mass):  # caller supplies a self-adjoint / explicit transpose
        return mass
    return lambda u: jtu.tree_map(lambda x: mass.T @ x, u)


def _theta_of(method: str) -> float:
    if method == "beuler":
        return 1.0
    if method == "cn":
        return 0.5
    raise ValueError(f"unknown implicit method {method!r}; use 'beuler' or 'cn'")


# ---------------------------------------------------------------------------
# one implicit step (forward)
# ---------------------------------------------------------------------------

def implicit_step(f: VectorField, u_n: PyTree, theta_p: PyTree, t_n, h,
                  theta: float, newton_iters: int = 10,
                  newton_tol: float = 1e-9, gmres_iters: int = 20,
                  gmres_tol: float = 1e-10, mass=None) -> PyTree:
    """Solve M u_{n+1} = M u_n + h[(1-theta) f(u_n, t_n) + theta f(u_{n+1},
    t_{n+1})] (eq. 12 generalized; mass=None means M = I)."""
    t_next = t_n + h
    f_n = f(u_n, theta_p, t_n)
    apply_m = _mass_apply(mass)
    # constant part g = M u_n + h (1-theta) f_n
    g_const = tree_axpy(h * (1.0 - theta), f_n, apply_m(u_n))

    def residual(v):
        return tree_sub(tree_axpy(-h * theta, f(v, theta_p, t_next),
                                  apply_m(v)), g_const)

    def newton_body(carry):
        v, it, _ = carry
        r = residual(v)

        def jv(w):
            # (M - h*theta*J) w, J = df/du at v — matrix-free via jvp
            _, jw = jax.jvp(lambda uu: f(uu, theta_p, t_next), (v,), (w,))
            return tree_axpy(-h * theta, jw, apply_m(w))

        dv, _ = gmres(jv, tree_scale(-1.0, r), tol=gmres_tol,
                      maxiter=gmres_iters, solve_method="incremental")
        v_new = tree_add(v, dv)
        return (v_new, it + 1, tree_norm(residual(v_new)))

    def newton_cond(carry):
        _, it, rnorm = carry
        return jnp.logical_and(it < newton_iters, rnorm > newton_tol)

    # predictor: explicit Euler
    v0 = tree_axpy(h, f_n, u_n)
    carry0 = (v0, jnp.array(0, jnp.int32), tree_norm(residual(v0)))
    v_final, _, _ = jax.lax.while_loop(newton_cond, newton_body, carry0)
    return v_final


def implicit_adjoint_step(f: VectorField, u_n: PyTree, u_next: PyTree,
                          theta_p: PyTree, t_n, h, theta: float,
                          lam: PyTree, gmres_iters: int = 20,
                          gmres_tol: float = 1e-10, mass=None):
    """One reverse step of the theta-method discrete adjoint (eq. 13)."""
    t_next = t_n + h
    apply_mt = _mass_apply_t(mass)

    # transposed linear solve: (M - h*theta*f_u(u_next))^T lam_s = lam
    _, vjp_next = jax.vjp(lambda uu, th: f(uu, th, t_next), u_next, theta_p)

    def jtv(w):
        u_bar, _ = vjp_next(w)
        return tree_axpy(-h * theta, u_bar, apply_mt(w))

    lam_s, _ = gmres(jtv, lam, tol=gmres_tol, maxiter=gmres_iters,
                     solve_method="incremental")

    # lam_n = M^T lam_s + h(1-theta) f_u(u_n)^T lam_s
    _, vjp_n = jax.vjp(lambda uu, th: f(uu, th, t_n), u_n, theta_p)
    u_bar_n, th_bar_n = vjp_n(tree_scale(h * (1.0 - theta), lam_s))
    lam_prev = tree_add(apply_mt(lam_s), u_bar_n)

    # mu increment
    _, th_bar_next = vjp_next(tree_scale(h * theta, lam_s))
    th_bar = tree_add(th_bar_n, th_bar_next)
    return lam_prev, th_bar


# ---------------------------------------------------------------------------
# full solve with discrete adjoint (custom_vjp)
# ---------------------------------------------------------------------------

def odeint_implicit(f: VectorField, u0: PyTree, theta_p: PyTree, *, dt: float,
                    n_steps: int, t0: float = 0.0, method: str = "cn",
                    newton_iters: int = 10, newton_tol: float = 1e-9,
                    gmres_iters: int = 20, gmres_tol: float = 1e-10,
                    mass=None) -> PyTree:
    if mass is not None:
        # close over the (static) mass operator so the custom_vjp signature
        # stays hashable
        fm = f

        def wrapped(*args):
            return _odeint_implicit_mass(fm, mass, float(t0), float(dt),
                                         int(n_steps), _theta_of(method),
                                         int(newton_iters), float(newton_tol),
                                         int(gmres_iters), float(gmres_tol),
                                         *args)
        return wrapped(u0, theta_p)
    return _odeint_implicit(f, float(t0), float(dt), int(n_steps),
                            _theta_of(method), int(newton_iters),
                            float(newton_tol), int(gmres_iters),
                            float(gmres_tol), u0, theta_p)


def _odeint_implicit_mass(f, mass, t0, dt, n_steps, theta, newton_iters,
                          newton_tol, gmres_iters, gmres_tol, u0, theta_p):
    """Mass-matrix path (no custom_vjp shortcut: differentiates through the
    per-step adjoint explicitly by reusing implicit_adjoint_step in a manual
    scan -- forward-only use + grad via the theta-method identity)."""
    def body(carry, n):
        u = carry
        t_n = t0 + dt * n
        u_next = implicit_step(f, u, theta_p, t_n, dt, theta, newton_iters,
                               newton_tol, gmres_iters, gmres_tol, mass=mass)
        return u_next, None

    u_final, _ = jax.lax.scan(body, u0, jnp.arange(n_steps))
    return u_final


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6, 7, 8))
def _odeint_implicit(f, t0, dt, n_steps, theta, newton_iters, newton_tol,
                     gmres_iters, gmres_tol, u0, theta_p):
    u_final, _ = _implicit_solve(f, t0, dt, n_steps, theta, newton_iters,
                                 newton_tol, gmres_iters, gmres_tol, u0,
                                 theta_p, save_states=False)
    return u_final


def _implicit_solve(f, t0, dt, n_steps, theta, newton_iters, newton_tol,
                    gmres_iters, gmres_tol, u0, theta_p, save_states):
    def body(carry, n):
        u = carry
        t_n = t0 + dt * n
        u_next = implicit_step(f, u, theta_p, t_n, dt, theta,
                               newton_iters, newton_tol, gmres_iters, gmres_tol)
        return u_next, (u if save_states else None)

    u_final, states = jax.lax.scan(body, u0, jnp.arange(n_steps))
    return u_final, states


def _odeint_implicit_fwd(f, t0, dt, n_steps, theta, newton_iters, newton_tol,
                         gmres_iters, gmres_tol, u0, theta_p):
    u_final, states = _implicit_solve(f, t0, dt, n_steps, theta, newton_iters,
                                      newton_tol, gmres_iters, gmres_tol, u0,
                                      theta_p, save_states=True)
    return u_final, (states, u_final, theta_p)


def _odeint_implicit_bwd(f, t0, dt, n_steps, theta, newton_iters, newton_tol,
                         gmres_iters, gmres_tol, res, g):
    states, u_final, theta_p = res

    # u_next for step n is states[n+1] (or u_final for the last step)
    u_nexts = jtu.tree_map(
        lambda s, uf: jnp.concatenate([s[1:], uf[None]], axis=0), states,
        u_final)

    def body(carry, inp):
        lam, mu = carry
        u_n, u_next, n = inp
        t_n = t0 + dt * n
        lam, th_bar = implicit_adjoint_step(f, u_n, u_next, theta_p, t_n, dt,
                                            theta, lam, gmres_iters, gmres_tol)
        return (lam, tree_add(mu, th_bar)), None

    (lam, mu), _ = jax.lax.scan(
        body, (g, tree_zeros_like(theta_p)),
        (states, u_nexts, jnp.arange(n_steps)), reverse=True)
    return lam, mu


_odeint_implicit.defvjp(_odeint_implicit_fwd, _odeint_implicit_bwd)
