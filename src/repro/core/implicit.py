"""Implicit time integration with discrete adjoints (paper §3.3) under the
memory-plan / checkpoint-offload stack.

Theta-method family:  u_{n+1} = u_n + h [ (1-theta) f(u_n) + theta f(u_{n+1}) ]
  theta = 1.0  -> backward Euler   (paper eq. 12)
  theta = 0.5  -> Crank-Nicolson   (used for the stiff Robertson system, §5.3)

Forward pass: Newton iterations; each Newton step solves the linear system
(I - h*theta*J) dv = -r with matrix-free GMRES, the action of J = df/du
supplied by ``jax.jvp`` — exactly the paper's "matrix-free iterative method
whose matrix action comes from AD" design.

Reverse pass (discrete adjoint, paper eq. 13 generalized to theta-methods):
    (I - h*theta*f_u(u_{n+1}))^T lam_s = lam_{n+1}          (transposed GMRES,
                                                             action by jax.vjp)
    lam_n  = (I + h*(1-theta)*f_u(u_n))^T lam_s
    mu_n  += h * [ (1-theta) f_th(u_n) + theta f_th(u_{n+1}) ]^T lam_s

The nonlinear/linear solvers never enter the backpropagation graph — only
``f`` is differentiated (one vjp per GMRES/adjoint application), which is
the paper's key memory argument for implicit schemes AND what makes
checkpoint spacing cheap here: a checkpoint is one *converged state*
vector, the Newton/GMRES iterates are never stored.

Checkpoint policies (``adjoint=``), mirroring ``core/adjoint.py``:

  pnode     store every converged state u_0..u_{N-1} (+ u_final); the
            reverse pass solves one transposed linear system per step with
            zero recomputation.  Under ``offload="spill"`` the states are
            segment-batched through the host-callback ``SpillStore``
            (one ``write_batch``/``prefetch`` round-trip per
            ceil(sqrt(N_t))-step segment), so device-live memory is
            O(segment) states regardless of N_t — and, unlike the explicit
            scanned spill path, this one is **vmap-compatible**: the store
            callbacks are vectorized (``vmap_method="broadcast_all"``), a
            single host round-trip carries the whole batch and each batch
            element occupies its own block of the spilled slot (the
            per-batch-element key scheme; see ``repro.mem.offload``).
  revolve   binomial (Prop. 2) checkpoint schedule over states only:
            ``ncheck`` slots, segments re-advanced by re-running the Newton
            solve — recomputation trades against memory exactly as in the
            explicit case, except a slot costs S bytes, not (N_s+1)S.
            Slots live in a ``CheckpointStore`` tier
            (device / pinned-host / callback-spill).
  revolve2  scanned two-level variant (bounded compiled liveness): boundary
            states in the store, each segment re-advanced once and
            adjointed under ``lax.scan``.
  auto      delegate the (policy, ncheck, offload) choice to
            ``repro.mem.planner.plan_odeint`` under ``mem_budget=<bytes>``
            (the implicit cost model: per-step recompute cost
            newton_iters*(gmres_iters+2)+1 f evaluations, NFE-B
            gmres_iters+2 per adjoint solve).

``adjoint="naive"`` (AD through the solver) is impossible by construction:
Newton/GMRES run in ``while_loop``s that have no reverse rule — the
paper's motivating limitation.  The AD-through-a-dense-unrolled-Newton
oracle in tests/test_reverse_accuracy.py is the exactness reference.

Convergence reporting: every path threads a converged flag and the final
Newton residual out of the step loop; ``odeint_implicit(...,
return_stats=True)`` returns ``(u_final, ImplicitStats)`` where
``stats.diverged`` is True if ANY step exhausted ``newton_iters`` with
residual > ``newton_tol`` (instead of silently returning garbage states
and gradients), ``stats.max_residual`` is the worst final residual and
``stats.newton_iters`` the total iteration count (the measured forward
NFE driver).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu
from jax.scipy.sparse.linalg import gmres

from repro.obs.profile import scope
from repro.core import revolve as revolve_mod
from repro.core.integrators import (
    PyTree,
    VectorField,
    tree_add,
    tree_axpy,
    tree_norm,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)

IMPLICIT_METHODS = ("beuler", "cn")
IMPLICIT_POLICIES = ("pnode", "revolve", "revolve2")


def _mass_apply(mass):
    if mass is None:
        return lambda u: u
    if callable(mass):
        return mass
    return lambda u: jtu.tree_map(lambda x: mass @ x, u)


def _mass_apply_t(mass):
    if mass is None:
        return lambda u: u
    if callable(mass):  # caller supplies a self-adjoint / explicit transpose
        return mass
    return lambda u: jtu.tree_map(lambda x: mass.T @ x, u)


def _theta_of(method: str) -> float:
    if method == "beuler":
        return 1.0
    if method == "cn":
        return 0.5
    raise ValueError(f"unknown implicit method {method!r}; use 'beuler' or "
                     "'cn'")


def is_implicit_method(method: str) -> bool:
    return method in IMPLICIT_METHODS


class StepInfo(NamedTuple):
    """Per-step Newton exit state (threaded out of the solve scan)."""
    iters: jax.Array      # Newton iterations taken
    residual: jax.Array   # final ||residual|| at exit
    converged: jax.Array  # residual <= newton_tol at exit


class ImplicitStats(NamedTuple):
    """Solve-level convergence report (see ``return_stats=``)."""
    diverged: jax.Array      # any step exited on newton_iters with r > tol
    max_residual: jax.Array  # worst final Newton residual across steps
    newton_iters: jax.Array  # total Newton iterations over the solve
    rescued: jax.Array       # steps recovered by a rescue retry (PR 8)


class RescueConfig(NamedTuple):
    """Divergence-rescue knobs (``odeint_implicit(rescue=...)``).

    On a failed step (Newton exhausted its iteration cap, or a non-finite
    state — e.g. an injected NaN f-eval), the step is retried with an
    ESCALATED iteration cap: retry r gets ``newton_iters * escalate**r``
    iterations.  Key property: the Newton ``while_loop`` exits dynamically
    on ``residual <= tol``, so a retry that converges where the fault-free
    run would have converged produces **bit-identical** values — the
    escalated cap only matters when it binds.  ``dt_halving`` adds a last
    resort after all retries: two h/2 sub-steps (theta-method order is
    preserved; values are NOT bitwise the single-step ones, so it only
    runs when everything bitwise-preserving already failed)."""
    max_retries: int = 1
    escalate: int = 4
    dt_halving: bool = True


class _SolverConfig(NamedTuple):
    """Static (hashable) solver knobs — a single nondiff custom_vjp arg.
    ``rescue``/``fault``/``resilient`` default off: dormant configs build
    the exact pre-PR-8 trace (``_step`` stages no gates, the spill
    residuals carry no boundary states)."""
    theta: float
    newton_iters: int
    newton_tol: float
    gmres_iters: int
    gmres_tol: float
    rescue: Any = None       # RescueConfig | None
    fault: Any = None        # repro.ft.FaultPlan | None
    resilient: bool = False  # checked prefetch + recompute fallback


def _stats_zero() -> ImplicitStats:
    return ImplicitStats(jnp.zeros((), jnp.bool_),
                         jnp.zeros((), jnp.result_type(float)),
                         jnp.zeros((), jnp.int32),
                         jnp.zeros((), jnp.int32))


def _stats_merge(stats: ImplicitStats, info: StepInfo,
                 rescued=None) -> ImplicitStats:
    return ImplicitStats(
        jnp.logical_or(stats.diverged, jnp.logical_not(info.converged)),
        jnp.maximum(stats.max_residual, info.residual),
        stats.newton_iters + info.iters.astype(jnp.int32),
        stats.rescued if rescued is None else stats.rescued + rescued)


# ---------------------------------------------------------------------------
# one implicit step (forward) and its discrete adjoint
# ---------------------------------------------------------------------------

def implicit_step(f: VectorField, u_n: PyTree, theta_p: PyTree, t_n, h,
                  theta: float, newton_iters: int = 10,
                  newton_tol: float = 1e-9, gmres_iters: int = 20,
                  gmres_tol: float = 1e-10, mass=None):
    """Solve M u_{n+1} = M u_n + h[(1-theta) f(u_n, t_n) + theta f(u_{n+1},
    t_{n+1})] (eq. 12 generalized; mass=None means M = I).

    Returns ``(u_{n+1}, StepInfo)`` — the converged flag is the Newton exit
    condition ``residual <= newton_tol``; callers that loop steps aggregate
    it into ``ImplicitStats`` instead of silently dropping non-convergence.
    """
    t_next = t_n + h
    f_n = f(u_n, theta_p, t_n)
    apply_m = _mass_apply(mass)
    # constant part g = M u_n + h (1-theta) f_n
    g_const = tree_axpy(h * (1.0 - theta), f_n, apply_m(u_n))

    def residual(v):
        return tree_sub(tree_axpy(-h * theta, f(v, theta_p, t_next),
                                  apply_m(v)), g_const)

    def newton_body(carry):
        v, it, _ = carry
        r = residual(v)

        def jv(w):
            # (M - h*theta*J) w, J = df/du at v — matrix-free via jvp
            _, jw = jax.jvp(lambda uu: f(uu, theta_p, t_next), (v,), (w,))
            return tree_axpy(-h * theta, jw, apply_m(w))

        dv, _ = gmres(jv, tree_scale(-1.0, r), tol=gmres_tol,
                      maxiter=gmres_iters, solve_method="incremental")
        v_new = tree_add(v, dv)
        return (v_new, it + 1, tree_norm(residual(v_new)))

    def newton_cond(carry):
        _, it, rnorm = carry
        return jnp.logical_and(it < newton_iters, rnorm > newton_tol)

    # predictor: explicit Euler
    v0 = tree_axpy(h, f_n, u_n)
    carry0 = (v0, jnp.array(0, jnp.int32), tree_norm(residual(v0)))
    v_final, iters, rnorm = jax.lax.while_loop(newton_cond, newton_body,
                                               carry0)
    return v_final, StepInfo(iters, rnorm, rnorm <= newton_tol)


def implicit_adjoint_step(f: VectorField, u_n: PyTree, u_next: PyTree,
                          theta_p: PyTree, t_n, h, theta: float,
                          lam: PyTree, gmres_iters: int = 20,
                          gmres_tol: float = 1e-10, mass=None):
    """One reverse step of the theta-method discrete adjoint (eq. 13)."""
    t_next = t_n + h
    apply_mt = _mass_apply_t(mass)

    # transposed linear solve: (M - h*theta*f_u(u_next))^T lam_s = lam
    _, vjp_next = jax.vjp(lambda uu, th: f(uu, th, t_next), u_next, theta_p)

    def jtv(w):
        u_bar, _ = vjp_next(w)
        return tree_axpy(-h * theta, u_bar, apply_mt(w))

    lam_s, _ = gmres(jtv, lam, tol=gmres_tol, maxiter=gmres_iters,
                     solve_method="incremental")

    # lam_n = M^T lam_s + h(1-theta) f_u(u_n)^T lam_s
    _, vjp_n = jax.vjp(lambda uu, th: f(uu, th, t_n), u_n, theta_p)
    u_bar_n, th_bar_n = vjp_n(tree_scale(h * (1.0 - theta), lam_s))
    lam_prev = tree_add(apply_mt(lam_s), u_bar_n)

    # mu increment
    _, th_bar_next = vjp_next(tree_scale(h * theta, lam_s))
    th_bar = tree_add(th_bar_n, th_bar_next)
    return lam_prev, th_bar


def _tree_allfinite(tree):
    fin = jnp.ones((), jnp.bool_)
    for x in jtu.tree_leaves(tree):
        fin = jnp.logical_and(fin, jnp.all(jnp.isfinite(x)))
    return fin


def _rescued_step(f, cfg: _SolverConfig, u, theta_p, t_n, h, idx):
    """One implicit step under fault injection and/or divergence rescue.

    Attempt 0 runs at the configured iteration cap; planned faults (keyed
    by the traced step index ``idx``, so they re-fire identically on
    adjoint recomputes) poison its *exit state* — NaN/Inf ``u1`` or a
    forced non-converged flag.  Poisoning the result rather than wrapping
    ``f`` keeps attempt 0's Newton loop HLO identical to the fault-free
    step at every clean index: a wrapped ``f`` inserts a select into the
    loop body, which perturbs XLA fusion under vmap and costs bitwise
    equality at sub-ulp level.  A failed attempt (not converged, or
    non-finite state) falls through a ``lax.cond`` chain: ``max_retries``
    clean retries at escalated Newton caps — bit-identical to the
    fault-free step whenever they converge, because the Newton while_loop
    exits dynamically on residual <= tol — then optionally two clean h/2
    sub-steps as a non-bitwise last resort.  Returns
    ``(u_next, StepInfo, rescued)`` with ``rescued`` an int32 flag: the
    accepted result came from a retry/halving branch.
    """
    rescue = cfg.rescue if cfg.rescue is not None else \
        RescueConfig(max_retries=0, escalate=1, dt_halving=False)
    fault = cfg.fault

    # attempt-0 fault gates (Python False when the plan has none)
    bad_nan = bad_inf = forced = False
    if fault is not None:
        bad_nan = fault.traced_gate("newton", "nan", idx)
        bad_inf = fault.traced_gate("newton", "inf", idx)
        forced = fault.traced_gate("newton", "diverge", idx)

    def poison(x):
        if bad_nan is not False:
            x = jnp.where(bad_nan, jnp.full_like(x, jnp.nan), x)
        if bad_inf is not False:
            x = jnp.where(bad_inf, jnp.full_like(x, jnp.inf), x)
        return x

    def attempt(iters, uu, tt, hh):
        return implicit_step(f, uu, theta_p, tt, hh, cfg.theta, int(iters),
                             cfg.newton_tol, cfg.gmres_iters, cfg.gmres_tol)

    def halved():
        cap = cfg.newton_iters * (rescue.escalate ** max(rescue.max_retries,
                                                         1))
        u_half, ia = attempt(cap, u, t_n, h * 0.5)
        u_full, ib = attempt(cap, u_half, t_n + h * 0.5, h * 0.5)
        info = StepInfo(ia.iters + ib.iters,
                        jnp.maximum(ia.residual, ib.residual),
                        jnp.logical_and(ia.converged, ib.converged))
        return u_full, info

    makers = [lambda: attempt(cfg.newton_iters, u, t_n, h)]
    for r in range(1, rescue.max_retries + 1):
        cap = cfg.newton_iters * (rescue.escalate ** r)
        makers.append(lambda cap=cap: attempt(cap, u, t_n, h))
    if rescue.dt_halving:
        makers.append(halved)

    def chain(i):
        u1, info = makers[i]()
        if i == 0:
            if bad_nan is not False or bad_inf is not False:
                u1 = jtu.tree_map(poison, u1)
                info = info._replace(residual=poison(info.residual))
            if forced is not False:
                info = info._replace(converged=jnp.logical_and(
                    info.converged, jnp.logical_not(forced)))
        ok = jnp.logical_and(info.converged, _tree_allfinite(u1))
        resc = jnp.asarray(1 if i > 0 else 0, jnp.int32)
        if i == len(makers) - 1:
            return u1, info, jnp.where(ok, resc, jnp.int32(0))
        return jax.lax.cond(ok,
                            lambda _: (u1, info, resc),
                            lambda _: chain(i + 1), None)

    return chain(0)


def _step(f, cfg: _SolverConfig, u, theta_p, t_n, h, idx=None):
    """Returns ``(u_next, StepInfo, rescued)``.  Dormant configs (no rescue,
    no fault plan) take the plain path with a constant-folded zero rescue
    count — the staged HLO is identical to the pre-rescue build."""
    if cfg.rescue is None and cfg.fault is None:
        u_next, info = implicit_step(f, u, theta_p, t_n, h, cfg.theta,
                                     cfg.newton_iters, cfg.newton_tol,
                                     cfg.gmres_iters, cfg.gmres_tol)
        return u_next, info, jnp.zeros((), jnp.int32)
    return _rescued_step(f, cfg, u, theta_p, t_n, h,
                         jnp.asarray(0 if idx is None else idx))


def _adjoint_step(f, cfg: _SolverConfig, u_n, u_next, theta_p, t_n, h, lam):
    return implicit_adjoint_step(f, u_n, u_next, theta_p, t_n, h, cfg.theta,
                                 lam, cfg.gmres_iters, cfg.gmres_tol)


# ---------------------------------------------------------------------------
# Table-2-style accounting for the implicit family (the planner's model)
# ---------------------------------------------------------------------------

def implicit_step_fevals(newton_iters: int = 10,
                         gmres_iters: int = 20) -> int:
    """f evaluations one implicit step costs (the recompute unit): the
    predictor's f, plus per Newton iteration one residual f, one f
    linearization per GMRES iteration (the jvp matrix action), and the
    exit-residual f."""
    return int(newton_iters) * (int(gmres_iters) + 2) + 1


def implicit_adjoint_fevals(gmres_iters: int = 20) -> int:
    """f linearizations one discrete-adjoint step costs (NFE-B unit): one
    vjp application per transposed-GMRES iteration plus the two explicit
    vjps (lam_n and the theta increment)."""
    return int(gmres_iters) + 2


def implicit_nfe_forward(n_steps: int, newton_iters: int = 10,
                         gmres_iters: int = 20) -> int:
    return n_steps * implicit_step_fevals(newton_iters, gmres_iters)


def implicit_nfe_backward(n_steps: int, adjoint: str,
                          ncheck: int | None = None,
                          newton_iters: int = 10,
                          gmres_iters: int = 20) -> int:
    """Analytic NFE-B for the implicit policies: every policy pays one
    transposed-GMRES adjoint solve per step; revolve/revolve2 additionally
    re-run the Newton solve for recomputed steps."""
    adj = n_steps * implicit_adjoint_fevals(gmres_iters)
    stepc = implicit_step_fevals(newton_iters, gmres_iters)
    if adjoint == "pnode":
        return adj
    if adjoint == "revolve":
        return revolve_mod.optimal_extra_steps(n_steps, ncheck) * stepc + adj
    if adjoint == "revolve2":
        n_bound = len(revolve_mod.sweep_checkpoint_positions(
            n_steps, ncheck)) + 1
        return (n_steps - n_bound) * stepc + adj
    raise ValueError(adjoint)


def implicit_checkpoint_floats(n_steps: int, adjoint: str, state_size: int,
                               ncheck: int | None = None) -> int:
    """Checkpoint storage in floats: ONLY converged states are stored (the
    Newton/GMRES iterates never enter the graph), so a slot costs S — not
    the explicit family's (N_s+1)S."""
    if adjoint == "pnode":
        return (n_steps + 1) * state_size
    if adjoint == "revolve":
        return (ncheck + 1) * state_size
    if adjoint == "revolve2":
        bounds = [0] + revolve_mod.sweep_checkpoint_positions(n_steps, ncheck)
        seg = max(b - a for a, b in zip(bounds, bounds[1:] + [n_steps]))
        return (len(bounds) + seg + 1) * state_size
    raise ValueError(adjoint)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def odeint_implicit(f: VectorField, u0: PyTree, theta_p: PyTree, *, dt: float,
                    n_steps: int, t0: float = 0.0, method: str = "cn",
                    adjoint: str = "pnode", ncheck: int | None = None,
                    offload: str | None = None,
                    offload_segment: int | None = None,
                    snaps_in_ram: int | None = None,
                    offload_dir: str | None = None,
                    mem_budget: int | None = None,
                    mem_verify: str = "measure",
                    newton_iters: int = 10, newton_tol: float = 1e-9,
                    gmres_iters: int = 20, gmres_tol: float = 1e-10,
                    mass=None, return_stats: bool = False,
                    obs=None, rescue=None, fault_plan=None,
                    resilient: bool = False) -> PyTree:
    """Fixed-step implicit theta-method solve with a discrete adjoint.

    ``adjoint`` selects the checkpoint policy (``pnode`` dense states /
    ``revolve`` / ``revolve2``; ``auto`` + ``mem_budget=<bytes>`` delegates
    to the ``repro.mem`` planner, which knows the implicit cost model).
    ``offload`` routes checkpoints through a ``repro.mem.offload`` store
    tier exactly like the explicit ``odeint`` (including the ``disk``
    tier and the ``snaps_in_ram``/``offload_dir`` RAM/disk split knobs);
    gradients are bitwise-identical across tiers.  ``return_stats=True`` returns
    ``(u_final, ImplicitStats)`` so Newton/GMRES non-convergence surfaces
    as ``stats.diverged`` instead of silently wrong states/gradients.

    The scanned ``pnode`` + ``offload="spill"`` path supports ``jax.vmap``
    (batched stiff ensembles under a byte budget): the spill callbacks are
    vectorized, one host round-trip per segment carries the whole batch.
    The slot-addressed revolve tiers reject vmap up front like the
    explicit path does.

    ``obs=`` attaches a ``repro.obs.FlightRecorder``: every sweep emits
    a runtime ``implicit.steps`` event carrying the stacked per-step
    Newton exit states (iterations, residual, converged — one tap per
    scan, expanded back to per-step records by
    ``FlightRecorder.implicit_steps()``), reverse-pass re-advances emit
    ``implicit.recompute``, and the checkpoint store records its
    traffic.  Debug-effect taps only — gradients are bitwise-identical
    to ``obs=None``, which traces nothing extra (zero overhead off).

    Fault tolerance (PR 8; all three knobs default OFF and stage zero
    extra ops when off):

    ``rescue=`` a ``RescueConfig`` (or ``True`` for the defaults) turns on
    in-step divergence rescue: a failed step (Newton cap exhausted, or a
    non-finite state) is retried at escalated iteration caps — bitwise the
    fault-free step when the retry converges, since the Newton while_loop
    exits dynamically — with an optional two-half-step (non-bitwise) last
    resort.  Rescued-step counts surface as ``stats.rescued`` and
    ``implicit.rescue`` obs events.

    ``fault_plan=`` a ``repro.ft.FaultPlan`` injects deterministic faults:
    traced ``newton`` nan/inf/diverge gates keyed by absolute step index
    (they re-fire identically on adjoint recomputes — required for bitwise
    recovery), host-side spill callback drops/corruption/flakes, and tier
    outages that degrade ``offload`` down the spill→disk→host→device
    ladder before the store is built.

    ``resilient=True`` (scanned pnode+spill path only) checksums spilled
    segments and, when the bwd prefetch fails verification, re-integrates
    the segment forward from its entry state carried in the residuals —
    reusing the recompute machinery, so recovered gradients stay bitwise
    the fault-free ones.
    """
    n_steps = int(n_steps)
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")
    theta = _theta_of(method)

    if mass is not None:
        if (adjoint != "pnode" or offload is not None
                or mem_budget is not None or rescue is not None
                or fault_plan is not None or resilient):
            raise ValueError(
                "mass-matrix solves support only the default dense path "
                "(adjoint='pnode', no offload/mem_budget and no "
                "rescue/fault_plan/resilient): the mass operator is closed "
                "over statically and the solve is forward-only (see "
                "_odeint_implicit_mass)")
        return _odeint_implicit_mass(f, mass, float(t0), float(dt), n_steps,
                                     theta, int(newton_iters),
                                     float(newton_tol), int(gmres_iters),
                                     float(gmres_tol), u0, theta_p,
                                     return_stats)

    from_auto = adjoint == "auto"
    if from_auto:
        from repro.mem.planner import plan_odeint  # deferred: import cycle
        plan = plan_odeint(
            f, u0, theta_p, dt=float(dt), n_steps=n_steps, t0=float(t0),
            method=method, mem_budget=mem_budget, verify=mem_verify,
            solver_opts=dict(newton_iters=int(newton_iters),
                             newton_tol=float(newton_tol),
                             gmres_iters=int(gmres_iters),
                             gmres_tol=float(gmres_tol)))
        adjoint, ncheck = plan.policy, plan.ncheck
        offload = plan.offload if plan.offload is not None else offload
        if plan.snaps_in_ram is not None and snaps_in_ram is None:
            snaps_in_ram = plan.snaps_in_ram
    elif mem_budget is not None:
        raise ValueError(
            "mem_budget is only meaningful with adjoint='auto' (the planner "
            f"chooses the policy); got adjoint={adjoint!r}")
    if adjoint == "naive":
        raise ValueError(
            "adjoint='naive' (AD through the solver) is impossible for "
            "implicit methods: Newton/GMRES run in while_loops with no "
            "reverse rule — the paper's motivating limitation; use one of "
            f"{IMPLICIT_POLICIES} (or 'auto' with mem_budget)")
    if adjoint not in IMPLICIT_POLICIES:
        raise ValueError(f"unknown implicit adjoint policy {adjoint!r}; one "
                         f"of {IMPLICIT_POLICIES} (or 'auto' with "
                         "mem_budget)")
    from repro.core.adjoint import _OFFLOAD_TIERS, _validate_ncheck
    if offload not in _OFFLOAD_TIERS:
        raise ValueError(f"unknown offload tier {offload!r}; one of "
                         f"{_OFFLOAD_TIERS}")
    offloaded = offload in ("host", "spill", "disk")
    if offload_segment is not None:
        if offload not in ("spill", "disk"):
            raise ValueError(
                "offload_segment only applies to the callback spill tiers "
                f"(offload='spill'/'disk'); got offload={offload!r}")
        if adjoint != "pnode":
            raise ValueError(
                "offload_segment only applies to the scanned pnode sweep "
                f"(adjoint='pnode'); adjoint={adjoint!r} checkpoints are "
                "slot-addressed at trace time")
        offload_segment = int(offload_segment)
        if offload_segment < 1:
            raise ValueError(
                f"offload_segment must be >= 1, got {offload_segment}")
    if snaps_in_ram is not None:
        if offload != "spill":
            raise ValueError(
                "snaps_in_ram is the spill tier's RAM/disk split "
                "(offload='spill'; offload='disk' is the snaps_in_ram=0 "
                f"corner); got offload={offload!r}")
        snaps_in_ram = int(snaps_in_ram)
        if snaps_in_ram < 0:
            raise ValueError(
                f"snaps_in_ram must be >= 0, got {snaps_in_ram}")
    if offload_dir is not None and offload not in ("spill", "disk"):
        raise ValueError(
            "offload_dir pins the disk tier's segment files "
            "(offload='spill'/'disk'); got offload="
            f"{offload!r}")

    if rescue is True:
        rescue = RescueConfig()
    if rescue is not None and not isinstance(rescue, RescueConfig):
        raise ValueError(f"rescue must be a RescueConfig, True, or None; "
                         f"got {rescue!r}")
    if resilient and not (adjoint == "pnode"
                          and offload in ("spill", "disk")):
        raise ValueError(
            "resilient=True (checked prefetch + recompute fallback) applies "
            "to the scanned spill paths (adjoint='pnode', "
            f"offload='spill'/'disk'); got adjoint={adjoint!r}, "
            f"offload={offload!r}")
    if fault_plan is not None and offloaded:
        # tier outage in the plan: walk the degradation ladder BEFORE the
        # store is built, so the solve runs on a healthy tier
        from repro.mem.offload import effective_tier
        eff = effective_tier(offload, fault_plan,
                             scanned=(adjoint == "pnode"), obs=obs)
        if eff != offload:
            offload = eff
            offloaded = offload in ("host", "spill", "disk")
            if offload not in ("spill", "disk"):
                offload_segment = None
                snaps_in_ram = None
            resilient = resilient and offload in ("spill", "disk")

    cfg = _SolverConfig(theta, int(newton_iters), float(newton_tol),
                        int(gmres_iters), float(gmres_tol),
                        rescue=rescue, fault=fault_plan,
                        resilient=bool(resilient))
    t0, dt = float(t0), float(dt)
    if obs is not None:
        extra = {}
        if rescue is not None:
            extra["rescue"] = True
        if fault_plan is not None:
            extra["faulted"] = True
        if resilient:
            extra["resilient"] = True
        obs.record("implicit.solve", method=method, adjoint=adjoint,
                   n_steps=n_steps, dt=dt, t0=t0,
                   ncheck=None if ncheck is None else int(ncheck),
                   offload=offload, newton_iters=cfg.newton_iters,
                   gmres_iters=cfg.gmres_iters, planned=from_auto, **extra)

    if adjoint in ("revolve", "revolve2"):
        ncheck = _validate_ncheck(adjoint, ncheck, n_steps)
        if offloaded:
            # slot-addressed stores see one logical slot per batch — the
            # same aliasing hazard the explicit path rejects up front
            from repro.core.adjoint import _reject_vmap_offload
            _reject_vmap_offload(u0, theta_p,
                                 f"odeint_implicit(adjoint={adjoint!r})")
        from repro.mem.offload import make_store  # deferred: import cycle
        store = make_store(offload, fault_plan=fault_plan,
                           snaps_in_ram=snaps_in_ram, disk_dir=offload_dir)
        if obs is not None:
            store.bind_obs(obs)
        impl = _imp_revolve if adjoint == "revolve" else _imp_revolve2
        u_final, stats = impl(f, cfg, t0, dt, n_steps, ncheck, store, u0,
                              theta_p)
    elif offloaded:  # pnode
        if offload == "host":
            raise ValueError(
                "offload='host' applies to trace-time checkpoint sites "
                "(revolve/revolve2); the scanned pnode sweep offloads "
                "through offload='spill'")
        from repro.mem.offload import (batch_scale, default_segment,
                                       make_store)
        segment = (offload_segment if offload_segment is not None
                   else default_segment(n_steps))
        store = make_store(offload, fault_plan=fault_plan,
                           integrity=bool(resilient),
                           snaps_in_ram=snaps_in_ram, disk_dir=offload_dir)
        if obs is not None:
            store.bind_obs(obs)
        # mapped axes are only visible HERE (as BatchTracers on the args);
        # the custom_vjp fwd is retraced at logical shapes, so the store's
        # payload-cap chunking needs the batch factor handed to it
        store.payload_scale = batch_scale((u0, theta_p))
        u_final, stats = _imp_spill(f, cfg, t0, dt, n_steps, store,
                                    min(segment, n_steps), u0, theta_p)
    else:
        u_final, stats = _imp_dense(f, cfg, t0, dt, n_steps, obs, u0,
                                    theta_p)
    return (u_final, stats) if return_stats else u_final


# ---------------------------------------------------------------------------
# mass-matrix path (forward-only; kept from the pre-offload implementation)
# ---------------------------------------------------------------------------

def _odeint_implicit_mass(f, mass, t0, dt, n_steps, theta, newton_iters,
                          newton_tol, gmres_iters, gmres_tol, u0, theta_p,
                          return_stats):
    """Mass-matrix path (no custom_vjp shortcut: the mass operator is
    closed over statically; forward-only use)."""
    def body(carry, n):
        u, stats = carry
        t_n = t0 + dt * n
        u_next, info = implicit_step(f, u, theta_p, t_n, dt, theta,
                                     newton_iters, newton_tol, gmres_iters,
                                     gmres_tol, mass=mass)
        return (u_next, _stats_merge(stats, info)), None

    (u_final, stats), _ = jax.lax.scan(body, (u0, _stats_zero()),
                                       jnp.arange(n_steps))
    return (u_final, stats) if return_stats else u_final


# ---------------------------------------------------------------------------
# dense pnode: every converged state rides the custom_vjp residuals
# ---------------------------------------------------------------------------

def _imp_solve(f, cfg, t0, dt, n_steps, u0, theta_p, save_states, base=0,
               obs=None, obs_kind="implicit.steps"):
    track_rescue = cfg.rescue is not None or cfg.fault is not None

    def body(carry, n):
        u, stats = carry
        # t as t0 + dt*(base+n) everywhere (not (t0+dt*base) + dt*n) so a
        # recomputed segment's times — hence its states — are bitwise the
        # forward sweep's
        t_n = t0 + dt * (base + n)
        u_next, info, resc = _step(f, cfg, u, theta_p, t_n, dt, base + n)
        ys = u if save_states else None
        if obs is not None:
            ys = (ys, info, resc if track_rescue else None)
        return (u_next, _stats_merge(stats, info, resc)), ys

    (u_final, stats), ys = jax.lax.scan(body, (u0, _stats_zero()),
                                        jnp.arange(n_steps))
    if obs is not None:
        states, infos, rescs = ys
        # ONE stacked debug-effect tap at the top level of the rule: a
        # per-step tap inside the scan body would be silently dropped in
        # custom_vjp fwd rules on jax 0.4.37 (scan-in-fwd effects; see
        # repro.obs.trace docstring), the top-level tap on the stacked
        # StepInfo is not.  Nothing feeds the computation, so numerics
        # are unchanged.
        obs.emit(obs_kind, base=jnp.asarray(base), iters=infos.iters,
                 residual=infos.residual, converged=infos.converged)
        if track_rescue:  # separate stream: dormant event logs unchanged
            obs.emit("implicit.rescue", base=jnp.asarray(base),
                     rescued=rescs)
    else:
        states = ys
    return u_final, stats, states


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _imp_dense(f, cfg, t0, dt, n_steps, obs, u0, theta_p):
    u_final, stats, _ = _imp_solve(f, cfg, t0, dt, n_steps, u0, theta_p,
                                   save_states=False, obs=obs)
    return u_final, stats


@scope("implicit/fwd")
def _imp_dense_fwd(f, cfg, t0, dt, n_steps, obs, u0, theta_p):
    u_final, stats, states = _imp_solve(f, cfg, t0, dt, n_steps, u0, theta_p,
                                        save_states=True, obs=obs)
    return (u_final, stats), (states, u_final, theta_p)


@scope("implicit/bwd")
def _imp_dense_bwd(f, cfg, t0, dt, n_steps, obs, res, ct):
    g, _ = ct  # the stats output is non-differentiable; drop its cotangent
    states, u_final, theta_p = res

    # u_next for step n is states[n+1] (or u_final for the last step)
    u_nexts = jtu.tree_map(
        lambda s, uf: jnp.concatenate([s[1:], uf[None]], axis=0), states,
        u_final)

    def body(carry, inp):
        lam, mu = carry
        u_n, u_next, n = inp
        t_n = t0 + dt * n
        lam, th_bar = _adjoint_step(f, cfg, u_n, u_next, theta_p, t_n, dt,
                                    lam)
        return (lam, tree_add(mu, th_bar)), None

    (lam, mu), _ = jax.lax.scan(
        body, (g, tree_zeros_like(theta_p)),
        (states, u_nexts, jnp.arange(n_steps)), reverse=True)
    return lam, mu


_imp_dense.defvjp(_imp_dense_fwd, _imp_dense_bwd)


# ---------------------------------------------------------------------------
# revolve: Prop-2 schedule over converged states, Newton re-advance between
# checkpoints, slots in a CheckpointStore tier
# ---------------------------------------------------------------------------

def _imp_advance(f, cfg, u, theta_p, start_idx, m, t0, dt, stats=None,
                 obs=None, obs_kind="implicit.steps"):
    """Re-run m implicit steps from u (step indices start_idx..start_idx+m-1)
    — bitwise-identical to the forward sweep's states since the op sequence
    is the same.  Stats aggregation is optional (the reverse-pass advances
    drop it: their convergence is the forward's, already reported)."""
    if m <= 0:
        return (u, stats) if stats is not None else u

    track = stats is not None

    def body(carry, k):
        u, st = carry
        t = t0 + dt * (start_idx + k)
        u, info, resc = _step(f, cfg, u, theta_p, t, dt, start_idx + k)
        return (u, _stats_merge(st, info, resc) if track else st), \
            (info if obs is not None else None)

    (u, stats), infos = jax.lax.scan(body, (u, stats), jnp.arange(m))
    if obs is not None:  # stacked top-level tap (see _imp_solve)
        obs.emit(obs_kind, base=jnp.asarray(start_idx), iters=infos.iters,
                 residual=infos.residual, converged=infos.converged)
    return (u, stats) if track else u


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6))
def _imp_revolve(f, cfg, t0, dt, n_steps, ncheck, store, u0, theta_p):
    u_final, stats, _ = _imp_solve(f, cfg, t0, dt, n_steps, u0, theta_p,
                                   save_states=False, obs=store._obs)
    return u_final, stats


@scope("imp_revolve/fwd")
def _imp_revolve_fwd(f, cfg, t0, dt, n_steps, ncheck, store, u0, theta_p):
    positions = [0] + revolve_mod.sweep_checkpoint_positions(n_steps, ncheck)
    bounds = positions + [n_steps]
    u, stats = u0, _stats_zero()
    for a, b in zip(bounds[:-1], bounds[1:]):
        store.put(a, u)
        u, stats = _imp_advance(f, cfg, u, theta_p, a, b - a, t0, dt, stats,
                                obs=store._obs)
    return (u, stats), (store.pack(), u, theta_p)


@scope("imp_revolve/bwd")
def _imp_revolve_bwd(f, cfg, t0, dt, n_steps, ncheck, store, res, ct):
    g, _ = ct
    ckpt_res, u_final, theta_p = res
    positions = [0] + revolve_mod.sweep_checkpoint_positions(n_steps, ncheck)
    store.unpack(ckpt_res, positions)

    lam = g
    mu = tree_zeros_like(theta_p)
    # the schedule adjoints steps in strictly decreasing order, so u_{n+1}
    # for the step about to be adjointed is always the previous adjoint's
    # checkpoint (u_final initially) — no stage storage needed at all
    u_next = u_final
    for act in revolve_mod.reverse_schedule(n_steps, ncheck):
        kind = act[0]
        if kind == "advance":
            _, start, m = act
            u = store.get(start)
            u = _imp_advance(f, cfg, u, theta_p, start, m, t0, dt,
                             obs=store._obs, obs_kind="implicit.recompute")
            store.put(start + m, u)
        elif kind == "adjoint":
            _, idx = act
            u_i = store.get(idx)
            store.free(idx)
            t_i = t0 + dt * idx
            lam, th_bar = _adjoint_step(f, cfg, u_i, u_next, theta_p, t_i,
                                        dt, lam)
            mu = tree_add(mu, th_bar)
            u_next = u_i
            # trace-time-unrolled chain: serialize so XLA cannot keep every
            # step's theta-sized gradients live at once (see explicit path)
            lam, mu = jax.lax.optimization_barrier((lam, mu))
        elif kind == "free":
            store.free(act[1])
        else:  # pragma: no cover
            raise ValueError(act)
    return lam, mu


_imp_revolve.defvjp(_imp_revolve_fwd, _imp_revolve_bwd)


# ---------------------------------------------------------------------------
# revolve2: boundary states + scanned per-segment re-advance/adjoint
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6))
def _imp_revolve2(f, cfg, t0, dt, n_steps, ncheck, store, u0, theta_p):
    u_final, stats, _ = _imp_solve(f, cfg, t0, dt, n_steps, u0, theta_p,
                                   save_states=False, obs=store._obs)
    return u_final, stats


@scope("imp_revolve2/fwd")
def _imp_revolve2_fwd(f, cfg, t0, dt, n_steps, ncheck, store, u0, theta_p):
    from repro.core.adjoint import _segment_bounds
    u, stats = u0, _stats_zero()
    for a, b in _segment_bounds(n_steps, ncheck):
        store.put(a, u)
        u, stats = _imp_advance(f, cfg, u, theta_p, a, b - a, t0, dt, stats,
                                obs=store._obs)
    return (u, stats), (store.pack(), theta_p)


@scope("imp_revolve2/bwd")
def _imp_revolve2_bwd(f, cfg, t0, dt, n_steps, ncheck, store, res, ct):
    g, _ = ct
    ckpt_res, theta_p = res
    from repro.core.adjoint import _segment_bounds
    bounds = _segment_bounds(n_steps, ncheck)
    store.unpack(ckpt_res, [a for a, _ in bounds])

    lam = g
    mu = tree_zeros_like(theta_p)
    for a, b in reversed(bounds):
        m = b - a
        u_a = store.get(a)
        store.free(a)
        # re-advance the segment, saving states (scan); the recomputed
        # segment end is bitwise the forward's u_b
        u_b, _, states = _imp_solve(f, cfg, t0, dt, m, u_a, theta_p,
                                    save_states=True, base=a,
                                    obs=store._obs,
                                    obs_kind="implicit.recompute")
        u_nexts = jtu.tree_map(
            lambda s, ub: jnp.concatenate([s[1:], ub[None]], axis=0), states,
            u_b)

        def body(carry, inp):
            lam_, mu_ = carry
            u_n, u_next, n = inp
            t_n = t0 + dt * (a + n)
            lam_, th_bar = _adjoint_step(f, cfg, u_n, u_next, theta_p, t_n,
                                         dt, lam_)
            return (lam_, tree_add(mu_, th_bar)), None

        (lam, mu), _ = jax.lax.scan(
            body, (lam, mu), (states, u_nexts, jnp.arange(m)), reverse=True)
    return lam, mu


_imp_revolve2.defvjp(_imp_revolve2_fwd, _imp_revolve2_bwd)


# ---------------------------------------------------------------------------
# pnode + spill: segment-batched host-callback checkpoint streaming.  The
# residual is one token scalar + u_final, so compiled device-live memory is
# O(segment) state vectors regardless of N_t.  vmap-compatible: the store's
# batched callbacks ship the whole batch per round-trip (each element's
# checkpoints occupy its own block of the slot) — the per-batch-element key
# scheme that lets thousands of vmapped stiff systems train under one
# memory budget.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6))
def _imp_spill(f, cfg, t0, dt, n_steps, store, segment, u0, theta_p):
    u_final, stats, _ = _imp_solve(f, cfg, t0, dt, n_steps, u0, theta_p,
                                   save_states=False, obs=store._obs)
    return u_final, stats


@scope("imp_spill/fwd")
def _imp_spill_fwd(f, cfg, t0, dt, n_steps, store, segment, u0, theta_p):
    n_full, rem = divmod(n_steps, segment)
    obs = store._obs
    track_rescue = cfg.rescue is not None or cfg.fault is not None
    # resilient mode keeps each segment's ENTRY state in the residuals
    # (O(sqrt(N)) extra liveness) so the bwd sweep can re-integrate a
    # segment whose spilled payload fails its integrity check
    resilient = cfg.resilient

    def run_segment(u, stats, tok, base, m):
        def step(carry, i):
            u, st = carry
            t = t0 + dt * (base + i)
            u_next, info, resc = _step(f, cfg, u, theta_p, t, dt, base + i)
            return (u_next, _stats_merge(st, info, resc)), \
                ((u, info) if obs is not None else u)

        (u, stats), ys = jax.lax.scan(step, (u, stats), jnp.arange(m))
        staged, infos = ys if obs is not None else (ys, None)
        tok = store.write_batch(tok, base, staged)  # ONE callback, m slots
        return u, stats, tok, infos

    u, stats, tok = u0, _stats_zero(), store.init_token()
    seg_infos = rem_infos = None
    seg_starts = rem_start = None
    if n_full:
        def seg_body(carry, s_idx):
            u, stats, tok = carry
            u_in = u
            u, stats, tok, infos = run_segment(u, stats, tok,
                                               s_idx * segment, segment)
            return (u, stats, tok), \
                (infos, u_in if resilient else None)

        (u, stats, tok), (seg_infos, seg_starts) = jax.lax.scan(
            seg_body, (u, stats, tok), jnp.arange(n_full))
    if rem:
        rem_start = u if resilient else None
        u, stats, tok, rem_infos = run_segment(
            u, stats, tok, jnp.asarray(n_full * segment), rem)
    if obs is not None:
        # stacked top-level taps (see _imp_solve: per-step taps inside
        # the scans are dropped in custom_vjp fwd rules on jax 0.4.37)
        if seg_infos is not None:
            flat = jtu.tree_map(
                lambda a: a.reshape((-1,) + a.shape[2:]), seg_infos)
            obs.emit("implicit.steps", base=jnp.asarray(0),
                     iters=flat.iters, residual=flat.residual,
                     converged=flat.converged)
        if rem_infos is not None:
            obs.emit("implicit.steps", base=jnp.asarray(n_full * segment),
                     iters=rem_infos.iters, residual=rem_infos.residual,
                     converged=rem_infos.converged)
        if track_rescue:
            obs.emit("implicit.rescue", base=jnp.asarray(0),
                     rescued=stats.rescued)
    return (u, stats), (tok, u, theta_p, seg_starts, rem_start)


@scope("imp_spill/bwd")
def _imp_spill_bwd(f, cfg, t0, dt, n_steps, store, segment, res, ct):
    g, _ = ct
    tok, u_final, theta_p, seg_starts, rem_start = res
    n_full, rem = divmod(n_steps, segment)
    obs = store._obs
    resilient = cfg.resilient

    def recompute_states(u_start, base, m):
        # identical op sequence to the forward sub-sweep (same
        # t0 + dt*(base+i) times, same _step — injected faults and their
        # rescues re-fire, keyed by the absolute step index), so the
        # recovered states are bitwise the ones the lost segment held
        def step(u, i):
            t = t0 + dt * (base + i)
            u_next, _info, _resc = _step(f, cfg, u, theta_p, t, dt, base + i)
            return u_next, u

        _, states = jax.lax.scan(step, u_start, jnp.arange(m))
        return states

    def run_segment_bwd(lam, mu, u_next, tok, base, m, u_start):
        if resilient:
            tok, ok, fetched = store.prefetch_checked(tok, base, m)
            states = jax.lax.cond(
                ok, lambda _: fetched,
                lambda _: recompute_states(u_start, base, m), None)
            if obs is not None:  # bwd-rule emits survive jit(grad)
                obs.emit("spill.recover", base=jnp.asarray(base), ok=ok)
        else:
            tok, states = store.prefetch(tok, base, m)  # ONE callback
            # software-pipeline the NEXT (earlier) full segment: queue its
            # background gather now so segment base-segment streams in
            # while this segment's adjoint scan runs (no-op for tiers
            # without an async path; resilient mode stays synchronous so
            # checksum verification and fault injection keep their
            # deterministic callback order)
            nb = base - segment
            tok = jax.lax.cond(
                nb >= 0,
                lambda t: store.prefetch_issue(t, jnp.maximum(nb, 0),
                                               segment),
                lambda t: t, tok)
        u_nexts = jtu.tree_map(
            lambda s, un: jnp.concatenate([s[1:], un[None]], axis=0), states,
            u_next)

        def step(carry, inp):
            lam, mu = carry
            u_n, u_np1, i = inp
            t_n = t0 + dt * (base + i)
            lam, th_bar = _adjoint_step(f, cfg, u_n, u_np1, theta_p, t_n, dt,
                                        lam)
            return (lam, tree_add(mu, th_bar)), None

        (lam, mu), _ = jax.lax.scan(step, (lam, mu),
                                    (states, u_nexts, jnp.arange(m)),
                                    reverse=True)
        # the next (earlier) segment's u_next is this segment's first state
        u_prev = jtu.tree_map(lambda s: s[0], states)
        return lam, mu, u_prev, tok

    lam, mu, u_next = g, tree_zeros_like(theta_p), u_final
    if rem:  # the trailing partial segment is adjointed first
        lam, mu, u_next, tok = run_segment_bwd(
            lam, mu, u_next, tok, jnp.asarray(n_full * segment), rem,
            rem_start)
    elif n_full and not resilient:
        # no partial segment issued the first background gather — warm the
        # pipeline for the last full segment before the scan consumes it
        tok = store.prefetch_issue(tok, jnp.asarray((n_full - 1) * segment),
                                   segment)
    if n_full:
        def seg_body(carry, inp):
            s_idx, u_start = inp
            lam, mu, u_next, tok = carry
            lam, mu, u_next, tok = run_segment_bwd(lam, mu, u_next, tok,
                                                   s_idx * segment, segment,
                                                   u_start)
            return (lam, mu, u_next, tok), None

        (lam, mu, u_next, tok), _ = jax.lax.scan(
            seg_body, (lam, mu, u_next, tok),
            (jnp.arange(n_full), seg_starts), reverse=True)
    return lam, mu


_imp_spill.defvjp(_imp_spill_fwd, _imp_spill_bwd)
