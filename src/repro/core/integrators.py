"""Explicit Runge-Kutta stepping on pytrees + fixed-step forward solves.

The vector field signature everywhere in this framework is

    f(u, theta, t) -> du/dt

with ``u`` and ``theta`` arbitrary pytrees and ``t`` a scalar.

``rk_step`` computes one step and returns the stage derivatives so that the
high-level discrete adjoint (``core/adjoint.py``) can reconstruct stage
inputs without re-evaluating ``f`` — this is the paper's "checkpoint the
states *and stage values*" design (PNODE).  ``rk_adjoint_step`` implements
the discrete adjoint recursion (eq. 7 of the paper, in the standard RK
adjoint form of Hager/Sandu): one transposed JVP of ``f`` per stage, so the
backpropagation graph depth is O(N_l), independent of N_t.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.core.tableaus import ButcherTableau, get_tableau

PyTree = Any
VectorField = Callable[[PyTree, PyTree, jax.Array], PyTree]


# ---------------------------------------------------------------------------
# pytree arithmetic helpers
# ---------------------------------------------------------------------------

def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jtu.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jtu.tree_map(jnp.subtract, a, b)


def tree_scale(s, a: PyTree) -> PyTree:
    return jtu.tree_map(lambda x: s * x, a)


def tree_axpy(s, x: PyTree, y: PyTree) -> PyTree:
    """y + s * x elementwise over the pytree."""
    return jtu.tree_map(lambda xi, yi: yi + s * xi, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jtu.tree_map(jnp.zeros_like, a)


def tree_lincomb(coeffs, trees) -> PyTree:
    """sum_i coeffs[i] * trees[i]; skips zero coefficients (trace-time)."""
    acc = None
    for c, tr in zip(coeffs, trees):
        if isinstance(c, float) and c == 0.0:
            continue
        term = tree_scale(c, tr)
        acc = term if acc is None else tree_add(acc, term)
    if acc is None:
        acc = tree_zeros_like(trees[0])
    return acc


def tree_stack(trees) -> PyTree:
    return jtu.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree, n) -> list:
    return [jtu.tree_map(lambda x: x[i], tree) for i in range(n)]


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    leaves = jtu.tree_map(lambda x, y: jnp.sum(x * y), a, b)
    return jtu.tree_reduce(jnp.add, leaves)


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jtu.tree_map(lambda x: x.astype(dtype), a)


# ---------------------------------------------------------------------------
# explicit RK stepping
# ---------------------------------------------------------------------------

def rk_stages(f: VectorField, tab: ButcherTableau, u: PyTree, theta: PyTree,
              t, h) -> list:
    """Compute the stage derivatives k_1..k_s (list of pytrees)."""
    ks: list = []
    for i in range(tab.num_stages):
        xi = u
        for j in range(i):
            aij = float(tab.a[i, j])
            if aij != 0.0:
                xi = tree_axpy(h * aij, ks[j], xi)
        ks.append(f(xi, theta, t + float(tab.c[i]) * h))
    return ks


def rk_combine(tab: ButcherTableau, u: PyTree, ks, h) -> PyTree:
    """u + h * sum_i b_i k_i."""
    out = u
    for i in range(tab.num_stages):
        bi = float(tab.b[i])
        if bi != 0.0:
            out = tree_axpy(h * bi, ks[i], out)
    return out


def rk_step(f: VectorField, tab: ButcherTableau, u: PyTree, theta: PyTree,
            t, h) -> Tuple[PyTree, PyTree]:
    """One explicit RK step.  Returns (u_next, stages) with stages stacked
    along a new leading axis of size N_s (so it scans cleanly)."""
    ks = rk_stages(f, tab, u, theta, t, h)
    u_next = rk_combine(tab, u, ks, h)
    return u_next, tree_stack(ks)


def rk_stage_inputs(tab: ButcherTableau, u: PyTree, stages: PyTree, h) -> list:
    """Reconstruct the stage inputs x_i = u + h*sum_j a_ij k_j from stored
    stage derivatives — no f evaluations (the PNODE trick)."""
    ks = tree_unstack(stages, tab.num_stages)
    xs = []
    for i in range(tab.num_stages):
        xi = u
        for j in range(i):
            aij = float(tab.a[i, j])
            if aij != 0.0:
                xi = tree_axpy(h * aij, ks[j], xi)
        xs.append(xi)
    return xs


def rk_adjoint_step(f: VectorField, tab: ButcherTableau, u: PyTree,
                    stages: PyTree, theta: PyTree, t, h,
                    lam: PyTree) -> Tuple[PyTree, PyTree]:
    """Discrete adjoint of one explicit RK step (the paper's eq. 7).

    Given the step's initial state ``u``, its stored stage derivatives, and
    the incoming adjoint ``lam`` (= lambda_{n+1}), returns

        lam_prev  = (d u_{n+1} / d u_n)^T lam
        theta_bar = (d u_{n+1} / d theta)^T lam     (increment for mu)

    Implementation: reverse stage recursion
        v_i     = b_i * lam + sum_{j>i} a_ji * w_j
        (w_i, g_i) = vjp(f, x_i)(h * v_i)        # one transposed JVP per stage
        lam_prev = lam + sum_i w_i
        theta_bar = sum_i g_i
    """
    s = tab.num_stages
    xs = rk_stage_inputs(tab, u, stages, h)
    ws: list = [None] * s
    lam_prev = lam
    theta_bar = None
    for i in reversed(range(s)):
        vi = tree_scale(float(tab.b[i]), lam)
        for j in range(i + 1, s):
            aji = float(tab.a[j, i])
            if aji != 0.0 and ws[j] is not None:
                vi = tree_axpy(aji, ws[j], vi)
        if float(tab.b[i]) == 0.0 and all(
            float(tab.a[j, i]) == 0.0 for j in range(i + 1, s)
        ):
            ws[i] = None
            continue
        ti = t + float(tab.c[i]) * h
        _, vjp_fn = jax.vjp(lambda uu, th: f(uu, th, ti), xs[i], theta)
        wi, gi = vjp_fn(tree_scale(h, vi))
        ws[i] = wi
        lam_prev = tree_add(lam_prev, wi)
        theta_bar = gi if theta_bar is None else tree_add(theta_bar, gi)
    if theta_bar is None:
        theta_bar = tree_zeros_like(theta)
    return lam_prev, theta_bar


# ---------------------------------------------------------------------------
# fixed-step forward solves
# ---------------------------------------------------------------------------

def solve_fixed(f: VectorField, method: str, u0: PyTree, theta: PyTree,
                t0: float, h: float, n_steps: int,
                save_states: bool = False,
                save_stages: bool = False):
    """Integrate n_steps of size h with a fixed-step explicit RK method.

    Returns (u_final, saved) where ``saved`` is a dict possibly containing
    'states' (the N_t *pre-step* states u_0..u_{N_t-1}) and 'stages'
    (N_t stacked stage pytrees).
    """
    tab = get_tableau(method)

    def body(carry, n):
        u = carry
        t = t0 + n.astype(jnp.result_type(float)) * h
        u_next, stages = rk_step(f, tab, u, theta, t, h)
        out = {}
        if save_states:
            out["states"] = u
        if save_stages:
            out["stages"] = stages
        return u_next, out

    u_final, saved = jax.lax.scan(body, u0, jnp.arange(n_steps))
    return u_final, saved


def solve_fixed_trajectory(f: VectorField, method: str, u0: PyTree,
                           theta: PyTree, t0: float, h: float, n_steps: int):
    """Like solve_fixed but returns the full trajectory u_1..u_{N_t}
    (stacked along a new leading axis), for plotting / loss-over-trajectory."""
    tab = get_tableau(method)

    def body(carry, n):
        u = carry
        t = t0 + n.astype(jnp.result_type(float)) * h
        u_next, _ = rk_step(f, tab, u, theta, t, h)
        return u_next, u_next

    u_final, traj = jax.lax.scan(body, u0, jnp.arange(n_steps))
    return u_final, traj
