"""Explicit Runge-Kutta stepping on pytrees + fixed-step forward solves.

The vector field signature everywhere in this framework is

    f(u, theta, t) -> du/dt

with ``u`` and ``theta`` arbitrary pytrees and ``t`` a scalar.

``rk_step`` computes one step and returns the stage derivatives so that the
high-level discrete adjoint (``core/adjoint.py``) can reconstruct stage
inputs without re-evaluating ``f`` — this is the paper's "checkpoint the
states *and stage values*" design (PNODE).  ``rk_adjoint_step`` implements
the discrete adjoint recursion (eq. 7 of the paper, in the standard RK
adjoint form of Hager/Sandu): one transposed JVP of ``f`` per stage, so the
backpropagation graph depth is O(N_l), independent of N_t.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.core.tableaus import ButcherTableau, get_tableau

PyTree = Any
VectorField = Callable[[PyTree, PyTree, jax.Array], PyTree]


# ---------------------------------------------------------------------------
# pytree arithmetic helpers
# ---------------------------------------------------------------------------

def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jtu.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jtu.tree_map(jnp.subtract, a, b)


def tree_scale(s, a: PyTree) -> PyTree:
    return jtu.tree_map(lambda x: s * x, a)


def tree_axpy(s, x: PyTree, y: PyTree) -> PyTree:
    """y + s * x elementwise over the pytree."""
    return jtu.tree_map(lambda xi, yi: yi + s * xi, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jtu.tree_map(jnp.zeros_like, a)


def tree_lincomb(coeffs, trees) -> PyTree:
    """sum_i coeffs[i] * trees[i]; skips zero coefficients (trace-time)."""
    acc = None
    for c, tr in zip(coeffs, trees):
        if isinstance(c, float) and c == 0.0:
            continue
        term = tree_scale(c, tr)
        acc = term if acc is None else tree_add(acc, term)
    if acc is None:
        acc = tree_zeros_like(trees[0])
    return acc


def tree_stage_lincomb(base: PyTree, pairs, scale=None,
                       base_coeff: float | None = None,
                       fused: bool = False) -> PyTree:
    """``base_coeff*base + sum (scale*w_i) * tree_i`` over (w_i, tree_i)
    ``pairs`` — the RK stage-update / stage-adjoint primitive.

    ``fused=False`` is the seed path: one ``tree_axpy`` per pair, exactly
    the historical accumulation order.  ``fused=True`` lowers the whole
    combination to ONE Pallas kernel per leaf (``kernels.ops.fused_lincomb``,
    interpret-mode on CPU) with the same accumulation order inside the
    kernel, so results are bitwise-identical under jit.  Callers must
    already have dropped zero-weight pairs (both paths assume it).
    """
    if not fused:
        out = base if base_coeff is None else tree_scale(base_coeff, base)
        for w, tr in pairs:
            out = tree_axpy(w if scale is None else scale * w, tr, out)
        return out
    from repro.kernels.ops import fused_lincomb  # deferred: keep core light
    weights = [w for w, _ in pairs]
    terms = [t for _, t in pairs]
    if not terms:
        return base if base_coeff is None else tree_scale(base_coeff, base)

    def leaf(b, *ts):
        if b.size == 0:  # degenerate leaf: nothing to fuse
            out = b if base_coeff is None else base_coeff * b
            for w, t in zip(weights, ts):
                out = out + (w if scale is None else scale * w) * t
            return out
        return fused_lincomb(b, ts, weights, scale, base_coeff)

    return jtu.tree_map(leaf, base, *terms)


def tree_stack(trees) -> PyTree:
    return jtu.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree, n) -> list:
    return [jtu.tree_map(lambda x: x[i], tree) for i in range(n)]


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    leaves = jtu.tree_map(lambda x, y: jnp.sum(x * y), a, b)
    return jtu.tree_reduce(jnp.add, leaves)


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jtu.tree_map(lambda x: x.astype(dtype), a)


# ---------------------------------------------------------------------------
# explicit RK stepping
# ---------------------------------------------------------------------------

def rk_stages(f: VectorField, tab: ButcherTableau, u: PyTree, theta: PyTree,
              t, h, fused: bool = False) -> list:
    """Compute the stage derivatives k_1..k_s (list of pytrees).
    ``fused=True`` builds each stage input with one Pallas lincomb kernel
    per leaf instead of a tree_axpy chain (bitwise-identical under jit)."""
    ks: list = []
    for i in range(tab.num_stages):
        pairs = [(float(tab.a[i, j]), ks[j]) for j in range(i)
                 if float(tab.a[i, j]) != 0.0]
        xi = tree_stage_lincomb(u, pairs, scale=h, fused=fused)
        ks.append(f(xi, theta, t + float(tab.c[i]) * h))
    return ks


def rk_combine(tab: ButcherTableau, u: PyTree, ks, h,
               fused: bool = False) -> PyTree:
    """u + h * sum_i b_i k_i."""
    pairs = [(float(tab.b[i]), ks[i]) for i in range(tab.num_stages)
             if float(tab.b[i]) != 0.0]
    return tree_stage_lincomb(u, pairs, scale=h, fused=fused)


def rk_step(f: VectorField, tab: ButcherTableau, u: PyTree, theta: PyTree,
            t, h, fused: bool = False) -> Tuple[PyTree, PyTree]:
    """One explicit RK step.  Returns (u_next, stages) with stages stacked
    along a new leading axis of size N_s (so it scans cleanly)."""
    ks = rk_stages(f, tab, u, theta, t, h, fused=fused)
    u_next = rk_combine(tab, u, ks, h, fused=fused)
    return u_next, tree_stack(ks)


def rk_stage_inputs(tab: ButcherTableau, u: PyTree, stages: PyTree, h,
                    fused: bool = False) -> list:
    """Reconstruct the stage inputs x_i = u + h*sum_j a_ij k_j from stored
    stage derivatives — no f evaluations (the PNODE trick)."""
    ks = tree_unstack(stages, tab.num_stages)
    xs = []
    for i in range(tab.num_stages):
        pairs = [(float(tab.a[i, j]), ks[j]) for j in range(i)
                 if float(tab.a[i, j]) != 0.0]
        xs.append(tree_stage_lincomb(u, pairs, scale=h, fused=fused))
    return xs


def rk_adjoint_step(f: VectorField, tab: ButcherTableau, u: PyTree,
                    stages: PyTree, theta: PyTree, t, h,
                    lam: PyTree, fused: bool = False) -> Tuple[PyTree, PyTree]:
    """Discrete adjoint of one explicit RK step (the paper's eq. 7).

    Given the step's initial state ``u``, its stored stage derivatives, and
    the incoming adjoint ``lam`` (= lambda_{n+1}), returns

        lam_prev  = (d u_{n+1} / d u_n)^T lam
        theta_bar = (d u_{n+1} / d theta)^T lam     (increment for mu)

    Implementation: reverse stage recursion
        v_i     = b_i * lam + sum_{j>i} a_ji * w_j
        (w_i, g_i) = vjp(f, x_i)(h * v_i)        # one transposed JVP per stage
        lam_prev = lam + sum_i w_i
        theta_bar = sum_i g_i
    """
    s = tab.num_stages
    xs = rk_stage_inputs(tab, u, stages, h, fused=fused)
    ws: list = [None] * s
    lam_prev = lam
    theta_bar = None
    for i in reversed(range(s)):
        if float(tab.b[i]) == 0.0 and all(
            float(tab.a[j, i]) == 0.0 for j in range(i + 1, s)
        ):
            ws[i] = None
            continue
        pairs = [(float(tab.a[j, i]), ws[j]) for j in range(i + 1, s)
                 if float(tab.a[j, i]) != 0.0 and ws[j] is not None]
        vi = tree_stage_lincomb(lam, pairs, base_coeff=float(tab.b[i]),
                                fused=fused)
        ti = t + float(tab.c[i]) * h
        _, vjp_fn = jax.vjp(lambda uu, th: f(uu, th, ti), xs[i], theta)
        wi, gi = vjp_fn(tree_scale(h, vi))
        ws[i] = wi
        lam_prev = tree_add(lam_prev, wi)
        theta_bar = gi if theta_bar is None else tree_add(theta_bar, gi)
    if theta_bar is None:
        theta_bar = tree_zeros_like(theta)
    return lam_prev, theta_bar


# ---------------------------------------------------------------------------
# fixed-step forward solves
# ---------------------------------------------------------------------------

def solve_fixed(f: VectorField, method: str, u0: PyTree, theta: PyTree,
                t0: float, h: float, n_steps: int,
                save_states: bool = False,
                save_stages: bool = False,
                fused: bool = False):
    """Integrate n_steps of size h with a fixed-step explicit RK method.

    Returns (u_final, saved) where ``saved`` is a dict possibly containing
    'states' (the N_t *pre-step* states u_0..u_{N_t-1}) and 'stages'
    (N_t stacked stage pytrees).
    """
    tab = get_tableau(method)

    def body(carry, n):
        u = carry
        t = t0 + n.astype(jnp.result_type(float)) * h
        u_next, stages = rk_step(f, tab, u, theta, t, h, fused=fused)
        out = {}
        if save_states:
            out["states"] = u
        if save_stages:
            out["stages"] = stages
        return u_next, out

    u_final, saved = jax.lax.scan(body, u0, jnp.arange(n_steps))
    return u_final, saved


def solve_fixed_trajectory(f: VectorField, method: str, u0: PyTree,
                           theta: PyTree, t0: float, h: float, n_steps: int):
    """Like solve_fixed but returns the full trajectory u_1..u_{N_t}
    (stacked along a new leading axis), for plotting / loss-over-trajectory."""
    tab = get_tableau(method)

    def body(carry, n):
        u = carry
        t = t0 + n.astype(jnp.result_type(float)) * h
        u_next, _ = rk_step(f, tab, u, theta, t, h)
        return u_next, u_next

    u_final, traj = jax.lax.scan(body, u0, jnp.arange(n_steps))
    return u_final, traj
