"""Continuous normalizing flows (FFJORD) on top of the PNODE adjoint core.

The CNF ODE evolves (x, log p) jointly:

    d x / dt       = f(x, theta, t)
    d logdet / dt  = -tr( df/dx )

Trace estimation: exact (d jvps, for small d — the paper's tabular datasets
are 6/43/63-dim) or Hutchinson (one vjp with a fixed Rademacher probe).
The augmented system is just another vector field, so every adjoint policy
(pnode/pnode2/revolve/aca/anode/naive/continuous) applies unchanged — this is
what the paper's Tables 3-7 measure.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.adjoint import odeint
from repro.core.integrators import PyTree, VectorField


def exact_trace_vf(f: VectorField, dim: int) -> VectorField:
    """Augmented vector field with exact trace (dim jvp probes)."""

    def aug(state, theta, t):
        x, _logdet = state
        fx = f(x, theta, t)

        def jac_diag_i(i):
            e = jnp.zeros((dim,)).at[i].set(1.0)
            e = jnp.broadcast_to(e, x.shape)
            _, jv = jax.jvp(lambda xx: f(xx, theta, t), (x,), (e,))
            return jv[..., i]

        diag = jnp.stack([jac_diag_i(i) for i in range(dim)], axis=-1)
        trace = jnp.sum(diag, axis=-1)
        return (fx, -trace)

    return aug


def hutchinson_trace_vf(f: VectorField, probe: jax.Array) -> VectorField:
    """Augmented vector field with a Hutchinson trace estimate.

    ``probe`` is a fixed Rademacher tensor shaped like x (drawn once per
    training iteration, as in FFJORD)."""

    def aug(state, theta, t):
        x, _logdet = state
        fx, vjp_fn = jax.vjp(lambda xx: f(xx, theta, t), x)
        (vjp_probe,) = vjp_fn(probe)
        trace_est = jnp.sum(vjp_probe * probe, axis=-1)
        return (fx, -trace_est)

    return aug


def cnf_log_prob(f: VectorField, x: jax.Array, theta: PyTree, *,
                 dt: float, n_steps: int, method: str = "dopri5",
                 adjoint: str = "pnode", ncheck: int | None = None,
                 trace: str = "exact", probe: jax.Array | None = None,
                 t0: float = 0.0) -> jax.Array:
    """log p(x) under the CNF that flows data -> base N(0, I) over [t0, t1].

    Integrates the augmented ODE forward from the data points; returns the
    per-sample log-probability (batch,) — the training loss is its negative
    mean (Tables 3-7 of the paper).
    """
    dim = x.shape[-1]
    if trace == "exact":
        aug = exact_trace_vf(f, dim)
    elif trace == "hutchinson":
        if probe is None:
            raise ValueError("hutchinson trace needs a probe")
        aug = hutchinson_trace_vf(f, probe)
    else:
        raise ValueError(trace)

    logdet0 = jnp.zeros(x.shape[:-1], x.dtype)
    z, dlogdet = odeint(aug, (x, logdet0), theta, dt=dt, n_steps=n_steps,
                        t0=t0, method=method, adjoint=adjoint, ncheck=ncheck)
    base_logp = -0.5 * jnp.sum(z ** 2, axis=-1) - 0.5 * dim * jnp.log(2 * jnp.pi)
    # log p(x) = log p_base(z) + integral of -tr(J) accumulated in dlogdet
    return base_logp + dlogdet


def cnf_sample(f: VectorField, z: jax.Array, theta: PyTree, *, dt: float,
               n_steps: int, method: str = "dopri5", t0: float = 0.0):
    """Sample by integrating base noise backward through the flow."""
    t1 = t0 + dt * n_steps

    def neg_f(x, th, t):
        return -f(x, th, t1 + t0 - t)

    logdet0 = jnp.zeros(z.shape[:-1], z.dtype)
    aug = exact_trace_vf(neg_f, z.shape[-1])
    x, _ = odeint(aug, (z, logdet0), theta, dt=dt, n_steps=n_steps, t0=t0,
                  method=method, adjoint="naive")
    return x
