"""Adaptive-step Dopri5 with discrete adjoint over *accepted* steps.

The paper (§4) notes that rejected steps have no influence on the cost or
memory of PNODE's reverse pass because the adjoint involves only accepted
steps.  We reproduce that here: the forward pass is a bounded
``lax.while_loop`` with a PI step-size controller; accepted steps write
(state, stages, h, t) into a preallocated ring buffer of ``max_steps``; the
reverse pass scans the buffer backward applying the per-stage discrete
adjoint with each step's own h.

The reverse sweep's cost scales with *accepted* steps, not ``max_steps``:
each slot's adjoint step sits inside a ``lax.cond`` on ``idx < n_accepted``,
so slots in the invalid tail of the ring buffer execute the identity branch
— zero f evaluations — instead of computing a masked-out adjoint step as
the pre-fusion implementation did.  Measured NFE-B is therefore
``adjoint_stages('dopri5') * n_accepted`` regardless of ``max_steps``
(BENCH_3's hot-path section asserts this).

Returns (u_final, info) where info carries NFE counters (accepted/rejected) —
these feed the Table-8 benchmark.

mem — the ring buffer allocates max_steps*(N_s+1) state vectors up front
(Table-2 pnode storage at the worst-case step count).  ``offload="spill"``
(or ``"disk"`` — same callbacks, file-backed payloads) writes accepted
steps through a ``repro.mem.offload`` store instead: the device carries
one token scalar plus a SEGMENT-SIZED staging ring, the host side holds
the checkpoints, and the reverse sweep prefetches them back one
``offload_segment``-sized chunk per host callback (``store.prefetch``;
segments whose first slot is past ``n_accepted`` are cond-skipped, so
host round-trips are O(n_accepted / segment), not O(max_steps)).

The FORWARD sweep is segment-batched too: accepted steps land in a
device-side ring of ``offload_segment`` slots (rejected attempts
where-mask to a no-op), and the ring is flushed with ONE ``write_batch``
callback each time the accepted count crosses a segment boundary, plus
one trailing flush for the partial last segment — ceil(n_accepted/seg)
write callbacks total instead of one per *attempted* step (the last O(N)
callback path; tests/test_hotpath.py asserts the ceil bound).  The
trailing flush ships the full ring, so slots in [n_accepted,
ceil(n_accepted/seg)*seg) hold stale ring entries — the reverse sweep
cond-skips everything past ``n_accepted``, so they are never read.  The
reverse sweep software-pipelines its reads (``prefetch_issue`` of
segment k-1 right after segment k's data lands — see
``repro.mem.offload``), overlapping host/disk I/O with adjoint compute.
Device-live memory is O(segment) states for any max_steps, with
identical gradients (rejected steps never reach the store, mirroring the
paper's observation that they cost the adjoint nothing).

``fused_stages=True`` lowers the RK stage updates (forward) and per-stage
adjoint recursion (reverse) through the Pallas ``fused_lincomb`` kernel
(interpret-mode on CPU) — same flag and caveats as ``odeint``.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.core.integrators import (
    PyTree,
    VectorField,
    rk_adjoint_step,
    rk_combine,
    rk_stages,
    tree_add,
    tree_stack,
    tree_zeros_like,
)
from repro.core.tableaus import DOPRI5, get_tableau
from repro.obs.profile import scope


class AdaptiveInfo(NamedTuple):
    n_accepted: jax.Array
    n_rejected: jax.Array
    nfe_forward: jax.Array


def _error_norm(u, u_new, err, rtol, atol):
    def leaf(e, a, b):
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        return jnp.sum((e / scale) ** 2), e.size

    parts = [leaf(e, a, b) for e, a, b in zip(
        jtu.tree_leaves(err), jtu.tree_leaves(u), jtu.tree_leaves(u_new))]
    total = sum(p[0] for p in parts)
    count = sum(p[1] for p in parts)
    return jnp.sqrt(total / count)


def odeint_adaptive(f: VectorField, u0: PyTree, theta: PyTree, *,
                    t0: float, t1: float, rtol: float = 1e-6,
                    atol: float = 1e-6, max_steps: int = 512,
                    h0: float | None = None, method: str = "dopri5",
                    offload: str | None = None,
                    offload_segment: int | None = None,
                    snaps_in_ram: int | None = None,
                    offload_dir: str | None = None,
                    fused_stages: bool = False,
                    obs=None, fault_plan=None):
    """Adaptive solve from t0 to t1; differentiable (discrete adjoint over
    accepted steps).  Returns (u_final, AdaptiveInfo).  ``offload="spill"``
    (or ``"disk"`` for file-backed payloads) replaces the preallocated
    ring buffer with a host-side checkpoint store: accepted steps batch
    through a segment-sized staging ring flushed once per
    ``offload_segment`` accepted steps (default ceil(sqrt(max_steps))),
    and the reverse sweep prefetches them back one segment per host
    callback; ``snaps_in_ram`` caps the spill tier's RAM-resident slots
    (overflow sinks to disk files) and ``offload_dir`` pins the disk
    files to a caller-owned directory.  ``fused_stages`` selects the
    Pallas stage-fusion kernels (see module docstring).

    ``obs=`` attaches a ``repro.obs.FlightRecorder``: every *attempted*
    step emits a runtime ``adaptive.step`` event (t, h, error norm,
    accept, and the attempt counter — ``FlightRecorder.adaptive_steps()``
    reconstructs the exact accepted/rejected sequence from them), and the
    spill store's callbacks record per-segment ``spill.*`` traffic.  The
    taps are ``jax.debug.callback`` effects: no op feeds the computation,
    so gradients are bitwise-identical to ``obs=None`` (which traces no
    tap at all — zero overhead when off).

    ``fault_plan=`` (a ``repro.ft.FaultPlan``) injects NaN-poisoned f
    evaluations at chosen *attempt* indices (site ``"adaptive"``, kind
    ``"nan"``).  The controller is written to survive them without help: a
    NaN error norm rejects the attempt (``NaN <= 1.0`` is False), the
    non-finite PI factor falls back to the minimum shrink (0.2) instead of
    poisoning every later step size, and a total-attempt cap bounds the
    reject loop — so once the fault window passes, integration resumes at
    a smaller h (recovery here is convergent, not bitwise: the step-size
    trajectory legitimately differs from the fault-free run).  The
    ``adaptive.step`` obs stream records each poisoned attempt
    (``err_norm`` NaN, ``accept`` False)."""
    if method != "dopri5":
        raise ValueError("adaptive integration currently supports dopri5")
    if offload not in (None, "device", "spill", "disk"):
        raise ValueError(
            f"unknown offload tier {offload!r} for the adaptive ring "
            "buffer; one of (None, 'device', 'spill', 'disk')")
    if offload_segment is not None and offload not in ("spill", "disk"):
        raise ValueError(
            "offload_segment only applies to the callback spill/disk "
            f"tiers; got offload={offload!r}")
    if snaps_in_ram is not None and offload != "spill":
        raise ValueError(
            "snaps_in_ram is the spill tier's RAM/disk split "
            f"(offload='spill'); got offload={offload!r}")
    if offload_dir is not None and offload not in ("spill", "disk"):
        raise ValueError(
            "offload_dir pins the disk tier's segment files "
            f"(offload='spill'/'disk'); got offload={offload!r}")
    if offload in ("spill", "disk") and fault_plan is not None:
        # tier outage: the scanned ring buffer walks spill -> disk ->
        # device (the slot-addressed host tier is not scanned-capable)
        from repro.mem.offload import effective_tier
        eff = effective_tier(offload, fault_plan, scanned=True, obs=obs)
        offload = None if eff in (None, "device") else eff
    store = None
    segment = 1
    if offload in ("spill", "disk"):
        from repro.core.adjoint import _reject_vmap_offload
        from repro.mem.offload import default_segment, make_store
        _reject_vmap_offload(u0, theta, "odeint_adaptive")
        store = make_store(offload, fault_plan=fault_plan,
                           snaps_in_ram=snaps_in_ram, disk_dir=offload_dir)
        segment = (int(offload_segment) if offload_segment is not None
                   else default_segment(int(max_steps)))
        segment = max(1, min(segment, int(max_steps)))
    h_init = float(h0) if h0 is not None else (float(t1) - float(t0)) / 100.0
    if obs is not None:
        if store is not None:
            store.bind_obs(obs)
        obs.record("adaptive.solve", method=method, t0=float(t0),
                   t1=float(t1), rtol=float(rtol), atol=float(atol),
                   max_steps=int(max_steps), h0=h_init,
                   offload=offload, segment=segment,
                   fused=bool(fused_stages))
    u_final, info = _odeint_adaptive(f, float(t0), float(t1), float(rtol),
                                     float(atol), int(max_steps),
                                     float(h_init), store, segment,
                                     bool(fused_stages), obs, fault_plan,
                                     u0, theta)
    return u_final, info


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11))
def _odeint_adaptive(f, t0, t1, rtol, atol, max_steps, h0, store, segment,
                     fused, obs, fault, u0, theta):
    out, _res = _adaptive_fwd_solve(f, t0, t1, rtol, atol, max_steps, h0,
                                    store, segment, fused, u0, theta,
                                    obs=obs, fault=fault)
    return out


def _adaptive_fwd_solve(f, t0, t1, rtol, atol, max_steps, h0, store, segment,
                        fused, u0, theta, obs=None, fault=None):
    tab = DOPRI5
    s = tab.num_stages
    order = tab.order
    spill = store is not None
    seg = max(1, min(int(segment), int(max_steps)))

    def buf_like(x):
        return jnp.zeros((max_steps,) + x.shape, x.dtype)

    def ring_like(x):
        return jnp.zeros((seg,) + x.shape, x.dtype)

    stage0 = tree_stack([u0] * s)  # shape template for stages
    if spill:
        # the carry holds the store token plus a segment-sized staging
        # ring: accepted steps land at ring position n_acc % seg and ONE
        # write_batch callback flushes the full ring each time the
        # accepted count crosses a segment boundary — O(n_acc/seg)
        # callbacks instead of one write_at per attempted step
        fdt = jnp.result_type(float)
        ring0 = (jtu.tree_map(ring_like, u0),
                 jtu.tree_map(ring_like, jtu.tree_map(jnp.zeros_like,
                                                      stage0)),
                 jnp.zeros((seg,), fdt), jnp.zeros((seg,), fdt))
        bufs0 = (store.init_token(), ring0)
    else:
        state_buf = jtu.tree_map(buf_like, u0)
        stage_buf = jtu.tree_map(buf_like,
                                 jtu.tree_map(jnp.zeros_like, stage0))
        h_buf = jnp.zeros((max_steps,), jnp.result_type(float))
        t_buf = jnp.zeros((max_steps,), jnp.result_type(float))
        bufs0 = (state_buf, stage_buf, h_buf, t_buf)

    def cond(carry):
        u, t, h, n_acc, n_rej, bufs, err_prev = carry
        # the total-attempt cap bounds the reject loop: a persistently
        # rejecting step (e.g. poisoned f-evals) can no longer hang the
        # while_loop — it exits with t short of t1, which the caller sees
        # in the counters.  Never binds on a healthy solve (rejections
        # would have to outnumber accepts 7:1 at the accept cap).
        return jnp.logical_and(
            jnp.logical_and(t < t1 - 1e-14, n_acc < max_steps),
            n_acc + n_rej < 8 * max_steps)

    def body(carry):
        u, t, h, n_acc, n_rej, bufs, err_prev = carry
        h = jnp.minimum(h, t1 - t)
        f_step = f
        if fault is not None:
            bad = fault.traced_gate("adaptive", "nan", n_acc + n_rej)
            if bad is not False:
                def f_step(uu, th, tt):
                    out = f(uu, th, tt)
                    return jtu.tree_map(
                        lambda x: jnp.where(bad, jnp.full_like(x, jnp.nan),
                                            x), out)
        ks = rk_stages(f_step, tab, u, theta, t, h, fused=fused)
        u_new = rk_combine(tab, u, ks, h, fused=fused)
        # embedded error estimate
        err = None
        for i in range(s):
            ci = float(tab.b[i] - tab.b_err[i])
            if ci == 0.0:
                continue
            term = jtu.tree_map(lambda k: h * ci * k, ks[i])
            err = term if err is None else tree_add(err, term)
        enorm = _error_norm(u, u_new, err, rtol, atol)
        accept = enorm <= 1.0
        if obs is not None:
            # debug-effect tap only — nothing feeds the computation, so
            # the solve (and its gradients) is bitwise-unchanged; the
            # attempt counter makes the event stream order-reconstructible
            obs.emit("adaptive.step", t=t, h=h, err_norm=enorm,
                     accept=accept, attempt=n_acc + n_rej)

        # PI controller (Hairer-Norsett-Wanner II.4): alpha=0.7/p, beta=0.4/p
        alpha, beta = 0.7 / order, 0.4 / order
        factor = 0.9 * (enorm + 1e-10) ** (-alpha) * (err_prev + 1e-10) ** (beta)
        # a NaN/Inf error norm (poisoned f-evals) must not poison the step
        # size forever: fall back to the maximum shrink so the retry probes
        # a smaller h.  Bitwise-neutral when factor is finite.
        factor = jnp.where(jnp.isfinite(factor), factor,
                           jnp.asarray(0.2, factor.dtype))
        factor = jnp.clip(factor, 0.2, 5.0)
        h_next = h * jnp.where(accept, factor, jnp.minimum(factor, 1.0))

        idx = n_acc
        if spill:
            tok, ring = bufs
            pos = jnp.remainder(idx, seg)
            ring2 = jtu.tree_map(
                lambda b, x: b.at[pos].set(jnp.where(accept, x, b[pos])),
                ring, (u, tree_stack(ks), h, t))
            # flush the staging ring once the accepted index fills it:
            # one segment-batched callback per seg ACCEPTED steps;
            # rejected attempts never reach the host
            do_flush = jnp.logical_and(accept, pos == seg - 1)
            tok2 = jax.lax.cond(
                do_flush,
                lambda t_: store.write_batch(t_, idx + 1 - seg, ring2),
                lambda t_: t_, tok)
            bufs2 = (tok2, ring2)
        else:
            sb, kb, hb, tb = bufs
            sb2 = jtu.tree_map(lambda b, x: b.at[idx].set(
                jnp.where(accept, x, b[idx])), sb, u)
            kb2 = jtu.tree_map(lambda b, x: b.at[idx].set(
                jnp.where(accept, x, b[idx])), kb, tree_stack(ks))
            hb2 = hb.at[idx].set(jnp.where(accept, h, hb[idx]))
            tb2 = tb.at[idx].set(jnp.where(accept, t, tb[idx]))
            bufs2 = (sb2, kb2, hb2, tb2)

        u_out = jtu.tree_map(lambda a, b: jnp.where(accept, b, a), u, u_new)
        t_out = jnp.where(accept, t + h, t)
        return (u_out, t_out, h_next,
                n_acc + accept.astype(jnp.int32),
                n_rej + (1 - accept.astype(jnp.int32)),
                bufs2,
                jnp.where(accept, enorm, err_prev))

    carry0 = (u0, jnp.asarray(t0, jnp.result_type(float)),
              jnp.asarray(h0, jnp.result_type(float)),
              jnp.array(0, jnp.int32), jnp.array(0, jnp.int32),
              bufs0,
              jnp.asarray(1.0, jnp.result_type(float)))
    u_f, t_f, h_f, n_acc, n_rej, bufs, _ = jax.lax.while_loop(cond, body, carry0)
    nfe = (n_acc + n_rej) * s
    info = AdaptiveInfo(n_accepted=n_acc, n_rejected=n_rej, nfe_forward=nfe)
    if spill:
        # trailing flush: ship the partially-filled ring (positions >=
        # n_acc % seg are stale entries landing at slots >= n_acc, which
        # the reverse sweep cond-skips — they are never read)
        tok, ring = bufs
        rem_n = jnp.remainder(n_acc, seg)
        tok = jax.lax.cond(
            rem_n > 0,
            lambda t_: store.write_batch(t_, n_acc - rem_n, ring),
            lambda t_: t_, tok)
        bufs = tok  # the ring is dead past this point; residual = token
    return (u_f, info), (bufs, n_acc, theta)


@scope("adaptive/fwd")
def _odeint_adaptive_fwd(f, t0, t1, rtol, atol, max_steps, h0, store,
                         segment, fused, obs, fault, u0, theta):
    out, res = _adaptive_fwd_solve(f, t0, t1, rtol, atol, max_steps, h0,
                                   store, segment, fused, u0, theta,
                                   obs=obs, fault=fault)
    return out, res


@scope("adaptive/bwd")
def _odeint_adaptive_bwd(f, t0, t1, rtol, atol, max_steps, h0, store,
                         segment, fused, obs, fault, res, g):
    tab = DOPRI5
    if obs is not None:
        obs.record("adaptive.adjoint", max_steps=max_steps,
                   segment=segment,
                   tier=store.tier if store is not None else "device")
    bufs, n_acc, theta = res
    g_u, _g_info = g  # ignore cotangents of the counters
    spill = store is not None

    def adjoint_one(lam, mu, u_n, k_n, h_n, t_n):
        lam2, th_bar = rk_adjoint_step(f, tab, u_n, k_n, theta, t_n, h_n,
                                       lam, fused=fused)
        return lam2, tree_add(mu, th_bar)

    if not spill:
        sb, kb, hb, tb = bufs

        def body(carry, idx):
            # cond (not where-masking): the invalid tail of the ring buffer
            # takes the identity branch, so reverse-sweep f evaluations
            # scale with n_accepted, not max_steps
            def do(c):
                lam, mu = c
                u_n = jtu.tree_map(lambda b: b[idx], sb)
                k_n = jtu.tree_map(lambda b: b[idx], kb)
                return adjoint_one(lam, mu, u_n, k_n, hb[idx], tb[idx])

            return jax.lax.cond(idx < n_acc, do, lambda c: c, carry), None

        (lam, mu), _ = jax.lax.scan(
            body, (g_u, tree_zeros_like(theta)),
            jnp.arange(max_steps), reverse=True)
        return lam, mu

    # spill tier: segment-prefetched reverse sweep — one host callback per
    # offload_segment slots, and segments entirely past n_accepted are
    # cond-skipped (no callback, no f evaluations)
    seg = max(1, min(segment, max_steps))
    n_full, remainder = divmod(max_steps, seg)
    tok = bufs

    def run_segment_bwd(carry, base, m):
        def proc(args):
            lam, mu, tok = args
            tok2, staged = store.prefetch(tok, base, m)  # ONE callback
            # software pipelining: queue the background gather of the next
            # (earlier) segment while this one's adjoint computes; base <
            # n_acc here, so nb < n_acc holds whenever nb >= 0 and the
            # issued segment is never a skipped one
            nb = base - seg
            tok2 = jax.lax.cond(
                nb >= 0,
                lambda t_: store.prefetch_issue(t_, jnp.maximum(nb, 0),
                                                seg),
                lambda t_: t_, tok2)

            def step(c, i):
                idx = base + i

                def do(c2):
                    lam, mu = c2
                    u_n, k_n, h_n, t_n = jtu.tree_map(lambda b: b[i], staged)
                    return adjoint_one(lam, mu, u_n, k_n, h_n, t_n)

                return jax.lax.cond(idx < n_acc, do, lambda c2: c2, c), None

            (lam, mu), _ = jax.lax.scan(step, (lam, mu), jnp.arange(m),
                                        reverse=True)
            return lam, mu, tok2

        return jax.lax.cond(base < n_acc, proc, lambda a: a, carry)

    carry = (g_u, tree_zeros_like(theta), tok)
    if remainder:  # trailing partial segment holds the highest slots
        carry = run_segment_bwd(carry, jnp.asarray(n_full * seg), remainder)
    if n_full:
        def seg_body(c, s_idx):
            return run_segment_bwd(c, s_idx * seg, seg), None

        carry, _ = jax.lax.scan(seg_body, carry, jnp.arange(n_full),
                                reverse=True)
    lam, mu, _tok = carry
    return lam, mu


_odeint_adaptive.defvjp(_odeint_adaptive_fwd, _odeint_adaptive_bwd)
