"""Deterministic, shardable synthetic token pipeline.

Every batch is a pure function of (seed, step), so a restarted job replays
the exact same stream (fault-tolerance requirement: restore checkpoint at
step k -> batches k+1... are identical).  Tokens follow a Zipf-ish rank
distribution so losses behave like text rather than uniform noise.

For multi-host training each host generates the full global batch lazily and
jit+GSPMD keeps only the local shard materialized (the generator runs inside
jit, so there is no host-side data movement at all).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    cfg: ModelConfig
    cell: ShapeCell
    seed: int = 0

    def batch(self, step) -> Dict[str, jax.Array]:
        """Batch for a given step (traced or concrete)."""
        cfg, cell = self.cfg, self.cell
        b, s = cell.global_batch, cell.seq_len
        n_text = s - (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        # Zipf-ish: exponentiate a uniform to concentrate mass on low ids
        u = jax.random.uniform(key, (b, n_text), jnp.float32, 1e-6, 1.0)
        ranks = jnp.floor((u ** 3.0) * cfg.vocab_size).astype(jnp.int32)
        tokens = jnp.clip(ranks, 0, cfg.vocab_size - 1)
        out = {"tokens": tokens, "targets": tokens}
        if cfg.frontend == "vision_stub":
            kp = jax.random.fold_in(key, 1)
            out["patches"] = 0.02 * jax.random.normal(
                kp, (b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            kf = jax.random.fold_in(key, 2)
            out["frames"] = 0.02 * jax.random.normal(
                kf, (b, cfg.enc_seq, cfg.d_model), jnp.float32)
        return out
