"""Byte-budget planner: solve for the Table-2 point instead of hand-picking.

Given a device-memory budget B, rank every reverse-accurate policy instance
by its extra reverse-pass f evaluations (the paper's NFE-B) and choose the
cheapest one whose peak bytes fit:

  naive(0 extra)  >  pnode  >  revolve(N_c as large as fits)  >  pnode2
  >  aca  >  [nothing fits on device]  pnode + spill offload

For revolve the planner picks the *largest* N_c whose checkpoint set
(N_c+1)(N_s+1)S fits — by Prop. 2 that minimizes recomputation, so a larger
budget can never cost more f evaluations (monotonicity; tested).  The spill
tier is a last resort: it keeps NFE-B at pnode's optimum but pays PCIe/host
traffic the NFE metric does not see, so it never outranks an in-device
policy that fits.  When the plan DOES offload, separate ``ram_budget`` /
``disk_budget`` knobs bound the off-device media: the planner solves the
dolfin-adjoint ``snaps_in_ram`` split (slots over the RAM cap sink to disk
segment files; ``offload="disk"`` when no slot fits RAM), priced by the
model's per-tier ``ram_bytes``/``disk_bytes``/``io_seconds`` columns.

Two verify modes:

  "model"    trust the analytic model (no compilation; use for planning
             sweeps and tests that must stay cheap);
  "measure"  walk the candidate list compiling each candidate's reverse
             pass and checking the *measured* peak bytes
             (``hlo_cost.peak_live_bytes``) against the budget — the mode
             ``odeint(adjoint="auto", mem_budget=...)`` uses by default, so
             the policy it returns provably fits on the lowered HLO (the
             acceptance criterion).  Measurements are cached per
             (f, shapes, config), so a training loop pays the compile walk
             once.

``plan_depth_remat`` applies the same budget logic to the depth dimension
(the LM layer stack's remat policy) for launch/train.py's --mem-budget.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

from jax import tree_util as jtu

from repro.core.implicit import is_implicit_method
from repro.core.tableaus import get_tableau
from repro.mem.model import (CostEstimate, f_activation_bytes,
                             max_fitting_ncheck, measure_reverse_cost,
                             policy_cost, slot_bytes, tree_bytes)

PyTree = Any


@dataclass(frozen=True)
class CandidateDecision:
    """One row of the ``explain=True`` planner report: a candidate the
    budget walk considered, whether it won, and — for every non-chosen
    candidate — exactly why it was rejected or skipped."""
    policy: str
    ncheck: Optional[int]
    offload: Optional[str]
    predicted_peak_bytes: int
    extra_fevals: int
    chosen: bool
    reason: str
    measured_bytes: Optional[float] = None
    snaps_in_ram: Optional[int] = None
    snaps_on_disk: Optional[int] = None

    def to_json(self) -> dict:
        return {"policy": self.policy, "ncheck": self.ncheck,
                "offload": self.offload,
                "predicted_peak_bytes": self.predicted_peak_bytes,
                "extra_fevals": self.extra_fevals, "chosen": self.chosen,
                "reason": self.reason,
                "measured_bytes": self.measured_bytes,
                "snaps_in_ram": self.snaps_in_ram,
                "snaps_on_disk": self.snaps_on_disk}


@dataclass(frozen=True)
class Plan:
    policy: str
    ncheck: Optional[int]
    offload: Optional[str]
    predicted: CostEstimate
    budget: Optional[int]
    fits: bool                      # predicted/measured peak <= budget
    measured_bytes: Optional[float] = None   # set in verify="measure"
    candidates: Tuple[CostEstimate, ...] = field(default=())
    #: populated by ``plan_odeint(..., explain=True)``: one decision per
    #: in-device candidate (same order as ``candidates``), plus the spill
    #: fallback row when the walk fell through to it
    report: Tuple[CandidateDecision, ...] = field(default=())
    #: the solved RAM/disk slot split when the plan offloads under a
    #: ram_budget: snaps_in_ram slots stay host-RAM-resident, the
    #: remaining snaps_on_disk sink to segment files (None when the split
    #: does not apply — no offload, or everything fits in RAM)
    snaps_in_ram: Optional[int] = None
    snaps_on_disk: Optional[int] = None

    @property
    def extra_fevals(self) -> int:
        return self.predicted.extra_fevals


def _solver_kw(solver_opts: Optional[dict]) -> dict:
    """The slice of solver_opts the cost model depends on."""
    so = solver_opts or {}
    return dict(newton_iters=int(so.get("newton_iters", 10)),
                gmres_iters=int(so.get("gmres_iters", 20)))


def candidate_costs(*, method: str, n_steps: int, state_bytes: int,
                    theta_bytes: int = 0, f_act_bytes: Optional[int] = None,
                    mem_budget: Optional[int] = None,
                    solver_opts: Optional[dict] = None
                    ) -> List[CostEstimate]:
    """In-device candidates, cheapest recomputation first.  revolve appears
    once, at the largest N_c that fits the budget (or N_c=1 when nothing
    does, as the minimum-memory in-device fallback).

    Implicit methods get the implicit candidate set: pnode (converged
    states only — already the memory floor per step), then the revolve /
    revolve2 checkpoint-spacing points at the largest fitting N_c; the
    AD-through-the-step policies (naive/anode/aca/pnode2) do not exist for
    implicit solves (no reverse rule through Newton/GMRES while_loops)."""
    if is_implicit_method(method):
        kw = dict(method=method, n_steps=n_steps, state_bytes=state_bytes,
                  theta_bytes=theta_bytes, **_solver_kw(solver_opts))
        cands = [policy_cost("pnode", **kw)]
        if n_steps >= 2:
            k = None
            if mem_budget is not None:
                k = max_fitting_ncheck(mem_budget, method=method,
                                       n_steps=n_steps,
                                       state_bytes=state_bytes,
                                       theta_bytes=theta_bytes,
                                       **_solver_kw(solver_opts))
            cands.append(policy_cost("revolve", ncheck=k if k else 1, **kw))
            cands.append(policy_cost("revolve2", ncheck=k if k else 1, **kw))
        cands.sort(key=lambda c: (c.extra_fevals, c.peak_bytes))
        return cands
    kw = dict(method=method, n_steps=n_steps, state_bytes=state_bytes,
              theta_bytes=theta_bytes, f_act_bytes=f_act_bytes)
    cands = [policy_cost("naive", **kw), policy_cost("pnode", **kw)]
    if n_steps >= 2:
        k = None
        if mem_budget is not None:
            k = max_fitting_ncheck(mem_budget, method=method,
                                   n_steps=n_steps, state_bytes=state_bytes,
                                   theta_bytes=theta_bytes)
        cands.append(policy_cost("revolve", ncheck=k if k else 1, **kw))
    cands.append(policy_cost("pnode2", **kw))
    cands.append(policy_cost("aca", **kw))
    cands.sort(key=lambda c: (c.extra_fevals, c.peak_bytes))
    return cands


def _spill_split(method: str, n_steps: int, state_bytes: int,
                 ram_budget: Optional[int], disk_budget: Optional[int]
                 ) -> Tuple[str, Optional[int], Optional[int], bool, str]:
    """Solve the dolfin-adjoint RAM/disk slot split for a pnode spill
    fallback: how many of the n_steps checkpoint slots fit the RAM budget,
    the rest sink to disk.  Returns (offload, snaps_in_ram, snaps_on_disk,
    disk_fits, note) — offload='disk' is the snaps_in_ram=0 corner, a None
    split means everything stays in RAM."""
    if ram_budget is None:
        return "spill", None, None, True, "no ram_budget — all slots in RAM"
    sb = max(1, slot_bytes(method, state_bytes))
    k = int(ram_budget) // sb
    if k >= n_steps:
        return ("spill", None, None, True,
                f"ram_budget fits all {n_steps} slots "
                f"({sb} B/slot) — no disk split needed")
    on_disk = n_steps - k
    disk_fits = disk_budget is None or on_disk * sb <= int(disk_budget)
    note = (f"ram_budget fits {k}/{n_steps} slots ({sb} B/slot) — "
            f"{on_disk} slots sink to disk"
            + ("" if disk_fits else
               f"; disk_budget exceeded ({on_disk * sb} B needed)"))
    if k == 0:
        return "disk", None, on_disk, disk_fits, note
    return "spill", k, on_disk, disk_fits, note


def plan_odeint(f: Callable, u0: PyTree, theta: PyTree, *, dt: float,
                n_steps: int, t0: float = 0.0, method: str = "rk4",
                mem_budget: Optional[int] = None,
                ram_budget: Optional[int] = None,
                disk_budget: Optional[int] = None,
                verify: str = "measure",
                loss_fn: Optional[Callable] = None,
                solver_opts: Optional[dict] = None,
                batch: int = 1,
                explain: bool = False) -> Plan:
    """Pick (policy, ncheck, offload) for one odeint call under a budget.

    ``explain=True`` additionally fills ``Plan.report`` with one
    ``CandidateDecision`` per candidate — same order as
    ``Plan.candidates`` — stating for the winner why it was chosen and
    for every other candidate why it was rejected (predicted or measured
    peak over budget) or skipped (a cheaper-recompute candidate already
    fit).  The walk itself is identical with or without ``explain``.

    ``loss_fn(u_final) -> scalar``: in ``verify="measure"`` mode the
    measured reverse pass is the gradient of THIS loss (the caller's
    training objective), so the budget check covers the loss's own working
    set too; when omitted the canonical sum-of-squares surrogate is
    measured (the pre-existing behavior).  Ignored in ``verify="model"``.

    ``solver_opts`` (newton_iters/newton_tol/gmres_iters/gmres_tol) applies
    to implicit methods: gmres_iters sets the Krylov-basis working-set
    term of the model and both iteration counts set the recompute price of
    a revolve segment; ``odeint_implicit(adjoint="auto")`` forwards its
    solver configuration here.  The same budget walk and spill fallback
    apply — the candidate set is just the implicit one (see
    ``candidate_costs``).

    ``ram_budget``/``disk_budget`` (bytes) bound the OFF-device media when
    the plan offloads: the planner solves the dolfin-adjoint
    ``snaps_in_ram`` split (``Plan.snaps_in_ram``/``snaps_on_disk``) so at
    most ram_budget bytes of checkpoint slots stay host-RAM-resident and
    the overflow sinks to disk segment files — ``offload="disk"`` when
    the RAM budget fits no slot at all.  With ``ram_budget`` alone (no
    ``mem_budget``) the plan is the long-trajectory shape directly: pnode
    + spill/disk offload under the RAM cap, no device-budget walk.  A
    disk_budget the overflow exceeds marks the plan ``fits=False`` (best
    effort), mirroring the device-budget semantics.

    ``batch`` prices a BATCHED solve (the serving engine's vmapped lane
    dimension): per-step state and f-activation working sets scale by the
    lane count — and so does every spill checkpoint slot, which is what
    sizes the batched offload working set — while ``theta`` is shared
    across lanes and does not.  ``batch > 1`` uses the analytic model for
    the budget walk (``verify="model"`` semantics) since the measured
    reverse pass lowers the unbatched program.
    """
    b = int(batch)
    if b < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if b > 1:
        verify = "model"
    state_bytes_ = tree_bytes(u0) * b
    if mem_budget is None and ram_budget is not None:
        # RAM-bounded offload without a device budget: the ROADMAP
        # long-trajectory shape — keep pnode's zero-recompute optimum,
        # move every checkpoint slot off device, split RAM/disk by budget
        off, in_ram, on_disk, disk_fits, note = _spill_split(
            method, n_steps, state_bytes_, ram_budget, disk_budget)
        est = policy_cost("pnode", method=method, n_steps=n_steps,
                          state_bytes=state_bytes_,
                          theta_bytes=tree_bytes(theta), offload=off,
                          snaps_in_ram=0 if off == "disk" else in_ram,
                          **_solver_kw(solver_opts))
        report = ()
        if explain:
            report = (CandidateDecision(
                "pnode", None, off, int(est.peak_bytes),
                int(est.extra_fevals), True,
                f"chosen: ram_budget without mem_budget — pnode + {off} "
                f"offload; {note}", None, in_ram, on_disk),)
        return Plan("pnode", None, off, est, None, disk_fits,
                    report=report, snaps_in_ram=in_ram,
                    snaps_on_disk=on_disk)
    if mem_budget is None:
        # no constraint: the paper's method — no recompute beyond the
        # per-stage linearizations, bounded graph depth
        est = policy_cost("pnode", method=method, n_steps=n_steps,
                          state_bytes=state_bytes_,
                          theta_bytes=tree_bytes(theta),
                          **_solver_kw(solver_opts))
        report = ()
        if explain:
            report = (CandidateDecision(
                "pnode", None, None, int(est.peak_bytes),
                int(est.extra_fevals), True,
                "chosen: no mem_budget — paper-default pnode (zero "
                "recompute beyond stage linearizations, bounded graph "
                "depth)"),)
        return Plan("pnode", None, None, est, None, True, report=report)
    if verify not in ("model", "measure"):
        raise ValueError(f"verify must be 'model' or 'measure', "
                         f"got {verify!r}")
    state_bytes = tree_bytes(u0) * b
    theta_bytes = tree_bytes(theta)
    fa = f_activation_bytes(f, u0, theta, t0) * b
    cands = candidate_costs(method=method, n_steps=n_steps,
                            state_bytes=state_bytes, theta_bytes=theta_bytes,
                            f_act_bytes=fa, mem_budget=mem_budget,
                            solver_opts=solver_opts)

    def _measure(cand) -> float:
        return measure_reverse_cost(
            f, u0, theta, dt=dt, n_steps=n_steps, t0=t0, method=method,
            policy=cand.policy, ncheck=cand.ncheck, loss_fn=loss_fn,
            solver_opts=solver_opts)["hlo_peak_bytes"]

    # per-candidate outcome bookkeeping for the explain report:
    # index -> (reason, measured_bytes or None)
    status: dict = {}
    chosen_idx: Optional[int] = None
    measured: Optional[float] = None
    for i, cand in enumerate(cands):
        if cand.peak_bytes > mem_budget:
            status[i] = (f"rejected: predicted peak {int(cand.peak_bytes)} B"
                         f" > budget {mem_budget} B", None)
            continue
        if verify == "measure":
            m = _measure(cand)
            if m > mem_budget:
                status[i] = (f"rejected: measured peak {int(m)} B > budget"
                             f" {mem_budget} B", m)
                continue
            measured = m
        chosen_idx = i
        status[i] = ("chosen: cheapest extra-NFE-B candidate whose peak "
                     "fits the budget", measured)
        break

    if chosen_idx is None and verify == "measure":
        # the model ruled candidates out; re-walk against measurement in
        # case the model over-estimated (it is deliberately conservative)
        for i, cand in enumerate(cands):
            m = _measure(cand)
            if m <= mem_budget:
                chosen_idx = i
                measured = m
                status[i] = ("chosen: model over-estimated (predicted "
                             f"{int(cand.peak_bytes)} B) but measured peak "
                             f"{int(m)} B fits the budget", m)
                break
            if cand.peak_bytes > mem_budget:
                status[i] = (f"rejected: predicted {int(cand.peak_bytes)} B"
                             f" and measured {int(m)} B both exceed budget"
                             f" {mem_budget} B", m)
            # else: keep the walk-1 measured-rejection reason

    def _report(spill_dec: Optional[CandidateDecision] = None):
        if not explain:
            return ()
        rows = []
        for i, cand in enumerate(cands):
            reason, m = status.get(
                i, ("skipped: a cheaper-recompute candidate already fit "
                    "(candidates are ranked by extra NFE-B, then peak "
                    "bytes)", None))
            rows.append(CandidateDecision(
                cand.policy, cand.ncheck, None, int(cand.peak_bytes),
                int(cand.extra_fevals), i == chosen_idx, reason, m))
        if spill_dec is not None:
            rows.append(spill_dec)
        return tuple(rows)

    if chosen_idx is not None:
        cand = cands[chosen_idx]
        return Plan(cand.policy, cand.ncheck, None, cand, mem_budget, True,
                    measured, tuple(cands), _report())

    # nothing fits on device: keep pnode's optimal NFE-B and move the
    # checkpoint storage off device through the spill store, split across
    # RAM and disk by the off-device budgets
    off, in_ram, on_disk, disk_fits, note = _spill_split(
        method, n_steps, state_bytes, ram_budget, disk_budget)
    est = policy_cost("pnode", method=method, n_steps=n_steps,
                      state_bytes=state_bytes, theta_bytes=theta_bytes,
                      f_act_bytes=fa, offload=off,
                      snaps_in_ram=0 if off == "disk" else in_ram,
                      **_solver_kw(solver_opts))
    measured = None
    fits = est.peak_bytes <= mem_budget
    if verify == "measure":
        measured = measure_reverse_cost(
            f, u0, theta, dt=dt, n_steps=n_steps, t0=t0, method=method,
            policy="pnode", offload=off, loss_fn=loss_fn,
            solver_opts=solver_opts)["hlo_peak_bytes"]
        fits = measured <= mem_budget
    fits = fits and disk_fits
    spill_dec = None
    if explain:
        spill_dec = CandidateDecision(
            "pnode", None, off, int(est.peak_bytes),
            int(est.extra_fevals), True,
            "chosen: fallback — no in-device candidate fits; spill keeps "
            "NFE-B at pnode's optimum and moves checkpoint storage off "
            f"device ({note})"
            + ("" if fits else
               " (best effort: the working set or the disk overflow "
               "exceeds its budget)"),
            measured, in_ram, on_disk)
    return Plan("pnode", None, off, est, mem_budget, fits, measured,
                tuple(cands), _report(spill_dec), snaps_in_ram=in_ram,
                snaps_on_disk=on_disk)


# ---------------------------------------------------------------------------
# depth-level planning (the LM layer stack)
# ---------------------------------------------------------------------------

def depth_remat_live_bytes(cfg, cell, remat: str, ncheck: Optional[int],
                           act_mult: float = 12.0) -> int:
    """The depth planner's predicted live bytes for a chosen
    (remat, ncheck) point — the number the launcher's metrics sink
    compares against the measured compiled peak (drift check)."""
    bytes_per = 2 if cfg.compute_dtype in ("bfloat16", "float16") else 4
    state = cell.global_batch * cell.seq_len * cfg.d_model * bytes_per
    act = int(act_mult * state)
    n = cfg.n_layers
    if remat == "none":
        return n * act
    if remat == "sqrt":
        seg = max(1, int(math.sqrt(n)))
        return (seg + math.ceil(n / seg)) * act
    if remat == "full":
        return n * state + act
    if remat == "revolve":
        k = ncheck or 1
        return k * state + math.ceil(n / (k + 1)) * act
    raise ValueError(f"unknown depth remat policy {remat!r}")


def plan_depth_remat(cfg, cell, mem_budget: int,
                     act_mult: float = 12.0
                     ) -> Tuple[str, Optional[int], bool]:
    """Map a byte budget to a depth-checkpointing policy for the layer-stack
    scan (core/depth_ode.checkpointed_scan): the ResNet<->ODE duality makes
    the layer stack a forward-Euler solve, so the same Table-2 trade
    applies with S = one residual-stream state and A ~ act_mult*S the
    transformer block's live activations.

    Candidates, cheapest recompute first:
      none     live ~ N_l * A            0 recomputed layers
      sqrt     live ~ 2*sqrt(N_l) * A    ~N_l recomputed layers (1x each)
      full     live ~ N_l*S + A          ~N_l recomputed layers, O(1) acts
      revolve  live ~ N_c*S + seg*A      Prop-2 recompute over layers

    Returns (remat, ncheck, fits); fits=False means even the minimum-live
    revolve point exceeds the budget (the caller should warn — the plan is
    best-effort, not a guarantee).
    """
    bytes_per = 2 if cfg.compute_dtype in ("bfloat16", "float16") else 4
    state = cell.global_batch * cell.seq_len * cfg.d_model * bytes_per
    act = int(act_mult * state)
    n = cfg.n_layers
    seg = max(1, int(math.sqrt(n)))
    options: List[Tuple[str, Optional[int], int]] = [
        ("none", None, n * act),
        ("sqrt", None, (seg + math.ceil(n / seg)) * act),
        ("full", None, n * state + act),
    ]
    for remat, ncheck, live in options:
        if live <= mem_budget:
            return remat, ncheck, True

    def rev_live(k: int) -> int:
        # boundary states + one in-flight segment's activations (the
        # jax.checkpoint segment recomputed under AD in the reverse pass)
        return k * state + math.ceil(n / (k + 1)) * act

    fitting = [k for k in range(1, n) if rev_live(k) <= mem_budget]
    if fitting:
        # most slots that fit => shortest segments => least recompute depth
        return "revolve", max(fitting), True
    best = min(range(1, n), key=rev_live) if n > 1 else 1
    return "revolve", best, False
