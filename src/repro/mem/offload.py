"""Checkpoint stores: where adjoint checkpoints live between fwd and bwd.

The revolve/pnode adjoints in ``core/adjoint.py`` write (state, stages)
checkpoints through one of these stores instead of returning them directly
as ``custom_vjp`` residuals.  Three tiers:

  device   checkpoints stay traced values and travel through the residual
           pytree — exactly the seed behavior (XLA keeps them in device
           memory for the whole fwd->bwd window).
  host     checkpoints are moved to the backend's pinned-host memory space
           with ``jax.device_put(x, TransferToMemoryKind("pinned_host"))``
           at put time and brought back at get time; the residual pytree
           carries host-resident arrays, so device-live memory between the
           sweeps is O(working set).  Sharded arrays keep their layout: a
           memory-kind transfer preserves the NamedSharding, so each device
           spills its own shard.  On backends without a pinned_host space
           (XLA:CPU in this container exposes only unpinned_host) the tier
           degrades to ``device`` and records ``effective_tier`` so callers
           and tests can see the downgrade.
  spill    checkpoints leave the XLA program entirely through a
           token-threaded ``jax.pure_callback`` into a host-side numpy dict.
           The residual is one f32 scalar (the ordering token), so the
           reverse pass's device-live set is O(ncheck) / O(1) regardless of
           ``n_steps``.  Ordering: every write returns a fresh token and
           every read consumes the latest one, so writes are
           data-dependencies of reads and XLA cannot reorder or elide
           them; slot reads return a token too, ordering subsequent
           frees/overwrites after the reads that precede them.
           (``io_callback(ordered=True)`` would be the natural primitive,
           but its effects are silently dropped inside ``custom_vjp`` rules
           on jax 0.4.37 — verified empirically — hence the token chain.)

Two addressing modes, matching the two checkpoint write paths:

  * slot puts/gets (``put``/``get``/``free``) take a *Python int* slot —
    the trace-time-unrolled revolve schedule addresses checkpoints by step
    index known at trace time;
  * indexed writes (``write_at``) take a *traced* index and thread the
    token explicitly — the adaptive ring buffer addresses by a
    loop-carried counter (with a ``keep`` mask for rejected steps); reads
    on the scanned paths go through the segment-batched ``prefetch``.

Segment-batched I/O (``write_batch``/``prefetch``): one callback per
checkpoint *segment* instead of per step.  ``write_batch(token, base, tree)``
stores ``seg`` consecutive slots from leaves stacked on axis 0;
``prefetch(token, base, seg)`` returns slots ``[base, base+seg)`` stacked —
a double-buffer-capable read: because it returns a fresh token and the
buffer it fills is an ordinary traced value, a caller may issue the
prefetch for segment k+1 before consuming segment k's buffer and overlap
host I/O with compute on backends with async callbacks (on XLA:CPU
``pure_callback`` is synchronous, so the batching win here is the callback
*count*, not overlap).  The scanned pnode/adaptive reverse sweeps use
these to cut host round-trips from O(n_steps) to O(n_segments); token
threading is unchanged, so frees still cannot reorder ahead of reads.

Payload cap: XLA:CPU copies callback operands/results on the same intra-op
thread pool the callback itself occupies, and once a single buffer is
large enough for that copy to be parallelized (~100 KiB measured on jax
0.4.37) the nested parallel-for deadlocks the pool — the callback never
returns and the program hangs.  ``write_batch``/``prefetch`` therefore
split any segment whose largest per-leaf payload (batch axes included)
exceeds ``_CB_PAYLOAD_CAP`` into multiple token-chained callbacks of
slot-aligned chunks.  ``spill_stats()`` counts every chunk callback, so
the BENCH gates price the real host round-trips.  A single slot bigger
than the cap cannot be split further (warned; the slot-addressed
``put``/``write_at`` paths have the same exposure).

Counters: every store keeps its own host-side callback counters
(``store.stats``, keyed by an auto-assigned ``store_id``) and mirrors each
increment into a process-wide aggregate — ``spill_stats()`` returns the
aggregate (the historical API the BENCH_3 gates and per-segment
callback-count tests read), ``per_store_spill_stats()`` the per-store
view.  All counter mutation holds one module lock: XLA executes callbacks
on its own thread pool, so a chunked/vmapped program's callbacks can run
concurrently with each other and with a benchmark's
``reset_spill_stats()`` on the main thread — unlocked dict updates would
lose increments or tear the reset.  Counters count actual EXECUTIONS, not
traces.  Attaching a ``repro.obs.FlightRecorder`` via ``bind_obs`` makes
every callback additionally record a ``spill.write``/``spill.read``/
``spill.free`` trace event carrying the store id, slot base, slot count,
and payload bytes — recorded purely host-side inside the callbacks that
already run, so the traced program is unchanged and grads stay bitwise
identical with obs on.

Table-2 mapping (see ``repro.mem.model``): the store only changes WHERE
N_c*(N_s+1) checkpoint vectors live, never how many f-evaluations the
policy performs — spill grads are bitwise-identical to device grads
(tests/test_mem.py).

vmap: the *slot-addressed* mode is not supported under ``vmap`` (the
callback sees one logical index for the whole batch, so per-example
checkpoints would alias — ``core.adjoint._reject_vmap_offload`` catches it
up front).  The *segment-batched* mode IS (``vmap_method="broadcast_all"``):
one callback serves the entire batch, each slot stores the full batch
block with batch axes leading, so element b's checkpoints occupy index b
of the block — the per-batch-element key scheme the vmapped implicit
ensembles rely on (``core.implicit``).  Stores are per-``odeint``-call
objects, so concurrent solves never share keys.

Resilience (PR 8; all dormant-by-default, the plain paths above are
byte-identical when unused):

  * ``integrity=True`` records a crc32 over every slot's CLEAN payload at
    write time; ``prefetch_checked`` re-verifies on read and returns an
    ``ok`` flag alongside the data (False on a missing slot, a checksum
    mismatch, or exhausted read retries), so callers with recompute
    freedom — the scanned implicit adjoint — can ``lax.cond`` into
    re-integrating the segment from its boundary state instead of
    consuming garbage.  Corruption is modeled *at rest*: an injected
    ``spill.write``/``corrupt`` fault flips stored bytes after
    checksumming, which is exactly what the read-side verify catches.
  * reads retry with exponential backoff (host-side ``time.sleep``; never
    in traced code) up to ``max_retries`` times when a ``FaultPlan``
    flakes the attempt — transient faults cost ``retry_cb`` ticks and
    succeed; persistent ones surface as ``ok=False`` (checked) or a
    ``RuntimeError`` (unchecked paths have no recompute fallback).
  * ``effective_tier(tier, fault_plan)`` walks the degradation ladder
    spill -> host -> device past tiers the plan marks down
    (``FaultSpec("tier.spill", 0, "down")``), recording ``store.degrade``
    obs events; scanned sweeps skip the slot-addressed host tier and
    degrade spill straight to device.
"""
from __future__ import annotations

import itertools
import threading
import time
import weakref
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import tree_util as jtu

from repro.obs.profile import host_annotation

PyTree = Any

TIERS = ("device", "host", "spill")

_TOKEN_SDS = jax.ShapeDtypeStruct((), jnp.float32)

#: per-callback payload cap in bytes, applied to each operand/result leaf
#: with mapped batch axes counted.  Above ~100 KiB the XLA:CPU callback
#: buffer copy is parallelized on the pool the callback blocks, and the
#: program deadlocks (see module docstring); 96 KiB keeps headroom.
_CB_PAYLOAD_CAP = 96 * 1024


def batch_scale(tree: PyTree) -> int:
    """Product of mapped-axis sizes riding the leaves of ``tree`` — the
    factor by which vmap multiplies every callback payload.

    Must be called where the mapped axes are still visible as
    ``BatchTracer``s (the ``odeint`` entry point, like
    ``core.adjoint._reject_vmap_offload``): ``custom_vjp`` forwards are
    retraced at *logical* shapes, so by the time ``write_batch`` runs the
    batch axes cannot be recovered from its arguments."""
    try:
        from jax.interpreters.batching import BatchTracer
    except ImportError:  # pragma: no cover - future jax moved it
        return 1

    def scale(x) -> int:
        s, y, depth = 1, x, 0
        while isinstance(y, jax.core.Tracer) and depth < 8:
            if isinstance(y, BatchTracer):
                bd = getattr(y, "batch_dim", None)
                if isinstance(bd, int):
                    s *= int(np.shape(y.val)[bd])
                y = y.val
            else:
                nxt = getattr(y, "primal", None)
                if nxt is None:
                    nxt = getattr(y, "val", None)
                if nxt is None or nxt is y:
                    break
                y = nxt
            depth += 1
        return s

    return max((scale(x) for x in jtu.tree_leaves(tree)), default=1)


def _tree_nbytes(tree: PyTree) -> int:
    """Logical payload bytes of a pytree (works on traced values)."""
    return sum(int(np.prod(jnp.shape(x), dtype=np.int64))
               * np.dtype(jnp.result_type(x)).itemsize
               for x in jtu.tree_leaves(tree))


def _chunk_slots(seg: int, per_slot_bytes: int) -> int:
    """Slots per callback so no payload leaf exceeds ``_CB_PAYLOAD_CAP``."""
    if per_slot_bytes <= 0:
        return seg
    m = int(_CB_PAYLOAD_CAP // per_slot_bytes)
    if m < 1:
        import warnings
        warnings.warn(
            f"spill store: a single checkpoint slot is {per_slot_bytes} "
            f"bytes, above the {_CB_PAYLOAD_CAP}-byte per-callback payload "
            "cap; XLA:CPU may deadlock copying it (see "
            "repro.mem.offload docstring)", stacklevel=3)
        return 1
    return min(m, seg)

#: counter keys every SpillStore tracks (per store and in the aggregate):
#: ``*_cb`` counts host round-trips, ``*_slots`` checkpoint slots moved
#: (slots/cb = achieved batching factor), ``*_bytes`` payload traffic;
#: ``retry_cb`` counts read attempts repeated after an injected flake and
#: ``integrity_fail`` slots that failed their checksum/presence check.
_STAT_KEYS = ("write_cb", "read_cb", "free_cb",
              "write_slots", "read_slots", "write_bytes", "read_bytes",
              "retry_cb", "integrity_fail")

#: guards ALL counter mutation and the reset: callbacks execute on XLA's
#: thread pool, concurrently with each other (chunked/vmapped programs)
#: and with a benchmark's ``reset_spill_stats()`` on the main thread.
_STATS_LOCK = threading.RLock()

#: process-wide aggregate (the historical ``spill_stats()`` view) —
#: updated in lockstep with the owning store's per-store dict, and kept
#: separate so traffic survives the (per-odeint-call) store objects.
_AGG: Dict[str, int] = {k: 0 for k in _STAT_KEYS}

#: live stores by id, weakly: stores are per-odeint-call objects, so dead
#: ones drop out of ``per_store_spill_stats()`` while their traffic stays
#: in the aggregate.
_STORES: "weakref.WeakValueDictionary[str, SpillStore]" = \
    weakref.WeakValueDictionary()
_STORE_IDS = itertools.count()


def reset_spill_stats() -> None:
    """Zero the aggregate and every live store's counters atomically (a
    callback running mid-reset sees either all-old or all-new)."""
    with _STATS_LOCK:
        for k in _STAT_KEYS:
            _AGG[k] = 0
        for st in list(_STORES.values()):
            for k in _STAT_KEYS:
                st.stats[k] = 0


def spill_stats() -> Dict[str, int]:
    """Copy of the AGGREGATE spill-store callback counters (every store's
    traffic summed; see ``per_store_spill_stats`` for the breakdown):
    ``*_cb`` counts host round-trips, ``*_slots`` counts checkpoint slots
    moved (so slots/cb is the achieved batching factor), ``*_bytes`` the
    payload traffic."""
    with _STATS_LOCK:
        return dict(_AGG)


def per_store_spill_stats() -> Dict[str, Dict[str, int]]:
    """Counters keyed by ``store_id`` for every live ``SpillStore`` that
    has executed at least one callback since its creation or the last
    reset (all-zero stores are omitted to keep the view readable)."""
    with _STATS_LOCK:
        return {sid: dict(st.stats) for sid, st in sorted(_STORES.items())
                if any(st.stats.values())}


def default_segment(n_steps: int) -> int:
    """Default checkpoint-segment length: ceil(sqrt(n_steps)), the classic
    bandwidth/footprint balance — O(sqrt n) host callbacks per sweep while
    the device-side staging buffer stays O(sqrt n) state vectors (sublinear,
    so spilling still removes the O(n) term from device-live memory)."""
    if n_steps <= 1:
        return 1
    r = int(np.sqrt(n_steps))
    return int(r if r * r >= n_steps else r + 1)


def host_memory_kind() -> Optional[str]:
    """The backend's off-device host memory space, or None if unavailable."""
    try:
        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
    except Exception:  # pragma: no cover - very old jaxlib
        return None
    default = None
    try:
        default = jax.devices()[0].default_memory().kind
    except Exception:  # pragma: no cover
        pass
    for kind in ("pinned_host", "unpinned_host"):
        if kind in kinds and kind != default:
            return kind
    return None


#: degradation ladder: where a tier falls when a fault plan marks it down
_LADDER = {"spill": "host", "host": "device"}


def _crc_leaves(arrs) -> int:
    """One crc32 over the concatenated bytes of a slot's leaves."""
    c = 0
    for a in arrs:
        c = zlib.crc32(np.ascontiguousarray(a).tobytes(), c)
    return c


def effective_tier(tier: Optional[str], fault_plan=None, *,
                   scanned: bool = False, obs=None) -> Optional[str]:
    """Walk the degradation ladder (spill -> host -> device) past tiers a
    ``FaultPlan`` marks unavailable (``FaultSpec("tier.<t>", 0, "down")``).
    Returns the first available tier; each hop is recorded as a
    ``store.degrade`` obs event when a recorder is given.  ``scanned=True``
    says the caller is a scanned segment-batched sweep, which cannot use
    the slot-addressed host tier — spill then degrades straight to
    device."""
    if fault_plan is None or tier in (None, "device"):
        return tier
    cur = tier
    while cur not in (None, "device") and fault_plan.tier_disabled(cur):
        nxt = "device" if (scanned and cur == "spill") else _LADDER[cur]
        if obs is not None:
            obs.record("store.degrade", requested=tier, from_tier=cur,
                       to_tier=nxt, scanned=bool(scanned))
        cur = nxt
    return cur


def make_store(tier: Optional[str], *, fault_plan=None,
               integrity: bool = False, max_retries: int = 3,
               retry_backoff_s: float = 1e-3) -> "CheckpointStore":
    """Build a store for ``tier``.  The resilience knobs apply to the
    spill tier only (the others have no host round-trips to protect):
    ``fault_plan`` arms the injection hooks inside the callbacks,
    ``integrity`` turns on per-slot crc32 checksums (required by
    ``prefetch_checked``), ``max_retries``/``retry_backoff_s`` bound the
    read retry loop.  ``store.requested_tier`` always records what the
    caller asked for, even after a ladder degrade upstream."""
    if tier in (None, "device"):
        st: CheckpointStore = DeviceStore()
    elif tier == "host":
        st = HostStore()
    elif tier == "spill":
        sp = SpillStore()
        sp.fault_plan = fault_plan
        sp.integrity = bool(integrity)
        sp.max_retries = int(max_retries)
        sp.retry_backoff_s = float(retry_backoff_s)
        st = sp
    else:
        raise ValueError(f"unknown offload tier {tier!r}; one of {TIERS}")
    st.requested_tier = tier
    return st


class CheckpointStore:
    """Common interface; concrete tiers override the transfer points.

    Forward sweep:   put(slot, tree)* -> pack() returned as residuals.
    Reverse sweep:   unpack(res, slots); then get/put/free in any order the
    schedule demands (bwd puts come from revolve "advance" actions).
    Scanned sweeps:  token = init_token(); token = write_at(token, i, tree)
    or token = write_batch(token, base, stacked); token, stacked =
    prefetch(token, base, seg) — token must ride the scan carry and cross
    fwd->bwd through the residuals.
    """

    tier = "device"

    def __init__(self):
        self._vals: Dict[int, PyTree] = {}
        self._order: List[int] = []
        self.effective_tier = self.tier
        self.requested_tier = self.tier
        self.store_id = f"{self.tier}-{next(_STORE_IDS)}"
        self._obs = None

    def bind_obs(self, recorder) -> None:
        """Attach a ``repro.obs.FlightRecorder``.  Device/host tiers
        record trace-time ``store.put``/``store.get``/``store.free``
        events (the schedule — once per compilation); the spill tier
        additionally records runtime ``spill.*`` events from inside its
        host callbacks (once per execution)."""
        self._obs = recorder

    def _note(self, kind: str, slot, tree: PyTree = None) -> None:
        if self._obs is None:
            return
        self._obs.record(kind, store=self.store_id,
                         tier=self.effective_tier, slot=slot,
                         bytes=_tree_nbytes(tree) if tree is not None else 0)

    # -- slot-addressed (trace-time revolve schedule) ----------------------
    def put(self, slot: int, tree: PyTree) -> None:
        self._note("store.put", slot, tree)
        if slot not in self._vals:
            self._order.append(slot)
        self._vals[slot] = self._to_store(tree)

    def get(self, slot: int) -> PyTree:
        self._note("store.get", slot, self._vals[slot])
        return self._from_store(self._vals[slot])

    def free(self, slot: int) -> None:
        self._note("store.free", slot)
        self._vals.pop(slot, None)

    def pack(self) -> PyTree:
        """Residual pytree carrying the forward sweep's checkpoints (in put
        order — the slot keys themselves are trace-time ints the reverse
        rule recomputes and passes back to ``unpack``)."""
        return tuple(self._vals[s] for s in self._order)

    def unpack(self, res: PyTree, slots) -> None:
        self._vals = dict(zip(slots, res))
        self._order = list(slots)

    # -- index-addressed (scanned pnode / adaptive ring buffer) ------------
    def init_token(self):
        return jnp.zeros((), jnp.float32)

    def write_at(self, token, idx, tree: PyTree, keep=None):
        raise NotImplementedError(
            f"offload tier {self.tier!r} does not support scanned "
            "(traced-index) checkpoint writes; use 'spill'")

    # -- segment-batched (one callback per checkpoint segment) -------------
    def write_batch(self, token, base, tree: PyTree):
        raise NotImplementedError(
            f"offload tier {self.tier!r} does not support segment-batched "
            "checkpoint writes; use 'spill'")

    def prefetch(self, token, base, seg: int):
        raise NotImplementedError(
            f"offload tier {self.tier!r} does not support segment "
            "prefetch; use 'spill'")

    # -- transfer points ----------------------------------------------------
    def _to_store(self, tree: PyTree) -> PyTree:
        return tree

    def _from_store(self, tree: PyTree) -> PyTree:
        return tree


class DeviceStore(CheckpointStore):
    tier = "device"


class HostStore(CheckpointStore):
    """Pinned-host residuals via memory-kind transfer (degrades to device)."""

    tier = "host"

    def __init__(self):
        super().__init__()
        self._kind = host_memory_kind()
        self.effective_tier = "host" if self._kind else "device"

    def _transfer(self, tree: PyTree, kind: str) -> PyTree:
        try:
            from jax._src.sharding_impls import TransferToMemoryKind
        except ImportError:  # pragma: no cover - newer jax moved it
            from jax.sharding import TransferToMemoryKind  # type: ignore
        return jtu.tree_map(
            lambda x: jax.device_put(x, TransferToMemoryKind(kind)), tree)

    def _to_store(self, tree: PyTree) -> PyTree:
        if self._kind is None:
            return tree
        return self._transfer(tree, self._kind)

    def _from_store(self, tree: PyTree) -> PyTree:
        if self._kind is None:
            return tree
        return self._transfer(tree, "device")


class SpillStore(CheckpointStore):
    """Host-dict spill through token-threaded pure_callback.

    The store object itself is a static (nondiff) argument of the
    ``custom_vjp`` that uses it, so the same instance — and the same host
    dict — is visible to both the fwd and bwd rules.  Leaf shape/dtype
    metadata is recorded at put-trace time (object attributes persist from
    the fwd trace to the bwd trace) so reads know their result shapes.
    """

    tier = "spill"

    def __init__(self):
        super().__init__()
        self._host: Dict[Any, List[np.ndarray]] = {}
        self._meta: Dict[Any, Tuple[Any, Tuple[jax.ShapeDtypeStruct, ...]]] = {}
        self._tok = None
        self.effective_tier = "spill"
        #: per-store callback counters (see module docstring); mutation
        #: holds _STATS_LOCK and mirrors into the _AGG view
        self.stats: Dict[str, int] = {k: 0 for k in _STAT_KEYS}
        _STORES[self.store_id] = self
        #: vmap payload multiplier for the chunking decision — set by the
        #: odeint entry point via ``batch_scale(...)`` (mapped axes are
        #: invisible by the time write_batch/prefetch are traced; see
        #: ``batch_scale``).
        self.payload_scale = 1
        #: resilience knobs (see ``make_store``); all dormant by default —
        #: with fault_plan=None and integrity=False the callbacks execute
        #: the exact pre-PR-8 byte sequence
        self.fault_plan = None
        self.integrity = False
        self.max_retries = 3
        self.retry_backoff_s = 1e-3
        #: per-slot crc32 over the CLEAN payload, recorded at write time
        #: when ``integrity`` is on (host-side dict like ``_host``)
        self._sums: Dict[int, int] = {}

    # -- resilience helpers (host-side, called from the callbacks) -----------
    def _tally_counter(self, key: str, n: int = 1) -> None:
        with _STATS_LOCK:
            self.stats[key] += n
            _AGG[key] += n

    def _apply_write_fault(self, spec, slot: int, arrs):
        """Apply a ticked ``spill.write`` fault to one slot's payload:
        ``drop`` loses it in transit (returns None, nothing stored),
        ``corrupt`` returns deterministically flipped bytes.  Checksums
        are recorded over the clean payload BEFORE this runs — the
        corruption-at-rest model the read-side verify detects."""
        if spec is None:
            return arrs
        if spec.kind == "drop":
            self._host.pop(slot, None)
            return None
        if spec.kind == "corrupt":
            return self.fault_plan.corrupt_arrays(arrs, salt=slot)
        return arrs

    def _read_attempt_ok(self, base: int) -> bool:
        """One logical read, retried with exponential backoff while the
        fault plan flakes it.  Every attempt ticks ``spill.read`` (so a
        spec's ``count`` window spans retries: transient faults are
        escaped by retrying, persistent ones exhaust the budget).
        Returns False only when ``max_retries`` retries all flaked."""
        if self.fault_plan is None:
            return True
        for attempt in range(self.max_retries + 1):
            spec = self.fault_plan.tick("spill.read")
            if spec is None or spec.kind != "flake":
                return True
            if attempt == self.max_retries:
                return False
            self._tally_counter("retry_cb")
            if self._obs is not None:
                self._obs.record("spill.retry", _runtime=True,
                                 store=self.store_id, base=base,
                                 attempt=attempt + 1)
            time.sleep(self.retry_backoff_s * (2 ** attempt))
        return False

    def _slot_intact(self, slot: int) -> bool:
        """Present and (when integrity is on) matching its write-time
        checksum.  A slot written before integrity was enabled has no
        recorded sum and passes (nothing to verify against)."""
        leaves = self._host.get(slot)
        if leaves is None:
            return False
        if not self.integrity:
            return True
        want = self._sums.get(slot)
        return want is None or _crc_leaves(leaves) == want

    # -- counting + obs (host-side, called from the callbacks) --------------
    def _tally(self, direction: str, *, slots: int, nbytes: int, base):
        """Bump this store's counters and the aggregate in lockstep (under
        the module lock — see module docstring), then record an obs event
        if a recorder is bound.  Runs on XLA's callback thread."""
        with _STATS_LOCK:
            if direction == "free":
                self.stats["free_cb"] += 1
                _AGG["free_cb"] += 1
            else:
                for key, n in ((f"{direction}_cb", 1),
                               (f"{direction}_slots", slots),
                               (f"{direction}_bytes", nbytes)):
                    self.stats[key] += n
                    _AGG[key] += n
        if self._obs is not None:
            self._obs.record(f"spill.{direction}", _runtime=True,
                             store=self.store_id, base=base,
                             slots=slots, bytes=nbytes)

    # -- host-side callbacks (never traced) ---------------------------------
    def _cb_write(self, token, slot, *leaves):
        with host_annotation("spill/write"):
            spec = (self.fault_plan.tick("spill.write")
                    if self.fault_plan is not None else None)
            arrs = [np.asarray(x).copy() for x in leaves]
            if self.integrity:
                self._sums[int(slot)] = _crc_leaves(arrs)
            arrs = self._apply_write_fault(spec, int(slot), arrs)
            if arrs is not None:
                self._host[int(slot)] = arrs
            self._tally("write", slots=1,
                        nbytes=sum(np.asarray(x).nbytes for x in leaves),
                        base=int(slot))
        return np.float32(0)

    def _cb_write_if(self, token, slot, keep, *leaves):
        with host_annotation("spill/write"):
            spec = (self.fault_plan.tick("spill.write")
                    if self.fault_plan is not None else None)
            if bool(keep):
                arrs = [np.asarray(x).copy() for x in leaves]
                if self.integrity:
                    self._sums[int(slot)] = _crc_leaves(arrs)
                arrs = self._apply_write_fault(spec, int(slot), arrs)
                if arrs is not None:
                    self._host[int(slot)] = arrs
                self._tally("write", slots=1,
                            nbytes=sum(np.asarray(x).nbytes for x in leaves),
                            base=int(slot))
            else:  # masked out: the round-trip still happened
                self._tally("write", slots=0, nbytes=0, base=int(slot))
        return np.float32(0)

    def _cb_read(self):
        def read(token, slot):
            with host_annotation("spill/read"):
                if not self._read_attempt_ok(int(slot)):
                    # the slot-addressed schedule has no recompute
                    # fallback; a persistent read failure is fatal here
                    raise RuntimeError(
                        f"spill store: read of slot {int(slot)} still "
                        f"failing after {self.max_retries} retries")
                leaves = self._host.get(int(slot))
                if leaves is None:
                    # a schedule bug or a reordered free — fail loudly
                    # rather than silently contributing zero gradients
                    raise KeyError(f"spill store: slot {int(slot)} read "
                                   "before it was written (or after free)")
                if not self._slot_intact(int(slot)):
                    self._tally_counter("integrity_fail")
                    raise RuntimeError(
                        f"spill store: slot {int(slot)} failed its "
                        "integrity check (checksum mismatch) and the "
                        "slot-addressed path has no recompute fallback")
                arrs = tuple(np.asarray(x) for x in leaves)
                self._tally("read", slots=1,
                            nbytes=sum(a.nbytes for a in arrs),
                            base=int(slot))
                return (np.float32(0),) + arrs
        return read

    def _cb_free(self, token, slot):
        with host_annotation("spill/free"):
            self._host.pop(int(slot), None)
            self._tally("free", slots=1, nbytes=0, base=int(slot))
        return np.float32(0)

    def _cb_write_batch(self, token, base, *stacked):
        """ONE host round-trip storing seg consecutive slots (leaves arrive
        stacked on the segment axis).

        Batch-aware: under ``vmap`` (``vmap_method="broadcast_all"``) every
        argument arrives broadcast to the full batch shape — the token's
        ndim IS the number of mapped axes (its logical shape is scalar), so
        the segment axis sits at ``np.ndim(token)`` and each slot stores
        the whole batch block ``arr[..., i, :]``.  One callback serves the
        entire batch and batch elements never alias: element b's
        checkpoints live at index b of its slot's block (the
        per-batch-element key scheme)."""
        with host_annotation("spill/write_batch"):
            spec = (self.fault_plan.tick("spill.write")
                    if self.fault_plan is not None else None)
            bnd = np.ndim(token)
            seg = int(np.shape(stacked[0])[bnd])
            base = int(np.ravel(base)[0])  # broadcast copies are identical
            arrs = [np.asarray(x) for x in stacked]
            sl = (slice(None),) * bnd
            for i in range(seg):
                slot_arrs = [a[sl + (i,)].copy() for a in arrs]
                if self.integrity:
                    self._sums[base + i] = _crc_leaves(slot_arrs)
                slot_arrs = self._apply_write_fault(spec, base + i, slot_arrs)
                if slot_arrs is not None:
                    self._host[base + i] = slot_arrs
            self._tally("write", slots=seg,
                        nbytes=sum(a.nbytes for a in arrs), base=base)
        return np.zeros(np.shape(token), np.float32)

    def _cb_prefetch(self, seg, checked=False):
        def fetch(token, base):
            with host_annotation("spill/prefetch"):
                _, sds = self._meta["idx"]
                bshape = np.shape(token)  # mapped axes (see _cb_write_batch)
                bnd = len(bshape)
                base = int(np.ravel(base)[0])
                sl = (slice(None),) * bnd
                ok = True
                if not self._read_attempt_ok(base):
                    if not checked:
                        raise RuntimeError(
                            f"spill store: prefetch at base {base} still "
                            f"failing after {self.max_retries} retries and "
                            "this path has no recompute fallback")
                    ok = False  # checked caller recomputes the segment
                out = []
                for k, s in enumerate(sds):
                    stack = np.zeros(bshape + (seg,) + tuple(s.shape),
                                     s.dtype)
                    if ok:
                        for i in range(seg):
                            leaves = self._host.get(base + i)
                            if leaves is not None:  # missing slots -> zeros
                                stack[sl + (i,)] = leaves[k]
                    out.append(stack)
                if checked and ok:
                    for i in range(seg):
                        if not self._slot_intact(base + i):
                            ok = False
                            self._tally_counter("integrity_fail")
                            if self._obs is not None:
                                self._obs.record(
                                    "spill.integrity", _runtime=True,
                                    store=self.store_id, slot=base + i,
                                    base=base)
                self._tally("read", slots=seg,
                            nbytes=sum(a.nbytes for a in out), base=base)
                res = (np.zeros(bshape, np.float32),)
                if checked:
                    res = res + (np.full(bshape, ok, bool),)
                return res + tuple(out)
        return fetch

    # -- metadata ------------------------------------------------------------
    def _record(self, key, tree: PyTree):
        leaves, treedef = jtu.tree_flatten(tree)
        sds = tuple(jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))
                    for x in leaves)
        self._meta[key] = (treedef, sds)
        return leaves

    # -- slot-addressed ------------------------------------------------------
    def put(self, slot: int, tree: PyTree) -> None:
        if self._tok is None:
            self._tok = self.init_token()
        leaves = self._record("slot", tree)
        self._tok = jax.pure_callback(
            self._cb_write, _TOKEN_SDS, self._tok, np.int32(slot), *leaves)

    def get(self, slot: int) -> PyTree:
        # reads also return a fresh token that subsequent free/put calls
        # consume: without that anti-dependency edge the scheduler could
        # legally run a free (or an overwriting put) before the read
        treedef, sds = self._meta["slot"]
        out = jax.pure_callback(
            self._cb_read(), (_TOKEN_SDS,) + sds,
            self._tok, np.int32(slot))
        self._tok = out[0]
        return jtu.tree_unflatten(treedef, out[1:])

    def free(self, slot: int) -> None:
        self._tok = jax.pure_callback(
            self._cb_free, _TOKEN_SDS, self._tok, np.int32(slot))

    def pack(self) -> PyTree:
        return self._tok

    def unpack(self, res: PyTree, slots) -> None:
        self._tok = res

    # -- index-addressed -----------------------------------------------------
    def write_at(self, token, idx, tree: PyTree, keep=None):
        leaves = self._record("idx", tree)
        if keep is None:
            return jax.pure_callback(
                self._cb_write, _TOKEN_SDS, token, idx, *leaves)
        return jax.pure_callback(
            self._cb_write_if, _TOKEN_SDS, token, idx, keep, *leaves)

    # -- segment-batched -----------------------------------------------------
    def write_batch(self, token, base, tree: PyTree):
        """Store slots ``[base, base+seg)`` in one callback per
        payload-capped chunk (one total in the common case).  ``tree``
        leaves carry the segment on axis 0 (``seg`` = the static leading
        dim, as stacked by a per-segment inner scan); ``base`` may be
        traced.  Returns a fresh ordering token."""
        leaves, treedef = jtu.tree_flatten(tree)
        # record PER-SLOT metadata (axis 0 stripped) under the same "idx"
        # key the adaptive write_at path records, so prefetch interoperates
        # with either write path
        sds = tuple(jax.ShapeDtypeStruct(tuple(jnp.shape(x)[1:]),
                                         jnp.result_type(x))
                    for x in leaves)
        self._meta["idx"] = (treedef, sds)
        seg = int(jnp.shape(leaves[0])[0]) if leaves else 1
        per_slot = max((int(np.prod(s.shape, dtype=np.int64))
                        * np.dtype(s.dtype).itemsize)
                       for s in sds) * self.payload_scale if leaves else 0
        m = _chunk_slots(seg, per_slot)
        tok = token
        for o in range(0, seg, m):
            chunk = [x[o:o + m] for x in leaves]
            tok = jax.pure_callback(self._cb_write_batch, _TOKEN_SDS, tok,
                                    base + o, *chunk,
                                    vmap_method="broadcast_all")
        return tok

    def prefetch(self, token, base, seg: int):
        """Fetch slots ``[base, base+seg)`` stacked on axis 0 in one
        callback per payload-capped chunk — one total in the common case
        (missing slots read as zeros — the reverse sweeps cond-skip or
        mask them).  Returns ``(token, tree)``; the fresh
        token orders any later frees/overwrites after this read, and
        because the result is an ordinary traced buffer the caller can
        issue the next segment's prefetch before consuming this one
        (double buffering)."""
        treedef, sds = self._meta["idx"]
        per_slot = max((int(np.prod(s.shape, dtype=np.int64))
                        * np.dtype(s.dtype).itemsize)
                       for s in sds) * self.payload_scale if sds else 0
        m = _chunk_slots(seg, per_slot)
        tok, pieces = token, []
        for o in range(0, seg, m):
            mm = min(m, seg - o)
            out_sds = (_TOKEN_SDS,) + tuple(
                jax.ShapeDtypeStruct((mm,) + tuple(s.shape), s.dtype)
                for s in sds)
            out = jax.pure_callback(self._cb_prefetch(mm), out_sds, tok,
                                    base + o, vmap_method="broadcast_all")
            tok = out[0]
            pieces.append(out[1:])
        if len(pieces) == 1:
            stacked = pieces[0]
        else:
            stacked = [jnp.concatenate(ps, axis=0) for ps in zip(*pieces)]
        return tok, jtu.tree_unflatten(treedef, stacked)

    def prefetch_checked(self, token, base, seg: int):
        """``prefetch`` plus an integrity verdict: returns ``(token, ok,
        tree)`` where ``ok`` (a traced bool) is True only if every slot in
        ``[base, base+seg)`` was present, passed its crc32 (recorded at
        write time; requires the store built with ``integrity=True``), and
        the host read did not exhaust its retry budget.  On ``ok=False``
        the returned tree is whatever could be read (zeros on total
        failure) — callers must ``lax.cond`` on ``ok`` into a recompute
        fallback rather than consume it.  Chunked exactly like
        ``prefetch``; the chunk verdicts AND together."""
        treedef, sds = self._meta["idx"]
        per_slot = max((int(np.prod(s.shape, dtype=np.int64))
                        * np.dtype(s.dtype).itemsize)
                       for s in sds) * self.payload_scale if sds else 0
        m = _chunk_slots(seg, per_slot)
        ok_sds = jax.ShapeDtypeStruct((), jnp.bool_)
        tok, ok, pieces = token, None, []
        for o in range(0, seg, m):
            mm = min(m, seg - o)
            out_sds = (_TOKEN_SDS, ok_sds) + tuple(
                jax.ShapeDtypeStruct((mm,) + tuple(s.shape), s.dtype)
                for s in sds)
            out = jax.pure_callback(self._cb_prefetch(mm, checked=True),
                                    out_sds, tok, base + o,
                                    vmap_method="broadcast_all")
            tok = out[0]
            ok = out[1] if ok is None else jnp.logical_and(ok, out[1])
            pieces.append(out[2:])
        if len(pieces) == 1:
            stacked = pieces[0]
        else:
            stacked = [jnp.concatenate(ps, axis=0) for ps in zip(*pieces)]
        return tok, ok, jtu.tree_unflatten(treedef, stacked)
