"""Checkpoint stores: where adjoint checkpoints live between fwd and bwd.

The revolve/pnode adjoints in ``core/adjoint.py`` write (state, stages)
checkpoints through one of these stores instead of returning them directly
as ``custom_vjp`` residuals.  Four tiers:

  device   checkpoints stay traced values and travel through the residual
           pytree — exactly the seed behavior (XLA keeps them in device
           memory for the whole fwd->bwd window).
  host     checkpoints are moved to the backend's pinned-host memory space
           with ``jax.device_put(x, TransferToMemoryKind("pinned_host"))``
           at put time and brought back at get time; the residual pytree
           carries host-resident arrays, so device-live memory between the
           sweeps is O(working set).  Sharded arrays keep their layout: a
           memory-kind transfer preserves the NamedSharding, so each device
           spills its own shard.  On backends without a pinned_host space
           (XLA:CPU in this container exposes only unpinned_host) the tier
           degrades to ``device`` and records ``effective_tier`` so callers
           and tests can see the downgrade.
  spill    checkpoints leave the XLA program entirely through a
           token-threaded ``jax.pure_callback`` into a host-side numpy dict.
           The residual is one f32 scalar (the ordering token), so the
           reverse pass's device-live set is O(ncheck) / O(1) regardless of
           ``n_steps``.  Ordering: every write returns a fresh token and
           every read consumes the latest one, so writes are
           data-dependencies of reads and XLA cannot reorder or elide
           them; slot reads return a token too, ordering subsequent
           frees/overwrites after the reads that precede them.
           (``io_callback(ordered=True)`` would be the natural primitive,
           but its effects are silently dropped inside ``custom_vjp`` rules
           on jax 0.4.37 — verified empirically — hence the token chain.)
  disk     the spill machinery with its slot payloads routed to
           file-backed segment files (``repro_spill_*.npz`` under a
           temp/caller directory) instead of the host RAM dict.  Same
           callbacks, same token contract, same CRC-integrity and
           retry-backoff behavior — only WHERE the host side of the
           callback puts the bytes changes, so every bitwise-gradient
           contract that holds for ``spill`` holds for ``disk`` unchanged.

Multi-tier split (``snaps_in_ram``): a spill store built with
``snaps_in_ram=K`` keeps at most K checkpoint slots resident in the RAM
dict and routes overflow batches to disk files — dolfin-adjoint's
multistage ``snaps_in_ram``/``snaps_on_disk`` shape (SNIPPETS.md snippet
2).  Routing is per write batch (a segment lands wholly in one tier, so a
prefetch usually touches one medium) and per slot on the slot-addressed
revolve path; freeing RAM slots makes room again, so a revolve schedule's
hot window stays in RAM while cold snapshots sink to disk.
``snaps_in_ram=None`` (default) is the historical all-RAM store;
``snaps_in_ram=0`` (what ``make_store("disk")`` configures) is all-disk.
Disk files hold one write batch each (one ``np.savez`` extent, no pickle),
with a slot->file index, a one-file read cache sized for the
segment-aligned access pattern, refcounted deletion, a stale-file sweep on
``set_disk_dir`` (dead runs' ``repro_spill_*.npz`` are removed), and a
``weakref.finalize`` that deletes this store's files (and its own tempdir)
at GC/exit.

Two addressing modes, matching the two checkpoint write paths:

  * slot puts/gets (``put``/``get``/``free``) take a *Python int* slot —
    the trace-time-unrolled revolve schedule addresses checkpoints by step
    index known at trace time;
  * indexed writes (``write_at``) take a *traced* index and thread the
    token explicitly — reads on the scanned paths go through the
    segment-batched ``prefetch``.  (The adaptive forward sweep used to
    ``write_at`` once per attempted step; it now batches accepted steps
    through a device-side staging ring and flushes with ``write_batch``
    once per segment — see ``core/adaptive.py``.)

Segment-batched I/O (``write_batch``/``prefetch``): one callback per
checkpoint *segment* instead of per step.  ``write_batch(token, base, tree)``
stores ``seg`` consecutive slots from leaves stacked on axis 0;
``prefetch(token, base, seg)`` returns slots ``[base, base+seg)`` stacked.
The scanned pnode/adaptive/implicit reverse sweeps use these to cut host
round-trips from O(n_steps) to O(n_segments); token threading is
unchanged, so frees still cannot reorder ahead of reads.

Async overlap (``prefetch_issue``): ``prefetch`` alone is synchronous on
XLA:CPU (``pure_callback`` blocks), so batching wins the callback *count*
but not overlap.  ``prefetch_issue(token, base, seg)`` is the overlap
half: a token-only callback that SUBMITS the host-side gather of
``[base, base+seg)`` to the store's single-worker background executor and
returns immediately; the matching ``prefetch``/``prefetch_checked`` at the
same base consumes the staged rows (``prefetch_hit_cb`` counts the hits)
instead of re-reading storage.  The reverse sweeps issue segment k-1's
gather right after waiting on segment k, so disk/dict I/O overlaps the
adjoint compute of the current segment.  Fault injection, integrity
verification, and retry-backoff stay in the synchronous wait callback (the
background task is a raw gather), so chaos schedules remain deterministic.
Ordering: the issue, the wait, and any later free all ride the one token
chain, and the wait blocks on the background future before returning — so
a free ordered after the wait cannot overtake the read.  Do not order a
free of the same slots BETWEEN an issue and its wait (no caller does).

Payload cap: XLA:CPU copies callback operands/results on the same intra-op
thread pool the callback itself occupies, and once a single buffer is
large enough for that copy to be parallelized (~100 KiB measured on jax
0.4.37) the nested parallel-for deadlocks the pool — the callback never
returns and the program hangs.  ``write_batch``/``prefetch`` therefore
split any segment whose largest per-leaf payload (batch axes included)
exceeds ``_CB_PAYLOAD_CAP`` into multiple token-chained callbacks of
slot-aligned chunks.  ``spill_stats()`` counts every chunk callback, so
the BENCH gates price the real host round-trips.  A single slot bigger
than the cap cannot be split further (warned; the slot-addressed
``put``/``write_at`` paths have the same exposure).

Counters: every store keeps its own host-side callback counters
(``store.stats``, keyed by an auto-assigned ``store_id``) and mirrors each
increment into a process-wide aggregate — ``spill_stats()`` returns the
aggregate (the historical API the BENCH_3 gates and per-segment
callback-count tests read), ``per_store_spill_stats()`` the per-store
view.  All counter mutation holds one module lock: XLA executes callbacks
on its own thread pool, so a chunked/vmapped program's callbacks can run
concurrently with each other and with a benchmark's
``reset_spill_stats()`` on the main thread — unlocked dict updates would
lose increments or tear the reset.  Counters count actual EXECUTIONS, not
traces.  ``read_cb``/``write_cb`` count data-carrying round-trips only;
``dispatch_cb`` counts the token-only async-issue callbacks separately so
the BENCH_3 callbacks-per-reverse-pass gates keep their historical
meaning.  ``disk_write_bytes``/``disk_read_bytes`` break the byte traffic
down by medium, and ``ram_bytes_peak`` is a high-water gauge of the RAM
dict (max-merged into the aggregate; zeroed by ``reset_spill_stats``) —
the number the BENCH_6 RAM-budget gate checks.  Attaching a
``repro.obs.FlightRecorder`` via ``bind_obs`` makes every callback
additionally record a ``spill.write``/``spill.read``/``spill.free``/
``spill.dispatch`` trace event carrying the store id, slot base, slot
count, payload bytes, and the medium (``tier="ram"|"disk"|"mixed"``) —
recorded purely host-side inside the callbacks that already run, so the
traced program is unchanged and grads stay bitwise identical with obs on.

Table-2 mapping (see ``repro.mem.model``): the store only changes WHERE
N_c*(N_s+1) checkpoint vectors live, never how many f-evaluations the
policy performs — spill and disk grads are bitwise-identical to device
grads (tests/test_mem.py, tests/test_longhaul.py).

vmap: the *slot-addressed* mode is not supported under ``vmap`` (the
callback sees one logical index for the whole batch, so per-example
checkpoints would alias — ``core.adjoint._reject_vmap_offload`` catches it
up front).  The *segment-batched* mode IS (``vmap_method="broadcast_all"``):
one callback serves the entire batch, each slot stores the full batch
block with batch axes leading, so element b's checkpoints occupy index b
of the block — the per-batch-element layout the vmapped implicit
ensembles rely on (``core.implicit``) and, since PR 10, the vmapped
explicit scanned pnode path (``core.adjoint``).  Stores are
per-``odeint``-call objects unless a caller passes its own
(``odeint(offload_store=...)``), so concurrent solves never share keys
(a caller-owned ``disk_dir`` likewise belongs to one live store at a
time — the stale sweep on init assumes any file it finds is from a dead
run).

Per-request lane keys (PR 10, the serving engine's contract): setting
``store.lane_keys = (rid_0, ..., rid_{B-1})`` — one entry per leading
mapped batch lane, ``None`` marking a padding lane — switches the
segment-batched callbacks from whole-batch blocks to per-lane rows keyed
``(rid_b, base + i)``.  Each in-flight request's checkpoint segments are
then independently written, prefetched, and freed: padding lanes store
NOTHING (a half-full bucket costs half the checkpoint bytes), a
departing request's slots are dropped host-side with
``free_request(rid)`` (``slot_census()`` returns to empty once every
lane departed), and ``request_slots(rid)`` counts one request's live
slots.  ``lane_keys`` is consulted at callback EXECUTION time, never at
trace time, so one compiled bucket program serves every batch
composition — the jit cache stays bounded by the bucket set.  Values
pass through the exact same bytes as the unkeyed layout (row ``b`` of
the batch block), so keyed batched solves stay bitwise-identical to the
equivalent unbatched per-request loop.  Only a single mapped axis is
supported (the serving batch); ``free_request`` runs between executions
(host-side, not token-ordered) — never while a solve that still needs
those slots is in flight.

Resilience (PR 8; all dormant-by-default, the plain paths above are
byte-identical when unused):

  * ``integrity=True`` records a crc32 over every slot's CLEAN payload at
    write time; ``prefetch_checked`` re-verifies on read and returns an
    ``ok`` flag alongside the data (False on a missing slot, a checksum
    mismatch, or exhausted read retries), so callers with recompute
    freedom — the scanned implicit adjoint — can ``lax.cond`` into
    re-integrating the segment from its boundary state instead of
    consuming garbage.  Corruption is modeled *at rest*: an injected
    ``spill.write``/``corrupt`` fault flips stored bytes after
    checksumming, which is exactly what the read-side verify catches —
    on the disk tier the flipped bytes are what lands in the segment
    file, so on-disk corruption takes the identical recompute path.
  * reads retry with exponential backoff (host-side ``time.sleep``; never
    in traced code) up to ``max_retries`` times when a ``FaultPlan``
    flakes the attempt — transient faults cost ``retry_cb`` ticks and
    succeed; persistent ones surface as ``ok=False`` (checked) or a
    ``RuntimeError`` (unchecked paths have no recompute fallback).
  * ``effective_tier(tier, fault_plan)`` walks the degradation ladder
    spill -> disk -> host -> device past tiers the plan marks down
    (``FaultSpec("tier.spill", 0, "down")``), recording ``store.degrade``
    obs events; scanned sweeps skip the slot-addressed host tier, so for
    them a downed disk tier degrades straight to device (disk itself IS
    scanned-capable — it's the same callbacks).
"""
from __future__ import annotations

import glob
import itertools
import os
import shutil
import tempfile
import threading
import time
import weakref
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import tree_util as jtu

from repro.obs.profile import host_annotation

PyTree = Any

TIERS = ("device", "host", "spill", "disk")

_TOKEN_SDS = jax.ShapeDtypeStruct((), jnp.float32)

#: per-callback payload cap in bytes, applied to each operand/result leaf
#: with mapped batch axes counted.  Above ~100 KiB the XLA:CPU callback
#: buffer copy is parallelized on the pool the callback blocks, and the
#: program deadlocks (see module docstring); 96 KiB keeps headroom.
_CB_PAYLOAD_CAP = 96 * 1024

#: filename prefix for disk-tier segment files; ``set_disk_dir`` sweeps
#: stale matches (files left by a dead run) before reusing a directory.
_DISK_PREFIX = "repro_spill_"


def batch_scale(tree: PyTree) -> int:
    """Product of mapped-axis sizes riding the leaves of ``tree`` — the
    factor by which vmap multiplies every callback payload.

    Must be called where the mapped axes are still visible as
    ``BatchTracer``s (the ``odeint`` entry point, like
    ``core.adjoint._reject_vmap_offload``): ``custom_vjp`` forwards are
    retraced at *logical* shapes, so by the time ``write_batch`` runs the
    batch axes cannot be recovered from its arguments."""
    try:
        from jax.interpreters.batching import BatchTracer
    except ImportError:  # pragma: no cover - future jax moved it
        return 1

    def scale(x) -> int:
        s, y, depth = 1, x, 0
        while isinstance(y, jax.core.Tracer) and depth < 8:
            if isinstance(y, BatchTracer):
                bd = getattr(y, "batch_dim", None)
                if isinstance(bd, int):
                    s *= int(np.shape(y.val)[bd])
                y = y.val
            else:
                nxt = getattr(y, "primal", None)
                if nxt is None:
                    nxt = getattr(y, "val", None)
                if nxt is None or nxt is y:
                    break
                y = nxt
            depth += 1
        return s

    return max((scale(x) for x in jtu.tree_leaves(tree)), default=1)


def _tree_nbytes(tree: PyTree) -> int:
    """Logical payload bytes of a pytree (works on traced values)."""
    return sum(int(np.prod(jnp.shape(x), dtype=np.int64))
               * np.dtype(jnp.result_type(x)).itemsize
               for x in jtu.tree_leaves(tree))


def _chunk_slots(seg: int, per_slot_bytes: int) -> int:
    """Slots per callback so no payload leaf exceeds ``_CB_PAYLOAD_CAP``."""
    if per_slot_bytes <= 0:
        return seg
    m = int(_CB_PAYLOAD_CAP // per_slot_bytes)
    if m < 1:
        import warnings
        warnings.warn(
            f"spill store: a single checkpoint slot is {per_slot_bytes} "
            f"bytes, above the {_CB_PAYLOAD_CAP}-byte per-callback payload "
            "cap; XLA:CPU may deadlock copying it (see "
            "repro.mem.offload docstring)", stacklevel=3)
        return 1
    return min(m, seg)

#: counter keys every SpillStore tracks (per store and in the aggregate):
#: ``*_cb`` counts data-carrying host round-trips, ``*_slots`` checkpoint
#: slots moved (slots/cb = achieved batching factor), ``*_bytes`` payload
#: traffic; ``dispatch_cb`` counts token-only async prefetch issues and
#: ``prefetch_hit_cb`` the waits that consumed a background gather;
#: ``disk_*_bytes`` is the slice of the byte traffic that hit segment
#: files; ``ram_bytes_peak`` is a high-water gauge (max-merged, not
#: summed) of the RAM dict; ``retry_cb`` counts read attempts repeated
#: after an injected flake and ``integrity_fail`` slots that failed their
#: checksum/presence check.
_STAT_KEYS = ("write_cb", "read_cb", "free_cb",
              "write_slots", "read_slots", "write_bytes", "read_bytes",
              "dispatch_cb", "prefetch_hit_cb",
              "disk_write_bytes", "disk_read_bytes", "ram_bytes_peak",
              "retry_cb", "integrity_fail")

#: guards ALL counter mutation and the reset: callbacks execute on XLA's
#: thread pool, concurrently with each other (chunked/vmapped programs)
#: and with a benchmark's ``reset_spill_stats()`` on the main thread.
_STATS_LOCK = threading.RLock()

#: process-wide aggregate (the historical ``spill_stats()`` view) —
#: updated in lockstep with the owning store's per-store dict, and kept
#: separate so traffic survives the (per-odeint-call) store objects.
_AGG: Dict[str, int] = {k: 0 for k in _STAT_KEYS}

#: live stores by id, weakly: stores are per-odeint-call objects, so dead
#: ones drop out of ``per_store_spill_stats()`` while their traffic stays
#: in the aggregate.
_STORES: "weakref.WeakValueDictionary[str, SpillStore]" = \
    weakref.WeakValueDictionary()
_STORE_IDS = itertools.count()


def reset_spill_stats() -> None:
    """Zero the aggregate and every live store's counters atomically (a
    callback running mid-reset sees either all-old or all-new)."""
    with _STATS_LOCK:
        for k in _STAT_KEYS:
            _AGG[k] = 0
        for st in list(_STORES.values()):
            for k in _STAT_KEYS:
                st.stats[k] = 0


def spill_stats() -> Dict[str, int]:
    """Copy of the AGGREGATE spill-store callback counters (every store's
    traffic summed; see ``per_store_spill_stats`` for the breakdown):
    ``*_cb`` counts host round-trips, ``*_slots`` counts checkpoint slots
    moved (so slots/cb is the achieved batching factor), ``*_bytes`` the
    payload traffic."""
    with _STATS_LOCK:
        return dict(_AGG)


def per_store_spill_stats() -> Dict[str, Dict[str, int]]:
    """Counters keyed by ``store_id`` for every live ``SpillStore`` that
    has executed at least one callback since its creation or the last
    reset (all-zero stores are omitted to keep the view readable)."""
    with _STATS_LOCK:
        return {sid: dict(st.stats) for sid, st in sorted(_STORES.items())
                if any(st.stats.values())}


def default_segment(n_steps: int) -> int:
    """Default checkpoint-segment length: ceil(sqrt(n_steps)), the classic
    bandwidth/footprint balance — O(sqrt n) host callbacks per sweep while
    the device-side staging buffer stays O(sqrt n) state vectors (sublinear,
    so spilling still removes the O(n) term from device-live memory)."""
    if n_steps <= 1:
        return 1
    r = int(np.sqrt(n_steps))
    return int(r if r * r >= n_steps else r + 1)


def host_memory_kind() -> Optional[str]:
    """The backend's off-device host memory space, or None if unavailable."""
    try:
        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
    except Exception:  # pragma: no cover - very old jaxlib
        return None
    default = None
    try:
        default = jax.devices()[0].default_memory().kind
    except Exception:  # pragma: no cover
        pass
    for kind in ("pinned_host", "unpinned_host"):
        if kind in kinds and kind != default:
            return kind
    return None


#: degradation ladder: where a tier falls when a fault plan marks it down
_LADDER = {"spill": "disk", "disk": "host", "host": "device"}


def _crc_leaves(arrs) -> int:
    """One crc32 over the concatenated bytes of a slot's leaves."""
    c = 0
    for a in arrs:
        c = zlib.crc32(np.ascontiguousarray(a).tobytes(), c)
    return c


def _slot_salt(slot) -> int:
    """Deterministic int salt for a slot key: ints pass through, the
    lane-keyed tuples (request_id, step) hash via crc32 of their repr —
    stable across processes (unlike ``hash``), so injected corruption
    stays replayable."""
    if isinstance(slot, (int, np.integer)):
        return int(slot)
    return zlib.crc32(repr(slot).encode("utf-8"))


def _cleanup_disk(paths: List[str], root: Optional[str], owned: bool) -> None:
    """weakref.finalize target: delete this store's segment files and, if
    the store created its own tempdir, the directory itself.  Module-level
    (no bound self) so the finalizer does not keep the store alive."""
    for p in paths:
        try:
            os.unlink(p)
        except OSError:
            pass
    if owned and root:
        shutil.rmtree(root, ignore_errors=True)


def _shutdown_exec(ex) -> None:
    """weakref.finalize target for the prefetch executor."""
    ex.shutdown(wait=False)


def effective_tier(tier: Optional[str], fault_plan=None, *,
                   scanned: bool = False, obs=None) -> Optional[str]:
    """Walk the degradation ladder (spill -> disk -> host -> device) past
    tiers a ``FaultPlan`` marks unavailable (``FaultSpec("tier.<t>", 0,
    "down")``).  Returns the first available tier; each hop is recorded as
    a ``store.degrade`` obs event when a recorder is given.
    ``scanned=True`` says the caller is a scanned segment-batched sweep,
    which cannot use the slot-addressed host tier — a downed disk tier
    then degrades straight to device (disk itself is scanned-capable, so
    spill -> disk holds for scanned sweeps too)."""
    if fault_plan is None or tier in (None, "device"):
        return tier
    cur = tier
    while cur not in (None, "device") and fault_plan.tier_disabled(cur):
        nxt = "device" if (scanned and cur == "disk") else _LADDER[cur]
        if obs is not None:
            obs.record("store.degrade", requested=tier, from_tier=cur,
                       to_tier=nxt, scanned=bool(scanned))
        cur = nxt
    return cur


def make_store(tier: Optional[str], *, fault_plan=None,
               integrity: bool = False, max_retries: int = 3,
               retry_backoff_s: float = 1e-3,
               snaps_in_ram: Optional[int] = None,
               disk_dir: Optional[str] = None) -> "CheckpointStore":
    """Build a store for ``tier``.  The resilience knobs apply to the
    spill/disk tiers only (the others have no host round-trips to
    protect): ``fault_plan`` arms the injection hooks inside the
    callbacks, ``integrity`` turns on per-slot crc32 checksums (required
    by ``prefetch_checked``), ``max_retries``/``retry_backoff_s`` bound
    the read retry loop.  ``snaps_in_ram`` caps the RAM-resident slot
    count of a ``spill`` store (overflow sinks to disk files; the
    dolfin-adjoint multistage split — ``make_store("disk")`` is the
    ``snaps_in_ram=0`` corner) and ``disk_dir`` pins the segment files to
    a caller-owned directory (stale files from dead runs are swept;
    default is a self-cleaning tempdir).  ``store.requested_tier`` always
    records what the caller asked for, even after a ladder degrade
    upstream."""
    if tier in (None, "device"):
        st: CheckpointStore = DeviceStore()
    elif tier == "host":
        st = HostStore()
    elif tier in ("spill", "disk"):
        sp = DiskStore() if tier == "disk" else SpillStore()
        sp.fault_plan = fault_plan
        sp.integrity = bool(integrity)
        sp.max_retries = int(max_retries)
        sp.retry_backoff_s = float(retry_backoff_s)
        if tier == "spill" and snaps_in_ram is not None:
            sp.snaps_in_ram = int(snaps_in_ram)
        if disk_dir is not None:
            sp.set_disk_dir(disk_dir)
        st = sp
    else:
        raise ValueError(f"unknown offload tier {tier!r}; one of {TIERS}")
    st.requested_tier = tier
    return st


class CheckpointStore:
    """Common interface; concrete tiers override the transfer points.

    Forward sweep:   put(slot, tree)* -> pack() returned as residuals.
    Reverse sweep:   unpack(res, slots); then get/put/free in any order the
    schedule demands (bwd puts come from revolve "advance" actions).
    Scanned sweeps:  token = init_token(); token = write_at(token, i, tree)
    or token = write_batch(token, base, stacked); token, stacked =
    prefetch(token, base, seg) — token must ride the scan carry and cross
    fwd->bwd through the residuals.
    """

    tier = "device"

    def __init__(self):
        self._vals: Dict[int, PyTree] = {}
        self._order: List[int] = []
        self.effective_tier = self.tier
        self.requested_tier = self.tier
        self.store_id = f"{self.tier}-{next(_STORE_IDS)}"
        self._obs = None

    def bind_obs(self, recorder) -> None:
        """Attach a ``repro.obs.FlightRecorder``.  Device/host tiers
        record trace-time ``store.put``/``store.get``/``store.free``
        events (the schedule — once per compilation); the spill tier
        additionally records runtime ``spill.*`` events from inside its
        host callbacks (once per execution)."""
        self._obs = recorder

    def _note(self, kind: str, slot, tree: PyTree = None) -> None:
        if self._obs is None:
            return
        self._obs.record(kind, store=self.store_id,
                         tier=self.effective_tier, slot=slot,
                         bytes=_tree_nbytes(tree) if tree is not None else 0)

    # -- slot-addressed (trace-time revolve schedule) ----------------------
    def put(self, slot: int, tree: PyTree) -> None:
        self._note("store.put", slot, tree)
        if slot not in self._vals:
            self._order.append(slot)
        self._vals[slot] = self._to_store(tree)

    def get(self, slot: int) -> PyTree:
        self._note("store.get", slot, self._vals[slot])
        return self._from_store(self._vals[slot])

    def free(self, slot: int) -> None:
        self._note("store.free", slot)
        self._vals.pop(slot, None)

    def pack(self) -> PyTree:
        """Residual pytree carrying the forward sweep's checkpoints (in put
        order — the slot keys themselves are trace-time ints the reverse
        rule recomputes and passes back to ``unpack``)."""
        return tuple(self._vals[s] for s in self._order)

    def unpack(self, res: PyTree, slots) -> None:
        self._vals = dict(zip(slots, res))
        self._order = list(slots)

    # -- index-addressed (scanned writes with a traced index) --------------
    def init_token(self):
        return jnp.zeros((), jnp.float32)

    def write_at(self, token, idx, tree: PyTree, keep=None):
        raise NotImplementedError(
            f"offload tier {self.tier!r} does not support scanned "
            "(traced-index) checkpoint writes; use 'spill' or 'disk'")

    # -- segment-batched (one callback per checkpoint segment) -------------
    def write_batch(self, token, base, tree: PyTree):
        raise NotImplementedError(
            f"offload tier {self.tier!r} does not support segment-batched "
            "checkpoint writes; use 'spill' or 'disk'")

    def prefetch(self, token, base, seg: int):
        raise NotImplementedError(
            f"offload tier {self.tier!r} does not support segment "
            "prefetch; use 'spill' or 'disk'")

    def prefetch_issue(self, token, base, seg: int):
        """Async-dispatch hook; a no-op on tiers without host I/O."""
        return token

    # -- transfer points ----------------------------------------------------
    def _to_store(self, tree: PyTree) -> PyTree:
        return tree

    def _from_store(self, tree: PyTree) -> PyTree:
        return tree


class DeviceStore(CheckpointStore):
    tier = "device"


class HostStore(CheckpointStore):
    """Pinned-host residuals via memory-kind transfer (degrades to device)."""

    tier = "host"

    def __init__(self):
        super().__init__()
        self._kind = host_memory_kind()
        self.effective_tier = "host" if self._kind else "device"

    def _transfer(self, tree: PyTree, kind: str) -> PyTree:
        try:
            from jax._src.sharding_impls import TransferToMemoryKind
        except ImportError:  # pragma: no cover - newer jax moved it
            from jax.sharding import TransferToMemoryKind  # type: ignore
        return jtu.tree_map(
            lambda x: jax.device_put(x, TransferToMemoryKind(kind)), tree)

    def _to_store(self, tree: PyTree) -> PyTree:
        if self._kind is None:
            return tree
        return self._transfer(tree, self._kind)

    def _from_store(self, tree: PyTree) -> PyTree:
        if self._kind is None:
            return tree
        return self._transfer(tree, "device")


class SpillStore(CheckpointStore):
    """Host-side spill through token-threaded pure_callback, with slot
    payloads split between a RAM dict and disk segment files.

    The store object itself is a static (nondiff) argument of the
    ``custom_vjp`` that uses it, so the same instance — and the same host
    state — is visible to both the fwd and bwd rules.  Leaf shape/dtype
    metadata is recorded at put-trace time (object attributes persist from
    the fwd trace to the bwd trace) so reads know their result shapes.

    ``snaps_in_ram`` governs the RAM/disk routing (see module docstring);
    all host-side slot state (``_host``, ``_disk`` index, file-slot
    refcounts, the read cache) is guarded by ``_io_lock`` because the
    background prefetch executor gathers concurrently with XLA's callback
    threads.
    """

    tier = "spill"

    def __init__(self):
        super().__init__()
        self._host: Dict[Any, List[np.ndarray]] = {}
        self._meta: Dict[Any, Tuple[Any, Tuple[jax.ShapeDtypeStruct, ...]]] = {}
        self._tok = None
        self.effective_tier = self.tier
        #: per-store callback counters (see module docstring); mutation
        #: holds _STATS_LOCK and mirrors into the _AGG view
        self.stats: Dict[str, int] = {k: 0 for k in _STAT_KEYS}
        _STORES[self.store_id] = self
        #: vmap payload multiplier for the chunking decision — set by the
        #: odeint entry point via ``batch_scale(...)`` (mapped axes are
        #: invisible by the time write_batch/prefetch are traced; see
        #: ``batch_scale``).
        self.payload_scale = 1
        #: per-request lane keys (serving; see module docstring): a tuple
        #: with one request id per leading mapped batch lane (None =
        #: padding lane, stores nothing).  Consulted at callback
        #: EXECUTION time — mutate between executions to re-key the same
        #: compiled program for a new batch composition.
        self.lane_keys: Optional[Tuple[Any, ...]] = None
        #: resilience knobs (see ``make_store``); all dormant by default —
        #: with fault_plan=None and integrity=False the callbacks execute
        #: the exact pre-PR-8 byte sequence
        self.fault_plan = None
        self.integrity = False
        self.max_retries = 3
        self.retry_backoff_s = 1e-3
        #: per-slot crc32 over the CLEAN payload, recorded at write time
        #: when ``integrity`` is on (host-side dict like ``_host``)
        self._sums: Dict[int, int] = {}
        #: RAM/disk split: at most ``snaps_in_ram`` slots in ``_host``
        #: (None = unlimited — the historical all-RAM store)
        self.snaps_in_ram: Optional[int] = None
        self._ram_bytes = 0
        self._disk_dir: Optional[str] = None
        self._disk_dir_owned = False
        self._disk: Dict[int, str] = {}            # slot -> segment file
        self._file_slots: Dict[str, set] = {}      # file -> live slots
        self._created: List[str] = []              # files we own (finalizer)
        self._read_cache: Tuple[Optional[str], Optional[dict]] = (None, None)
        self._file_seq = itertools.count()
        self.swept_files = 0
        #: serializes host-side slot-state access between XLA callback
        #: threads and the background prefetch executor
        self._io_lock = threading.RLock()
        self._exec = None
        self._inflight: Dict[int, Any] = {}        # chunk base -> Future

    # -- disk backend (host-side; callers hold no lock, these take it) ------
    def set_disk_dir(self, path: str) -> None:
        """Pin disk-tier segment files to a caller-owned directory.  Any
        stale ``repro_spill_*.npz`` left by a dead run is swept (counted
        in ``self.swept_files``); this store's own files are still removed
        at GC, but the directory itself is left alone."""
        os.makedirs(path, exist_ok=True)
        swept = 0
        for p in glob.glob(os.path.join(path, _DISK_PREFIX + "*.npz")):
            try:
                os.unlink(p)
                swept += 1
            except OSError:  # pragma: no cover - races with external rm
                pass
        self.swept_files = swept
        self._disk_dir = path
        self._disk_dir_owned = False
        weakref.finalize(self, _cleanup_disk, self._created, path, False)

    def _disk_root(self) -> str:
        if self._disk_dir is None:
            self._disk_dir = tempfile.mkdtemp(prefix="repro-spill-")
            self._disk_dir_owned = True
            weakref.finalize(self, _cleanup_disk, self._created,
                             self._disk_dir, True)
        return self._disk_dir

    def _host_insert(self, slot, leaves) -> None:
        # under _io_lock
        old = self._host.get(slot)
        if old is not None:
            self._ram_bytes -= sum(a.nbytes for a in old)
        self._host[slot] = leaves
        self._ram_bytes += sum(a.nbytes for a in leaves)
        with _STATS_LOCK:
            if self._ram_bytes > self.stats["ram_bytes_peak"]:
                self.stats["ram_bytes_peak"] = self._ram_bytes
            if self._ram_bytes > _AGG["ram_bytes_peak"]:
                _AGG["ram_bytes_peak"] = self._ram_bytes

    def _drop_slot(self, slot) -> None:
        """Remove every copy of ``slot`` (RAM and disk); deletes a segment
        file once its last live slot is dropped."""
        with self._io_lock:
            old = self._host.pop(slot, None)
            if old is not None:
                self._ram_bytes -= sum(a.nbytes for a in old)
            path = self._disk.pop(slot, None)
            if path is not None:
                live = self._file_slots.get(path)
                if live is not None:
                    live.discard(slot)
                    if not live:
                        self._file_slots.pop(path, None)
                        if self._read_cache[0] == path:
                            self._read_cache = (None, None)
                        try:
                            os.unlink(path)
                        except OSError:  # pragma: no cover
                            pass

    def _ram_has_room(self, slots) -> bool:
        # under _io_lock
        if self.snaps_in_ram is None:
            return True
        projected = len(self._host) + sum(1 for s in slots
                                          if s not in self._host)
        return projected <= self.snaps_in_ram

    def _disk_write_rows(self, rows: Dict[int, List[np.ndarray]]) -> int:
        # under _io_lock; one savez extent per write batch, no pickle
        path = os.path.join(
            self._disk_root(),
            f"{_DISK_PREFIX}{self.store_id}_{next(self._file_seq)}.npz")
        payload = {f"s{slot}_l{k}": a
                   for slot, leaves in rows.items()
                   for k, a in enumerate(leaves)}
        np.savez(path, **payload)
        self._created.append(path)
        self._file_slots[path] = set(rows)
        for slot in rows:
            # a rewrite supersedes any prior copy in either medium
            self._drop_slot(slot)
            self._disk[slot] = path
            self._file_slots[path].add(slot)
        return sum(a.nbytes for leaves in rows.values() for a in leaves)

    def _store_rows(self, rows: Dict[int, List[np.ndarray]]
                    ) -> Tuple[str, int]:
        """Route a batch of slots to RAM or disk per ``snaps_in_ram``.
        Returns ``(medium, disk_bytes)`` for counters/obs."""
        if not rows:
            return "ram", 0
        with self._io_lock:
            if self._ram_has_room(rows):
                for slot, leaves in rows.items():
                    if slot in self._disk:
                        self._drop_slot(slot)
                    self._host_insert(slot, leaves)
                return "ram", 0
            dbytes = self._disk_write_rows(rows)
        with _STATS_LOCK:
            self.stats["disk_write_bytes"] += dbytes
            _AGG["disk_write_bytes"] += dbytes
        return "disk", dbytes

    def _disk_read_slot(self, slot):
        # under _io_lock; one-file cache matches the segment-aligned
        # access pattern (a prefetch chunk was written as one file)
        path = self._disk.get(slot)
        if path is None:
            return None
        cpath, cdata = self._read_cache
        if cpath != path:
            with np.load(path) as z:
                cdata = {k: z[k] for k in z.files}
            self._read_cache = (path, cdata)
        leaves, k = [], 0
        while f"s{slot}_l{k}" in cdata:
            leaves.append(cdata[f"s{slot}_l{k}"])
            k += 1
        return leaves or None

    def _slot_read_any(self, slot):
        """One slot's leaves from whichever medium holds it (None if
        missing).  Second element reports disk bytes moved."""
        with self._io_lock:
            leaves = self._host.get(slot)
            if leaves is not None:
                return leaves, 0
            leaves = self._disk_read_slot(slot)
            if leaves is None:
                return None, 0
            return leaves, sum(a.nbytes for a in leaves)

    def _gather_rows(self, base: int, seg: int):
        """Host-side bulk read of ``seg`` consecutive slots (missing ->
        None rows).  Runs on the background executor (via
        ``prefetch_issue``) or synchronously inside the wait callback —
        raw I/O only, no fault ticks, so chaos stays deterministic."""
        rows, dbytes = [], 0
        with self._io_lock:
            for i in range(seg):
                leaves, db = self._slot_read_any(base + i)
                rows.append(leaves)
                dbytes += db
        return rows, dbytes

    @staticmethod
    def _check_lanes(bnd: int, shape, keys) -> None:
        """lane_keys requires exactly ONE mapped axis whose size matches
        the key tuple — anything else is a serving-engine wiring bug."""
        if bnd != 1:
            raise ValueError(
                f"lane_keys requires exactly one mapped batch axis, got "
                f"{bnd} (nest the request batch as the single vmapped "
                "axis)")
        if shape[0] != len(keys):
            raise ValueError(
                f"lane_keys has {len(keys)} entries but the mapped batch "
                f"axis has {shape[0]} lanes")

    def _gather_rows_keyed(self, base: int, seg: int, keys):
        """Keyed counterpart of ``_gather_rows``: per-lane rows
        ``[(keys[b], base+i) for i in range(seg)]`` (None rows for
        missing slots and padding lanes)."""
        rows, dbytes = [], 0
        with self._io_lock:
            for rk in keys:
                lane = []
                for i in range(seg):
                    if rk is None:
                        lane.append(None)
                        continue
                    leaves, db = self._slot_read_any((rk, base + i))
                    lane.append(leaves)
                    dbytes += db
                rows.append(lane)
        return rows, dbytes

    def slot_census(self) -> Dict[str, int]:
        """Live slot counts by medium (tests/benchmarks introspection)."""
        with self._io_lock:
            return {"ram": len(self._host), "disk": len(self._disk),
                    "disk_files": len(self._file_slots)}

    def request_slots(self, request_id) -> int:
        """Live lane-keyed slots held for one request (both media)."""
        with self._io_lock:
            return sum(1 for k in set(self._host) | set(self._disk)
                       if isinstance(k, tuple) and k[0] == request_id)

    def free_request(self, request_id) -> int:
        """Drop every lane-keyed checkpoint slot of a departed request
        (both media; segment files are deleted once their last live slot
        goes).  Host-side and NOT token-ordered: the serving engine calls
        it between executions, never while a solve that still needs the
        slots is in flight.  Returns the number of slots dropped."""
        with self._io_lock:
            victims = [k for k in set(self._host) | set(self._disk)
                       if isinstance(k, tuple) and k[0] == request_id]
        for k in victims:
            self._drop_slot(k)
            self._sums.pop(k, None)
        if victims:
            self._tally_counter("free_cb")
        if self._obs is not None:
            self._obs.record("spill.free_request", _runtime=True,
                             store=self.store_id, request=request_id,
                             slots=len(victims))
        return len(victims)

    def _ensure_exec(self):
        if self._exec is None:
            from concurrent.futures import ThreadPoolExecutor
            self._exec = ThreadPoolExecutor(
                max_workers=1,
                thread_name_prefix=f"spill-prefetch-{self.store_id}")
            weakref.finalize(self, _shutdown_exec, self._exec)
        return self._exec

    # -- resilience helpers (host-side, called from the callbacks) -----------
    def _tally_counter(self, key: str, n: int = 1) -> None:
        with _STATS_LOCK:
            self.stats[key] += n
            _AGG[key] += n

    def _apply_write_fault(self, spec, slot: int, arrs):
        """Apply a ticked ``spill.write`` fault to one slot's payload:
        ``drop`` loses it in transit (returns None, nothing stored),
        ``corrupt`` returns deterministically flipped bytes.  Checksums
        are recorded over the clean payload BEFORE this runs — the
        corruption-at-rest model the read-side verify detects (on the
        disk tier the flipped bytes land in the segment file)."""
        if spec is None:
            return arrs
        if spec.kind == "drop":
            self._drop_slot(slot)
            return None
        if spec.kind == "corrupt":
            return self.fault_plan.corrupt_arrays(arrs, salt=_slot_salt(slot))
        return arrs

    def _read_attempt_ok(self, base: int) -> bool:
        """One logical read, retried with exponential backoff while the
        fault plan flakes it.  Every attempt ticks ``spill.read`` (so a
        spec's ``count`` window spans retries: transient faults are
        escaped by retrying, persistent ones exhaust the budget).
        Returns False only when ``max_retries`` retries all flaked."""
        if self.fault_plan is None:
            return True
        for attempt in range(self.max_retries + 1):
            spec = self.fault_plan.tick("spill.read")
            if spec is None or spec.kind != "flake":
                return True
            if attempt == self.max_retries:
                return False
            self._tally_counter("retry_cb")
            if self._obs is not None:
                self._obs.record("spill.retry", _runtime=True,
                                 store=self.store_id, base=base,
                                 attempt=attempt + 1)
            time.sleep(self.retry_backoff_s * (2 ** attempt))
        return False

    def _leaves_intact(self, slot: int, leaves) -> bool:
        """Present and (when integrity is on) matching the write-time
        checksum.  A slot written before integrity was enabled has no
        recorded sum and passes (nothing to verify against)."""
        if leaves is None:
            return False
        if not self.integrity:
            return True
        want = self._sums.get(slot)
        return want is None or _crc_leaves(leaves) == want

    def _slot_intact(self, slot: int) -> bool:
        leaves, _ = self._slot_read_any(slot)
        return self._leaves_intact(slot, leaves)

    # -- counting + obs (host-side, called from the callbacks) --------------
    def _tally(self, direction: str, *, slots: int, nbytes: int, base,
               medium: str = "ram", disk_bytes: int = 0):
        """Bump this store's counters and the aggregate in lockstep (under
        the module lock — see module docstring), then record an obs event
        if a recorder is bound.  Runs on XLA's callback thread."""
        with _STATS_LOCK:
            if direction == "free":
                self.stats["free_cb"] += 1
                _AGG["free_cb"] += 1
            else:
                keys = [(f"{direction}_cb", 1),
                        (f"{direction}_slots", slots),
                        (f"{direction}_bytes", nbytes)]
                if direction == "read" and disk_bytes:
                    keys.append(("disk_read_bytes", disk_bytes))
                for key, n in keys:
                    self.stats[key] += n
                    _AGG[key] += n
        if self._obs is not None:
            self._obs.record(f"spill.{direction}", _runtime=True,
                             store=self.store_id, base=base,
                             slots=slots, bytes=nbytes, medium=medium)

    # -- host-side callbacks (never traced) ---------------------------------
    def _cb_write(self, token, slot, *leaves):
        with host_annotation("spill/write"):
            spec = (self.fault_plan.tick("spill.write")
                    if self.fault_plan is not None else None)
            arrs = [np.asarray(x).copy() for x in leaves]
            if self.integrity:
                self._sums[int(slot)] = _crc_leaves(arrs)
            arrs = self._apply_write_fault(spec, int(slot), arrs)
            medium = "ram"
            if arrs is not None:
                medium, _ = self._store_rows({int(slot): arrs})
            self._tally("write", slots=1,
                        nbytes=sum(np.asarray(x).nbytes for x in leaves),
                        base=int(slot), medium=medium)
        return np.float32(0)

    def _cb_write_if(self, token, slot, keep, *leaves):
        with host_annotation("spill/write"):
            spec = (self.fault_plan.tick("spill.write")
                    if self.fault_plan is not None else None)
            if bool(keep):
                arrs = [np.asarray(x).copy() for x in leaves]
                if self.integrity:
                    self._sums[int(slot)] = _crc_leaves(arrs)
                arrs = self._apply_write_fault(spec, int(slot), arrs)
                medium = "ram"
                if arrs is not None:
                    medium, _ = self._store_rows({int(slot): arrs})
                self._tally("write", slots=1,
                            nbytes=sum(np.asarray(x).nbytes for x in leaves),
                            base=int(slot), medium=medium)
            else:  # masked out: the round-trip still happened
                self._tally("write", slots=0, nbytes=0, base=int(slot))
        return np.float32(0)

    def _cb_read(self):
        def read(token, slot):
            with host_annotation("spill/read"):
                if not self._read_attempt_ok(int(slot)):
                    # the slot-addressed schedule has no recompute
                    # fallback; a persistent read failure is fatal here
                    raise RuntimeError(
                        f"spill store: read of slot {int(slot)} still "
                        f"failing after {self.max_retries} retries")
                leaves, dbytes = self._slot_read_any(int(slot))
                if leaves is None:
                    # a schedule bug or a reordered free — fail loudly
                    # rather than silently contributing zero gradients
                    raise KeyError(f"spill store: slot {int(slot)} read "
                                   "before it was written (or after free)")
                if not self._leaves_intact(int(slot), leaves):
                    self._tally_counter("integrity_fail")
                    raise RuntimeError(
                        f"spill store: slot {int(slot)} failed its "
                        "integrity check (checksum mismatch) and the "
                        "slot-addressed path has no recompute fallback")
                arrs = tuple(np.asarray(x) for x in leaves)
                self._tally("read", slots=1,
                            nbytes=sum(a.nbytes for a in arrs),
                            base=int(slot),
                            medium="disk" if dbytes else "ram",
                            disk_bytes=dbytes)
                return (np.float32(0),) + arrs
        return read

    def _cb_free(self, token, slot):
        with host_annotation("spill/free"):
            self._drop_slot(int(slot))
            self._tally("free", slots=1, nbytes=0, base=int(slot))
        return np.float32(0)

    def _cb_write_batch(self, token, base, *stacked):
        """ONE host round-trip storing seg consecutive slots (leaves arrive
        stacked on the segment axis).

        Batch-aware: under ``vmap`` (``vmap_method="broadcast_all"``) every
        argument arrives broadcast to the full batch shape — the token's
        ndim IS the number of mapped axes (its logical shape is scalar), so
        the segment axis sits at ``np.ndim(token)`` and each slot stores
        the whole batch block ``arr[..., i, :]``.  One callback serves the
        entire batch and batch elements never alias: element b's
        checkpoints live at index b of its slot's block (the
        per-batch-element key scheme).

        With ``lane_keys`` set the batch block is instead split into
        per-lane rows keyed ``(lane_keys[b], base + i)`` — same bytes,
        request-addressable slots (padding lanes store nothing)."""
        with host_annotation("spill/write_batch"):
            spec = (self.fault_plan.tick("spill.write")
                    if self.fault_plan is not None else None)
            bnd = np.ndim(token)
            seg = int(np.shape(stacked[0])[bnd])
            base = int(np.ravel(base)[0])  # broadcast copies are identical
            arrs = [np.asarray(x) for x in stacked]
            keys = self.lane_keys
            rows: Dict[Any, List[np.ndarray]] = {}
            if keys is not None:
                self._check_lanes(bnd, np.shape(arrs[0]), keys)
                for b, rk in enumerate(keys):
                    if rk is None:  # padding lane: nothing stored
                        continue
                    for i in range(seg):
                        key = (rk, base + i)
                        slot_arrs = [np.asarray(a[b, i]).copy()
                                     for a in arrs]
                        if self.integrity:
                            self._sums[key] = _crc_leaves(slot_arrs)
                        slot_arrs = self._apply_write_fault(spec, key,
                                                            slot_arrs)
                        if slot_arrs is not None:
                            rows[key] = slot_arrs
            else:
                sl = (slice(None),) * bnd
                for i in range(seg):
                    slot_arrs = [a[sl + (i,)].copy() for a in arrs]
                    if self.integrity:
                        self._sums[base + i] = _crc_leaves(slot_arrs)
                    slot_arrs = self._apply_write_fault(spec, base + i,
                                                        slot_arrs)
                    if slot_arrs is not None:
                        rows[base + i] = slot_arrs
            medium, _ = self._store_rows(rows)
            self._tally("write", slots=seg,
                        nbytes=sum(a.nbytes for a in arrs), base=base,
                        medium=medium)
        return np.zeros(np.shape(token), np.float32)

    def _cb_dispatch(self, seg, m):
        """Token-only callback: SUBMIT the gather of ``[base, base+seg)``
        (in the same slot-aligned chunks the wait will use) to the
        background executor and return.  Raw I/O only — faults, integrity,
        and retries stay in the synchronous wait callback."""
        def dispatch(token, base):
            with host_annotation("spill/dispatch"):
                base = int(np.ravel(base)[0])
                ex = self._ensure_exec()
                keys = self.lane_keys  # snapshot: stable per execution
                for o in range(0, seg, m):
                    b = base + o
                    if keys is not None:
                        self._inflight[b] = ex.submit(
                            self._gather_rows_keyed, b, min(m, seg - o),
                            keys)
                    else:
                        self._inflight[b] = ex.submit(
                            self._gather_rows, b, min(m, seg - o))
                self._tally_counter("dispatch_cb")
                if self._obs is not None:
                    self._obs.record("spill.dispatch", _runtime=True,
                                     store=self.store_id, base=base,
                                     slots=seg)
            return np.zeros(np.shape(token), np.float32)
        return dispatch

    def _cb_prefetch(self, seg, checked=False):
        def fetch(token, base):
            with host_annotation("spill/prefetch"):
                _, sds = self._meta["idx"]
                bshape = np.shape(token)  # mapped axes (see _cb_write_batch)
                bnd = len(bshape)
                base = int(np.ravel(base)[0])
                sl = (slice(None),) * bnd
                ok = True
                if not self._read_attempt_ok(base):
                    if not checked:
                        raise RuntimeError(
                            f"spill store: prefetch at base {base} still "
                            f"failing after {self.max_retries} retries and "
                            "this path has no recompute fallback")
                    ok = False  # checked caller recomputes the segment
                # consume a background gather staged by prefetch_issue, if
                # one is in flight for this chunk; fall back to reading
                # storage synchronously (also on background I/O errors —
                # the sync path then surfaces them deterministically)
                keys = self.lane_keys
                if keys is not None:
                    self._check_lanes(len(bshape), bshape, keys)
                rows, dbytes, hit = None, 0, False
                fut = self._inflight.pop(base, None)
                if fut is not None:
                    try:
                        rows, dbytes = fut.result()
                        hit = True
                    except Exception:  # pragma: no cover - backend I/O race
                        rows = None
                if rows is None:
                    rows, dbytes = (
                        self._gather_rows_keyed(base, seg, keys)
                        if keys is not None
                        else self._gather_rows(base, seg))
                if hit:
                    self._tally_counter("prefetch_hit_cb")
                out = []
                for k, s in enumerate(sds):
                    stack = np.zeros(bshape + (seg,) + tuple(s.shape),
                                     s.dtype)
                    if ok:
                        if keys is not None:
                            # per-lane keyed rows (padding lanes -> zeros)
                            for b in range(len(keys)):
                                for i in range(seg):
                                    if rows[b][i] is not None:
                                        stack[b, i] = rows[b][i][k]
                        else:
                            for i in range(seg):
                                if rows[i] is not None:  # missing -> zeros
                                    stack[sl + (i,)] = rows[i][k]
                    out.append(stack)
                if checked and ok:
                    if keys is not None:
                        for b, rk in enumerate(keys):
                            if rk is None:  # padding: legitimately absent
                                continue
                            for i in range(seg):
                                if self._leaves_intact((rk, base + i),
                                                       rows[b][i]):
                                    continue
                                ok = False
                                self._tally_counter("integrity_fail")
                                if self._obs is not None:
                                    self._obs.record(
                                        "spill.integrity", _runtime=True,
                                        store=self.store_id,
                                        slot=[rk, base + i], base=base)
                    else:
                        for i in range(seg):
                            if not self._leaves_intact(base + i, rows[i]):
                                ok = False
                                self._tally_counter("integrity_fail")
                                if self._obs is not None:
                                    self._obs.record(
                                        "spill.integrity", _runtime=True,
                                        store=self.store_id, slot=base + i,
                                        base=base)
                self._tally("read", slots=seg,
                            nbytes=sum(a.nbytes for a in out), base=base,
                            medium=("disk" if dbytes else "ram") if ok
                            else "ram",
                            disk_bytes=dbytes)
                res = (np.zeros(bshape, np.float32),)
                if checked:
                    res = res + (np.full(bshape, ok, bool),)
                return res + tuple(out)
        return fetch

    # -- metadata ------------------------------------------------------------
    def _record(self, key, tree: PyTree):
        leaves, treedef = jtu.tree_flatten(tree)
        sds = tuple(jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))
                    for x in leaves)
        self._meta[key] = (treedef, sds)
        return leaves

    def _per_slot_chunk(self, sds, seg: int) -> int:
        per_slot = max((int(np.prod(s.shape, dtype=np.int64))
                        * np.dtype(s.dtype).itemsize)
                       for s in sds) * self.payload_scale if sds else 0
        return _chunk_slots(seg, per_slot)

    # -- slot-addressed ------------------------------------------------------
    def put(self, slot: int, tree: PyTree) -> None:
        if self._tok is None:
            self._tok = self.init_token()
        leaves = self._record("slot", tree)
        self._tok = jax.pure_callback(
            self._cb_write, _TOKEN_SDS, self._tok, np.int32(slot), *leaves)

    def get(self, slot: int) -> PyTree:
        # reads also return a fresh token that subsequent free/put calls
        # consume: without that anti-dependency edge the scheduler could
        # legally run a free (or an overwriting put) before the read
        treedef, sds = self._meta["slot"]
        out = jax.pure_callback(
            self._cb_read(), (_TOKEN_SDS,) + sds,
            self._tok, np.int32(slot))
        self._tok = out[0]
        return jtu.tree_unflatten(treedef, out[1:])

    def free(self, slot: int) -> None:
        self._tok = jax.pure_callback(
            self._cb_free, _TOKEN_SDS, self._tok, np.int32(slot))

    def pack(self) -> PyTree:
        return self._tok

    def unpack(self, res: PyTree, slots) -> None:
        self._tok = res

    # -- index-addressed -----------------------------------------------------
    def write_at(self, token, idx, tree: PyTree, keep=None):
        leaves = self._record("idx", tree)
        if keep is None:
            return jax.pure_callback(
                self._cb_write, _TOKEN_SDS, token, idx, *leaves)
        return jax.pure_callback(
            self._cb_write_if, _TOKEN_SDS, token, idx, keep, *leaves)

    # -- segment-batched -----------------------------------------------------
    def write_batch(self, token, base, tree: PyTree):
        """Store slots ``[base, base+seg)`` in one callback per
        payload-capped chunk (one total in the common case).  ``tree``
        leaves carry the segment on axis 0 (``seg`` = the static leading
        dim, as stacked by a per-segment inner scan); ``base`` may be
        traced.  Returns a fresh ordering token."""
        leaves, treedef = jtu.tree_flatten(tree)
        # record PER-SLOT metadata (axis 0 stripped) under the same "idx"
        # key the adaptive write_at path records, so prefetch interoperates
        # with either write path
        sds = tuple(jax.ShapeDtypeStruct(tuple(jnp.shape(x)[1:]),
                                         jnp.result_type(x))
                    for x in leaves)
        self._meta["idx"] = (treedef, sds)
        seg = int(jnp.shape(leaves[0])[0]) if leaves else 1
        m = self._per_slot_chunk(sds, seg)
        tok = token
        for o in range(0, seg, m):
            chunk = [x[o:o + m] for x in leaves]
            tok = jax.pure_callback(self._cb_write_batch, _TOKEN_SDS, tok,
                                    base + o, *chunk,
                                    vmap_method="broadcast_all")
        return tok

    def prefetch_issue(self, token, base, seg: int):
        """Dispatch the host-side gather of slots ``[base, base+seg)``
        onto the store's background executor: ONE token-only callback that
        returns as soon as the work is queued, so the read of the next
        segment overlaps this segment's compute.  The matching
        ``prefetch``/``prefetch_checked`` at the same base consumes the
        staged rows.  Ordering rides the usual token chain — issue before
        wait, frees after the wait (the wait blocks on the background
        future, so a post-wait free cannot overtake the read)."""
        if "idx" not in self._meta:
            return token  # nothing written yet; the wait will read cold
        _, sds = self._meta["idx"]
        m = self._per_slot_chunk(sds, seg)
        return jax.pure_callback(self._cb_dispatch(seg, m), _TOKEN_SDS,
                                 token, base, vmap_method="broadcast_all")

    def prefetch(self, token, base, seg: int):
        """Fetch slots ``[base, base+seg)`` stacked on axis 0 in one
        callback per payload-capped chunk — one total in the common case
        (missing slots read as zeros — the reverse sweeps cond-skip or
        mask them).  Returns ``(token, tree)``; the fresh token orders any
        later frees/overwrites after this read.  When a ``prefetch_issue``
        for the same base is in flight its staged rows are consumed
        instead of re-reading storage (``prefetch_hit_cb``) — the
        double-buffered path; without an issue this is a synchronous
        read."""
        treedef, sds = self._meta["idx"]
        m = self._per_slot_chunk(sds, seg)
        tok, pieces = token, []
        for o in range(0, seg, m):
            mm = min(m, seg - o)
            out_sds = (_TOKEN_SDS,) + tuple(
                jax.ShapeDtypeStruct((mm,) + tuple(s.shape), s.dtype)
                for s in sds)
            out = jax.pure_callback(self._cb_prefetch(mm), out_sds, tok,
                                    base + o, vmap_method="broadcast_all")
            tok = out[0]
            pieces.append(out[1:])
        if len(pieces) == 1:
            stacked = pieces[0]
        else:
            stacked = [jnp.concatenate(ps, axis=0) for ps in zip(*pieces)]
        return tok, jtu.tree_unflatten(treedef, stacked)

    def prefetch_checked(self, token, base, seg: int):
        """``prefetch`` plus an integrity verdict: returns ``(token, ok,
        tree)`` where ``ok`` (a traced bool) is True only if every slot in
        ``[base, base+seg)`` was present, passed its crc32 (recorded at
        write time; requires the store built with ``integrity=True``), and
        the host read did not exhaust its retry budget.  On ``ok=False``
        the returned tree is whatever could be read (zeros on total
        failure) — callers must ``lax.cond`` on ``ok`` into a recompute
        fallback rather than consume it.  Chunked exactly like
        ``prefetch``; the chunk verdicts AND together."""
        treedef, sds = self._meta["idx"]
        m = self._per_slot_chunk(sds, seg)
        ok_sds = jax.ShapeDtypeStruct((), jnp.bool_)
        tok, ok, pieces = token, None, []
        for o in range(0, seg, m):
            mm = min(m, seg - o)
            out_sds = (_TOKEN_SDS, ok_sds) + tuple(
                jax.ShapeDtypeStruct((mm,) + tuple(s.shape), s.dtype)
                for s in sds)
            out = jax.pure_callback(self._cb_prefetch(mm, checked=True),
                                    out_sds, tok, base + o,
                                    vmap_method="broadcast_all")
            tok = out[0]
            ok = out[1] if ok is None else jnp.logical_and(ok, out[1])
            pieces.append(out[2:])
        if len(pieces) == 1:
            stacked = pieces[0]
        else:
            stacked = [jnp.concatenate(ps, axis=0) for ps in zip(*pieces)]
        return tok, ok, jtu.tree_unflatten(treedef, stacked)


class DiskStore(SpillStore):
    """All-disk spill: the ``snaps_in_ram=0`` corner of ``SpillStore`` as
    its own tier, so planners/validators can name it.  Same callbacks,
    token contract, integrity/retry behavior — slot payloads live in
    ``repro_spill_*.npz`` segment files instead of the RAM dict."""

    tier = "disk"

    def __init__(self):
        super().__init__()
        self.snaps_in_ram = 0
        self.effective_tier = "disk"
