"""repro.mem — adjoint memory planning and checkpoint offload.

The paper's contribution is a *tunable* memory/recompute trade (Table 2,
Prop. 2); this package makes the tuning automatic:

  model    analytic per-policy cost model (peak bytes, extra f-evals) and
           the measurement machinery that grounds it in lowered HLO;
  planner  ``plan_odeint`` — solve for the cheapest reverse-accurate policy
           under a byte budget (drives ``odeint(adjoint="auto",
           mem_budget=...)``) and ``plan_depth_remat`` for the LM stack;
  offload  device / pinned-host / host-spill checkpoint stores the adjoint
           write paths go through (``odeint(..., offload=...)``).
"""
from repro.mem.model import (CostEstimate, f_activation_bytes,
                             max_fitting_ncheck, measure_reverse_cost,
                             policy_cost, spill_callback_counts, tree_bytes)
from repro.mem.offload import (CheckpointStore, DeviceStore, HostStore,
                               SpillStore, default_segment,
                               host_memory_kind, make_store,
                               per_store_spill_stats, reset_spill_stats,
                               spill_stats)
from repro.mem.planner import (CandidateDecision, Plan, candidate_costs,
                               plan_depth_remat, plan_odeint)

__all__ = [
    "CostEstimate", "policy_cost", "tree_bytes", "f_activation_bytes",
    "max_fitting_ncheck", "measure_reverse_cost", "spill_callback_counts",
    "CheckpointStore", "DeviceStore", "HostStore", "SpillStore",
    "make_store", "host_memory_kind", "default_segment",
    "reset_spill_stats", "spill_stats", "per_store_spill_stats",
    "CandidateDecision", "Plan", "plan_odeint", "candidate_costs",
    "plan_depth_remat",
]
