"""Analytic per-policy memory/compute cost model (the paper's Table 2).

Maps every adjoint policy to (peak live bytes, extra reverse-pass f
evaluations) as a function of N_t (steps), the tableau's stage counts, the
state size, and — for revolve — N_c (checkpoint slots):

  policy      ckpt storage (bytes)                 NFE-B (extra f evals)
  naive       N_t * N_s * A_f    (AD residuals)    0
  continuous  0                                    N_s * N_t   (not rev-acc)
  anode       N_t * N_s * A_f    (recompute+AD)    2 N_s N_t
  aca         N_t * S                              2 N_s N_t
  pnode       N_t * (N_s+1) * S                    N_s^a N_t
  pnode2      N_t * S                              (N_s + N_s^a) N_t
  revolve     (N_c+1) * (N_s+1) * S                N_s p~(N_t,N_c) + N_s^a N_t
  revolve2    (N_c+1+seg*(N_s+1)) * S              ~N_s (N_t-N_c) + N_s^a N_t

with S = state bytes, N_s^a = stages the discrete adjoint linearizes
(``adjoint_stages``), p~ the Prop-2 recompute optimum, and A_f the bytes of
AD residuals one f evaluation leaves behind (``f_activation_bytes`` — the
N_l-dependent term that makes NODE-naive the steepest curve in Fig. 3).
An ``offload`` tier moves the ckpt-storage term off device (see
``repro.mem.offload``); it never changes NFE-B.

Off-device storage is itself two-tiered (the dolfin-adjoint multistage
split): ``snaps_in_ram`` caps how many checkpoint slots stay RAM-resident,
the overflow sinks to segment files on disk (``offload="disk"`` is the
all-disk corner).  ``CostEstimate`` prices the split with per-tier byte
columns (``ram_bytes``/``disk_bytes``) and a modeled transfer time
(``io_seconds`` — one fwd write + one bwd read of every slot at the
tier's bandwidth, plus per-callback latency), so the planner can solve
the ``snaps_in_ram`` split under separate RAM and disk byte budgets and
rank tiers by I/O cost where NFE-B ties.

Implicit theta-methods (``method="beuler"|"cn"``) dispatch to their own
Table-2 column (``core.implicit``): a checkpoint slot is ONE converged
state (S bytes — the Newton/GMRES iterates never enter the graph), the
reverse-step working set is dominated by the transposed-GMRES Krylov basis
(``gmres_iters`` state vectors), NFE-B counts f *linearizations*
(``implicit_adjoint_fevals`` per step) and a recomputed step costs a full
Newton solve (``implicit_step_fevals`` = newton_iters*(gmres_iters+2)+1
f evaluations) — which is why revolve checkpoint spacing is cheap in
memory but expensive in recompute for stiff solves, and the planner's
ranking by extra_fevals handles both families uniformly.

The model is validated against measured byte counts of the lowered reverse
pass (``launch/hlo_cost.peak_live_bytes`` on the compiled HLO) in
tests/test_mem.py, and ``measure_reverse_cost`` here is the measurement
used by both the planner's verify step and the fig3/mem_plan benchmarks.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.core import revolve as revolve_mod
from repro.core.adjoint import (adjoint_stages, checkpoint_floats,
                                nfe_backward)
from repro.core.implicit import (IMPLICIT_POLICIES, implicit_adjoint_fevals,
                                 implicit_checkpoint_floats,
                                 implicit_nfe_backward, implicit_step_fevals,
                                 is_implicit_method)
from repro.core.tableaus import get_tableau

PyTree = Any

#: policies whose gradients are exact reorderings of the naive chain rule
REVERSE_ACCURATE = ("naive", "anode", "aca", "pnode", "pnode2", "revolve",
                    "revolve2")

#: modeled off-device transfer rates: host-RAM copies (pinned-host /
#: callback-dict) vs segment-file disk I/O, plus the fixed cost of one
#: host callback round-trip.  Coarse XLA:CPU figures — the planner uses
#: the RAM:disk *ratio* to price the snaps_in_ram split, so absolute
#: calibration is not load-bearing (measured peaks gate the budget, not
#: these).
HOST_COPY_BW = 8e9       # bytes/s
DISK_BW = 500e6          # bytes/s
CALLBACK_LATENCY_S = 50e-6


def slot_bytes(method: str, state_bytes: int) -> int:
    """Bytes of ONE checkpoint slot: (N_s+1)*S for explicit tableaus
    (state + staged k_i), S for implicit methods (converged states only).
    The unit of the ``snaps_in_ram`` RAM/disk split."""
    if is_implicit_method(method):
        return int(state_bytes)
    return (get_tableau(method).num_stages + 1) * int(state_bytes)


def _offload_io(offload: Optional[str], ckpt_bytes: int, callbacks: int,
                method: str, state_bytes: int,
                snaps_in_ram: Optional[int]) -> Tuple[int, int, float]:
    """(ram_bytes, disk_bytes, io_seconds) of one fwd+bwd round trip: the
    off-device checkpoint set split across the RAM/disk media, each byte
    written once and read once at its tier's bandwidth."""
    if offload not in ("host", "spill", "disk") or ckpt_bytes <= 0:
        return 0, 0, 0.0
    if offload == "disk":
        ram, disk = 0, int(ckpt_bytes)
    elif offload == "spill" and snaps_in_ram is not None:
        sb = max(1, slot_bytes(method, state_bytes))
        ram = min(int(ckpt_bytes), int(snaps_in_ram) * sb)
        disk = int(ckpt_bytes) - ram
    else:  # host, or spill with unlimited RAM
        ram, disk = int(ckpt_bytes), 0
    io = 2.0 * (ram / HOST_COPY_BW + disk / DISK_BW) \
        + callbacks * CALLBACK_LATENCY_S
    return ram, disk, io


def tree_bytes(tree: PyTree) -> int:
    """Total bytes of a pytree of (possibly abstract) arrays."""
    total = 0
    for leaf in jtu.tree_leaves(tree):
        size = getattr(leaf, "size", None)
        dtype = getattr(leaf, "dtype", None)
        if size is None or dtype is None:
            leaf = jnp.asarray(leaf)
            size, dtype = leaf.size, leaf.dtype
        total += int(size) * jnp.dtype(dtype).itemsize
    return total


def f_activation_bytes(f: Callable, u0: PyTree, theta: PyTree,
                       t: float = 0.0) -> int:
    """AD-residual bytes one ``f`` evaluation leaves behind: the summed
    output bytes of every equation in f's jaxpr — the O(N_l) depth term
    that naive/anode pay per stage and the high-level adjoint avoids."""
    try:
        jaxpr = jax.make_jaxpr(lambda u, th: f(u, th, t))(u0, theta)
    except Exception:
        return tree_bytes(u0)
    total = 0
    for eqn in jaxpr.jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                n = 1
                for d in aval.shape:
                    n *= int(d)
                total += n * jnp.dtype(aval.dtype).itemsize
    return max(total, tree_bytes(u0))


@dataclass(frozen=True)
class CostEstimate:
    """One Table-2 row instantiated at concrete sizes."""
    policy: str
    ncheck: Optional[int]
    offload: Optional[str]
    ckpt_bytes: int        # checkpoint storage between fwd and bwd sweeps
    work_bytes: int        # transient working set of one reverse step
    extra_fevals: int      # NFE-B: reverse-pass f evaluations
    reverse_accurate: bool
    host_callbacks: int = 0  # host round-trips per reverse pass (spill tier)
    ram_bytes: int = 0       # off-device ckpt bytes resident in host RAM
    disk_bytes: int = 0      # off-device ckpt bytes sunk to segment files
    io_seconds: float = 0.0  # modeled fwd-write + bwd-read transfer time

    @property
    def peak_bytes(self) -> int:
        """Predicted device-live peak: offloaded ckpt storage leaves the
        device, everything else stays (including, for the spill tiers, the
        segment staging buffer folded into work_bytes)."""
        if self.offload in ("host", "spill", "disk"):
            return self.work_bytes
        return self.ckpt_bytes + self.work_bytes


def spill_callback_counts(policy: str, n_steps: int, *,
                          ncheck: Optional[int] = None,
                          segment: Optional[int] = None) -> Dict[str, int]:
    """Host callbacks one reverse pass issues on the spill tier (the
    batched-I/O reality the planner ranks against; BENCH_3 measures it).

    pnode's scanned sweeps batch ``segment`` checkpoints per callback
    (fwd ``write_batch`` + bwd ``prefetch``); the revolve policies are
    slot-addressed at trace time and already pay one callback per
    checkpoint-schedule action (puts/gets/frees).
    """
    from repro.core import revolve as revolve_mod  # late: import cycle
    from repro.mem.offload import default_segment
    if policy == "pnode":
        seg = min(segment or default_segment(n_steps), n_steps)
        n_segments = -(-n_steps // seg)
        return {"forward": n_segments, "backward": n_segments,
                "total": 2 * n_segments}
    if policy == "revolve":
        fwd = ncheck + 1  # one put per sweep checkpoint
        bwd = 0
        for act in revolve_mod.reverse_schedule(n_steps, ncheck):
            bwd += {"advance": 2, "adjoint": 2, "free": 1}[act[0]]
        return {"forward": fwd, "backward": bwd, "total": fwd + bwd}
    if policy == "revolve2":
        from repro.core.adjoint import _segment_bounds
        nb = len(_segment_bounds(n_steps, ncheck))
        return {"forward": nb, "backward": 2 * nb, "total": 3 * nb}
    return {"forward": 0, "backward": 0, "total": 0}


#: state copies one implicit reverse step keeps in flight beyond the
#: transposed-GMRES Krylov basis (lam, lam_s, u_n, u_next)
_IMPLICIT_WORK_STATES = 4


def _implicit_policy_cost(policy: str, *, n_steps: int, state_bytes: int,
                          theta_bytes: int, ncheck: Optional[int],
                          offload: Optional[str], segment: Optional[int],
                          newton_iters: int, gmres_iters: int,
                          snaps_in_ram: Optional[int] = None,
                          method: str = "cn") -> CostEstimate:
    """Implicit-family Table-2 row: checkpoints are converged states only
    (S bytes/slot), work is Krylov-basis dominated, recompute is Newton
    solves (see module docstring)."""
    if policy not in IMPLICIT_POLICIES:
        raise ValueError(
            f"policy {policy!r} is not available for implicit methods; "
            f"one of {IMPLICIT_POLICIES} (AD-through-the-solver policies "
            "have no reverse rule for the Newton/GMRES while_loops)")
    work = (int(gmres_iters) + _IMPLICIT_WORK_STATES) * state_bytes \
        + 3 * theta_bytes
    ckpt = implicit_checkpoint_floats(n_steps, policy, state_bytes,
                                      ncheck=ncheck)
    extra = implicit_nfe_backward(n_steps, policy, ncheck=ncheck,
                                  newton_iters=newton_iters,
                                  gmres_iters=gmres_iters)
    callbacks = 0
    if offload in ("spill", "disk"):
        callbacks = spill_callback_counts(policy, n_steps, ncheck=ncheck,
                                          segment=segment)["total"]
        if policy == "pnode":
            # segment staging buffer (states only — no stages to stage)
            from repro.mem.offload import default_segment
            seg = min(segment or default_segment(n_steps), n_steps)
            work += seg * state_bytes
    ram, disk, io = _offload_io(offload, int(ckpt), callbacks, method,
                                state_bytes, snaps_in_ram)
    return CostEstimate(policy=policy, ncheck=ncheck, offload=offload,
                        ckpt_bytes=int(ckpt), work_bytes=int(work),
                        extra_fevals=int(extra), reverse_accurate=True,
                        host_callbacks=int(callbacks), ram_bytes=ram,
                        disk_bytes=disk, io_seconds=io)


def policy_cost(policy: str, *, method: str, n_steps: int, state_bytes: int,
                theta_bytes: int = 0, f_act_bytes: Optional[int] = None,
                ncheck: Optional[int] = None,
                offload: Optional[str] = None,
                segment: Optional[int] = None,
                newton_iters: int = 10,
                gmres_iters: int = 20,
                snaps_in_ram: Optional[int] = None) -> CostEstimate:
    """Analytic (peak bytes, extra f-evals) for one policy instance.
    ``newton_iters``/``gmres_iters`` only affect implicit methods;
    ``snaps_in_ram`` prices the spill tier's RAM/disk slot split
    (``ram_bytes``/``disk_bytes``/``io_seconds`` columns)."""
    if is_implicit_method(method):
        return _implicit_policy_cost(policy, n_steps=n_steps,
                                     state_bytes=state_bytes,
                                     theta_bytes=theta_bytes, ncheck=ncheck,
                                     offload=offload, segment=segment,
                                     newton_iters=newton_iters,
                                     gmres_iters=gmres_iters,
                                     snaps_in_ram=snaps_in_ram,
                                     method=method)
    tab = get_tableau(method)
    s = tab.num_stages
    fa = f_act_bytes if f_act_bytes is not None else state_bytes
    # one step's stages + a few state copies in flight + grad accumulators
    work = (s + 3) * state_bytes + 3 * theta_bytes

    if policy in ("naive", "anode"):
        # AD through the (re)computed forward: every stage's f residuals
        ckpt = n_steps * s * fa
        if policy == "anode":
            ckpt += state_bytes  # the block-input checkpoint itself
    elif policy == "continuous":
        ckpt = 0
    else:
        ckpt = checkpoint_floats(method, n_steps, policy,
                                 state_bytes, ncheck=ncheck)
    extra = nfe_backward(method, n_steps, policy,
                         ncheck=ncheck) if policy != "naive" else 0
    callbacks = 0
    if offload in ("spill", "disk"):
        callbacks = spill_callback_counts(policy, n_steps, ncheck=ncheck,
                                          segment=segment)["total"]
        if policy == "pnode":
            # segment staging buffer: the batched sweeps hold one segment
            # of (state, stages) checkpoints on device between callbacks
            from repro.mem.offload import default_segment
            seg = min(segment or default_segment(n_steps), n_steps)
            work += seg * (s + 1) * state_bytes
    ram, disk, io = _offload_io(offload, int(ckpt), callbacks, method,
                                state_bytes, snaps_in_ram)
    return CostEstimate(policy=policy, ncheck=ncheck, offload=offload,
                        ckpt_bytes=int(ckpt), work_bytes=int(work),
                        extra_fevals=int(extra),
                        reverse_accurate=policy in REVERSE_ACCURATE,
                        host_callbacks=int(callbacks), ram_bytes=ram,
                        disk_bytes=disk, io_seconds=io)


def max_fitting_ncheck(budget: int, *, method: str, n_steps: int,
                       state_bytes: int, theta_bytes: int = 0,
                       newton_iters: int = 10,
                       gmres_iters: int = 20) -> Optional[int]:
    """Largest N_c whose revolve checkpoint set fits the byte budget
    (Table-2 storage (N_c+1)(N_s+1)S explicit, (N_c+1)S implicit — only
    converged states are stored), clamped to the valid [1, N_t-1] range;
    None if even N_c = 1 does not fit."""
    probe = policy_cost("revolve", method=method, n_steps=n_steps,
                        state_bytes=state_bytes, theta_bytes=theta_bytes,
                        ncheck=1, newton_iters=newton_iters,
                        gmres_iters=gmres_iters)
    avail = budget - probe.work_bytes
    if is_implicit_method(method):
        per_slot = state_bytes
    else:
        per_slot = (get_tableau(method).num_stages + 1) * state_bytes
    if per_slot <= 0:
        return n_steps - 1
    k = avail // per_slot - 1
    if k < 1:
        return None
    return int(min(k, n_steps - 1))


# ---------------------------------------------------------------------------
# measurement: the model's ground truth
# ---------------------------------------------------------------------------

_MEASURE_CACHE: Dict[Tuple, Dict[str, float]] = {}


def _struct_key(tree: PyTree) -> Tuple:
    leaves, treedef = jtu.tree_flatten(tree)
    return (str(treedef),) + tuple(
        (tuple(jnp.shape(x)), str(jnp.result_type(x))) for x in leaves)


def measure_reverse_cost(f: Callable, u0: PyTree, theta: PyTree, *,
                         dt: float, n_steps: int, t0: float = 0.0,
                         method: str = "rk4", policy: str = "pnode",
                         ncheck: Optional[int] = None,
                         offload: Optional[str] = None,
                         loss_fn: Optional[Callable] = None,
                         solver_opts: Optional[Dict[str, Any]] = None
                         ) -> Dict[str, float]:
    """Lower + compile the reverse pass (grad of a scalar loss of the
    solve) and measure its peak bytes two ways:

      hlo_peak_bytes  liveness sweep over the optimized HLO text
                      (``launch.hlo_cost.peak_live_bytes``) — the metric the
                      planner's budget check and the acceptance tests use;
      temp_bytes /    XLA's own compiled buffer-assignment accounting
      argument_bytes  (``compiled.memory_analysis()``), kept as a
                      cross-check column in the benchmarks.

    ``loss_fn(u_final) -> scalar`` measures the reverse pass of the
    CALLER'S loss (the planner forwards it from ``plan_odeint``) so the
    budget check sees the real training objective's working set; the
    default is the canonical sum-of-squares surrogate.

    ``solver_opts`` (newton_iters/newton_tol/gmres_iters/gmres_tol) is
    forwarded to ``odeint_implicit`` for implicit methods — the measured
    reverse pass uses the caller's actual solver configuration (the Krylov
    basis scales with gmres_iters), and the opts are part of the cache key.

    Results are cached on (f identity, loss_fn identity, arg structure,
    solve configuration): a planner verify step compiles each candidate at
    most once per session.
    """
    from repro.core.adjoint import odeint  # late: avoid import cycle
    from repro.launch.hlo_cost import peak_live_bytes

    opts_key = None if solver_opts is None else \
        tuple(sorted(solver_opts.items()))
    key = (id(f), None if loss_fn is None else id(loss_fn), _struct_key(u0),
           _struct_key(theta), float(dt), int(n_steps), float(t0), method,
           policy, ncheck, offload, opts_key,
           bool(jax.config.jax_enable_x64))
    hit = _MEASURE_CACHE.get(key)
    if hit is not None:
        return hit[1]

    def loss(u0_, th_):
        if is_implicit_method(method):
            from repro.core.implicit import odeint_implicit
            uf = odeint_implicit(f, u0_, th_, dt=dt, n_steps=n_steps, t0=t0,
                                 method=method, adjoint=policy,
                                 ncheck=ncheck, offload=offload,
                                 **(solver_opts or {}))
        else:
            uf = odeint(f, u0_, th_, dt=dt, n_steps=n_steps, t0=t0,
                        method=method, adjoint=policy, ncheck=ncheck,
                        offload=offload)
        if loss_fn is not None:
            return loss_fn(uf)
        return sum(jnp.sum(x * x) for x in jtu.tree_leaves(uf))

    grad_fn = jax.grad(loss, argnums=(0, 1))
    compiled = jax.jit(grad_fn).lower(u0, theta).compile()
    mem = compiled.memory_analysis()
    out = {
        "hlo_peak_bytes": float(peak_live_bytes(compiled.as_text())),
        "temp_bytes": float(getattr(mem, "temp_size_in_bytes", -1.0))
        if mem is not None else -1.0,
        "argument_bytes": float(getattr(mem, "argument_size_in_bytes", -1.0))
        if mem is not None else -1.0,
    }
    # the entry keeps strong references to f / loss_fn: id() keys would
    # otherwise be reusable after garbage collection and alias different
    # functions
    _MEASURE_CACHE[key] = ((f, loss_fn), out)
    return out
