"""Production mesh construction (v5e pod: 16x16 = 256 chips; multi-pod adds
a leading 'pod' axis).  A function — importing this module never touches jax
device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline analysis
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW_PER_LINK = 50e9       # B/s per link
