import os
# default to a pod's worth of fake host devices for the production-mesh CLI,
# but never stomp a caller that already forced its own device count (other
# XLA_FLAGS, e.g. --xla_dump_to, are preserved and the count appended)
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512"
                               ).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes, prove memory fits, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod]
  ... --accum 8 --remat sqrt --seq-shard   (hillclimb knobs)

Results are cached as JSON under experiments/dryrun/<mesh>/<arch>__<shape>*.json.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax import tree_util as jtu
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, cell_runnable, get_arch, get_shape
from repro.data.pipeline import SyntheticLM
from repro.dist import sharding as shd
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models import lm
from repro.obs import MetricsSink, StructuredLogger
from repro.optim.adamw import AdamW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in (post-SPMD) HLO text."""
    out = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for c in _COLLECTIVES:
            # result-typed ops look like:  %x = f32[..]{..} all-gather(...)
            if f" {c}(" in ls or f" {c}-start(" in ls:
                lhs = ls.split(f" {c}")[0]
                out[c] += _shape_bytes(lhs)
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _shaped(tree):
    return jtu.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_cell(arch: str, shape: str, mesh, accum: int = 1,
               remat: str | None = None, attn_impl: str | None = None):
    """Returns (fn, arg_shapes, in_shardings, kind)."""
    cfg = get_arch(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    cell = get_shape(shape)

    params_shape = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = shd.param_specs(cfg, params_shape, mesh)
    pshard = shd.to_shardings(pspecs, mesh)

    if cell.kind == "train":
        opt = AdamW(total_steps=1000)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = shd.opt_state_specs(pspecs, opt_shape)
        oshard = shd.to_shardings(ospecs, mesh)
        pipe = SyntheticLM(cfg, cell)
        batch_shape = jax.eval_shape(pipe.batch, jnp.zeros((), jnp.int32))
        bspecs = shd.batch_specs(cfg, cell, mesh)
        bshard = jtu.tree_map(lambda s: NamedSharding(mesh, s), bspecs,
                              is_leaf=lambda x: isinstance(x, P))
        step_fn = make_train_step(cfg, opt, accum=accum)
        args = (params_shape, opt_shape, batch_shape,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (pshard, oshard, bshard, NamedSharding(mesh, P()))
        return step_fn, args, in_sh, cfg, cell

    if cell.kind == "prefill":
        pipe = SyntheticLM(cfg, cell)
        batch_shape = jax.eval_shape(pipe.batch, jnp.zeros((), jnp.int32))
        batch_shape = {k: v for k, v in batch_shape.items() if k != "targets"}
        bspecs = {k: v for k, v in
                  shd.batch_specs(cfg, cell, mesh).items()
                  if k in batch_shape}
        bshard = jtu.tree_map(lambda s: NamedSharding(mesh, s), bspecs,
                              is_leaf=lambda x: isinstance(x, P))
        step_fn = make_prefill_step(cfg, max_seq=cell.seq_len)
        return step_fn, (params_shape, batch_shape), (pshard, bshard), cfg, cell

    # decode
    bsz = cell.global_batch
    state_shape = jax.eval_shape(
        lambda: lm.init_decode_state(cfg, bsz, cell.seq_len))
    sspecs = shd.decode_state_specs(cfg, cell, state_shape, mesh)
    sshard = shd.to_shardings(sspecs, mesh)
    ba = shd.batch_axes(mesh)
    bspec = ba if ba and bsz % max(
        1, int(jnp.prod(jnp.array([mesh.shape[a] for a in ba])))) == 0 else None
    token_shape = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
    tshard = NamedSharding(mesh, P(bspec, None))
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    step_fn = make_decode_step(cfg)
    return (step_fn, (params_shape, state_shape, token_shape, pos_shape),
            (pshard, sshard, tshard, NamedSharding(mesh, P())), cfg, cell)


def model_flops(cfg, cell, accum=1) -> float:
    """Useful-work FLOPs: 6ND (2ND inference) for parameter matmuls PLUS
    the attention score/value matmuls (2*2*B*S*ctx*H*dh fwd), which 6ND
    ignores but which dominate small-d_model archs at 4k+ context.  Causal
    global attention uses ctx = S/2; sliding-window layers use ctx = w;
    decode uses ctx = cache length.  SSM ('w') layers add the chunked
    linear-attention state matmuls ~6*B*S*H*dh^2.  RG-LRU ('r') recurrences
    are elementwise (negligible)."""
    n_active = cfg.active_param_count()
    b, s = cell.global_batch, cell.seq_len
    tokens = b * (s if cell.kind != "decode" else 1)
    mult = 3.0 if cell.kind == "train" else 1.0
    flops = (2.0 * mult) * n_active * tokens

    h, dh = (cfg.n_heads or 0), cfg.dh
    for kind, win in zip(cfg.kinds, cfg.win):
        if kind == "a" and h:
            if cell.kind == "decode":
                ctx = min(win, s) if win else s
                flops += mult * 4.0 * b * ctx * h * dh
            else:
                ctx = min(win, s) if win else s / 2.0
                flops += mult * 4.0 * b * s * ctx * h * dh
        elif kind == "w":
            nh = cfg.n_heads or (cfg.d_model // 64)
            dhw = cfg.d_model // nh
            per_tok = 6.0 * nh * dhw * dhw
            flops += mult * per_tok * tokens
    if cfg.family == "encdec" and cfg.enc_seq:
        # encoder self-attention (bidirectional) + decoder cross-attention
        se = cfg.enc_seq
        flops += mult * cfg.n_enc_layers * 4.0 * b * se * se * h * dh
        q = s if cell.kind != "decode" else 1
        flops += mult * cfg.n_layers * 4.0 * b * q * se * h * dh
    return flops


def run_cell(arch: str, shape: str, multi_pod: bool, accum: int = 1,
             remat: str | None = None, attn_impl: str | None = None,
             out_dir: str = "experiments/dryrun", force: bool = False,
             tag: str = "") -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    out_path = Path(out_dir) / mesh_name / f"{arch}__{shape}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    ok, reason = cell_runnable(arch, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "accum": accum,
           "remat": remat, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.size
        t0 = time.time()
        fn, args, in_sh, cfg, cell = build_cell(arch, shape, mesh, accum,
                                                remat, attn_impl)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        # trip-count-aware accounting (compiled.cost_analysis() counts every
        # lax.scan body ONCE — see launch/hlo_cost.py); all numbers are
        # per-partition (the SPMD module is per-device)
        acc = hlo_analyze(hlo)
        coll = {k: v for k, v in acc.collective_bytes.items()}
        coll["total"] = acc.collective_total
        flops = acc.flops
        bytes_acc = acc.bytes
        t_compute = flops / PEAK_FLOPS_BF16
        t_memory = bytes_acc / HBM_BW
        # ~4 usable ICI links per v5e chip on a 2D torus (x2 dirs x2 axes)
        t_coll = coll["total"] / (4 * ICI_BW_PER_LINK)
        mflops = model_flops(cfg, cell, accum)
        rec.update(
            status="ok",
            n_chips=n_chips,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=None if mem is None else {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            hlo_flops_per_device=flops,
            hlo_bytes_per_device=bytes_acc,
            collective_bytes_per_device=coll,
            roofline={
                "compute_s": t_compute,
                "memory_s": t_memory,
                "collective_s": t_coll,
                "dominant": max(
                    [("compute", t_compute), ("memory", t_memory),
                     ("collective", t_coll)], key=lambda kv: kv[1])[0],
            },
            model_flops_total=mflops,
            model_flops_per_device=mflops / n_chips,
            useful_flops_ratio=(mflops / n_chips) / max(flops, 1.0),
            params_total=cfg.param_count(),
            params_active=cfg.active_param_count(),
        )
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write one structured JSONL record per cell to "
                         "PATH (repro.obs.MetricsSink)")
    args = ap.parse_args()

    cells = []
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    sink = MetricsSink(args.metrics) if args.metrics else None
    slog = StructuredLogger(sink=sink)
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, args.mesh == "multipod", args.accum,
                           args.remat, args.attn_impl, args.out_dir,
                           args.force, args.tag)
            if rec["status"] == "ok":
                r = rec["roofline"]
                slog.log(
                    "dryrun.cell",
                    f"{arch:26s} {shape:12s} OK  compile={rec['compile_s']:.1f}s "
                    f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                    f"coll={r['collective_s']:.4f}s dom={r['dominant']}",
                    arch=arch, shape=shape, status="ok",
                    compile_s=rec["compile_s"], roofline=r,
                    memory=rec.get("memory"))
            else:
                slog.log("dryrun.cell",
                         f"{arch:26s} {shape:12s} SKIP ({rec['reason'][:60]})",
                         arch=arch, shape=shape, status="skipped",
                         reason=rec["reason"])
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            slog.log("dryrun.cell",
                     f"{arch:26s} {shape:12s} FAIL {type(e).__name__}: {e}",
                     arch=arch, shape=shape, status="fail",
                     error=f"{type(e).__name__}: {e}")
        sys.stdout.flush()
    if sink is not None:
        sink.close()


if __name__ == "__main__":
    main()
