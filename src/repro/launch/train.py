"""End-to-end LM training driver: mesh + sharding + synthetic data + AdamW
+ fault tolerance (watchdog, straggler detection, checkpoint-restart).

Runs any assigned arch (full config on the production mesh via --production,
reduced config on host devices by default so CPU runs finish):

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Deterministic restart: the data pipeline is keyed by step and the checkpoint
carries (params, opt_state, step), so rerunning with the same --ckpt-dir
resumes and replays the exact loss curve (tested in tests/test_ft.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.ckpt import CheckpointManager
from repro.configs.base import ModelConfig, ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.data.pipeline import SyntheticLM
from repro.dist import sharding as shd
from repro.ft import StragglerDetector, TrainSupervisor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import init_compress_state, make_train_step
from repro.models import lm
from repro.obs import MetricsSink, StructuredLogger
from repro.optim.adamw import AdamW


def _compiled_peak_bytes(step_fn, *concrete_args):
    """Best-effort measured peak of the compiled train step
    (``launch.hlo_cost.peak_live_bytes`` — the same metric the byte-budget
    planner verifies against).  None if lowering text is unavailable."""
    try:
        from repro.launch.hlo_cost import peak_live_bytes
        compiled = step_fn.lower(*concrete_args).compile()
        return int(peak_live_bytes(compiled.as_text()))
    except Exception:
        return None


def train(cfg: ModelConfig, cell: ShapeCell, *, steps: int, mesh=None,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          accum: int = 1, lr: float = 3e-4, log_every: int = 10,
          seed: int = 0, grad_dtype: str | None = None,
          compress: str | None = None, log_fn=print,
          sink: MetricsSink | None = None,
          predicted_peak_bytes: int | None = None,
          fault_plan=None, sentinel: bool = True,
          sentinel_bad_steps: int = 3, max_rollbacks: int = 2) -> dict:
    """Returns {"losses": [...], "resumed_from": step|None, ...}.

    ``compress`` wires optim/compress.py gradient compression into the
    production step (flag-gated, default off; see launch/steps.py).

    ``sink`` (a ``repro.obs.MetricsSink``) receives one structured
    ``train.step`` record per step — loss, global grad norm, wall time —
    plus a ``train.compile`` record comparing the compiled step's measured
    peak bytes against ``predicted_peak_bytes`` (the planner's number,
    when a budget was planned); drift beyond 25% is warned through
    ``log_fn`` and flagged in the record.

    Fault tolerance (PR 8).  ``sentinel=True`` (default) builds the step
    with the in-graph non-finite sentinel (launch/steps.py): a step whose
    loss or grads are non-finite — injected or natural — commits nothing,
    and the loop *retries* it (the data pipeline is keyed by step, so the
    retry sees the identical batch; since nothing was committed, a clean
    retry reproduces the fault-free loss bitwise).  After
    ``sentinel_bad_steps`` consecutive bad attempts the loop rolls back to
    the last committed checkpoint and replays (deterministic pipeline =>
    exact replay); after ``max_rollbacks`` rollbacks — or with no
    checkpoint to roll back to — it raises ``FloatingPointError`` instead
    of looping forever on a genuinely divergent run.  SIGTERM requests a
    clean shutdown: the loop finishes the in-flight step, writes a final
    checkpoint, and drains pending ``CheckpointManager`` commits before
    returning (``result["preempted"]`` is True).  ``fault_plan=`` (a
    ``repro.ft.FaultPlan``) drives the chaos harness: site
    ``"train.step"`` kinds ``nan`` (poison that attempt in-graph) and
    ``preempt`` (request shutdown after that step, exercising the same
    drain path as a real SIGTERM).  The loss history is keyed by step, so
    retries and rollback-replays overwrite rather than duplicate:
    ``result["losses"][i]`` is the committed loss of step ``start+i``,
    directly comparable to a fault-free run."""
    mesh = mesh or make_host_mesh()
    slog = StructuredLogger(log_fn=log_fn, sink=sink)
    opt = AdamW(lr=lr, total_steps=max(steps, 2), warmup_steps=min(100, steps // 10 + 1),
                grad_dtype=grad_dtype)
    pipe = SyntheticLM(cfg, cell, seed=seed)

    with mesh:
        params_shape = jax.eval_shape(
            lambda: lm.init_params(cfg, jax.random.PRNGKey(seed)))
        pspecs = shd.param_specs(cfg, params_shape, mesh)
        pshard = shd.to_shardings(pspecs, mesh)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = shd.opt_state_specs(pspecs, opt_shape)
        oshard = shd.to_shardings(ospecs, mesh)

        init_fn = jax.jit(lambda k: lm.init_params(cfg, k),
                          out_shardings=pshard)
        params = init_fn(jax.random.PRNGKey(seed))
        opt_state = jax.jit(opt.init, out_shardings=oshard)(params)
        start_step = 0

        int8 = compress == "int8"
        comp_state = None
        if int8:
            comp_state = jax.jit(
                lambda p: init_compress_state(compress, p),
                out_shardings=pshard)(params)

        def ckpt_tree():
            # the int8 error-feedback residual is training state: dropping
            # it on resume would silently fork the loss trajectory
            tree = {"params": params, "opt_state": opt_state}
            if int8:
                tree["comp_state"] = comp_state
            return tree

        mgr = None
        shardings = {"params": pshard, "opt_state": oshard}
        if int8:
            shardings["comp_state"] = pshard
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir, keep_n=3,
                                    fault_plan=fault_plan)
            latest = mgr.latest_step()
            if latest is not None:
                restored, start_step = mgr.restore_latest(ckpt_tree(),
                                                          shardings)
                params, opt_state = restored["params"], restored["opt_state"]
                if int8:
                    comp_state = restored["comp_state"]
                slog.log("train.resume",
                         f"[train] resumed from step {start_step}",
                         step=start_step)

        extra_in = (None,) if sentinel else ()  # the traced poison flag
        if int8:
            step_fn = jax.jit(
                make_train_step(cfg, opt, accum=accum, compress=compress,
                                sentinel=sentinel),
                in_shardings=(pshard, oshard, pshard, None, None) + extra_in,
                out_shardings=(pshard, oshard, pshard, None),
                donate_argnums=(0, 1, 2))
        else:
            step_fn = jax.jit(
                make_train_step(cfg, opt, accum=accum, compress=compress,
                                sentinel=sentinel),
                in_shardings=(pshard, oshard, None, None) + extra_in,
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1))

        measured_peak = None
        if sink is not None:
            # measure before step 0: donated buffers are gone afterwards
            first = pipe.batch(jnp.int32(start_step))
            cargs = ((params, opt_state, comp_state, first,
                      jnp.int32(start_step)) if int8 else
                     (params, opt_state, first, jnp.int32(start_step)))
            if sentinel:
                cargs = cargs + (False,)
            measured_peak = _compiled_peak_bytes(step_fn, *cargs)
            # a zero/absent prediction (planner skipped, dryrun config)
            # must still log the compile record — with drift=null — not
            # die on the division below
            drift = None
            if measured_peak is not None and predicted_peak_bytes:
                # the planner prices live *activations*; the compiled peak
                # also holds params/opt-state/batch, so fold those in
                from repro.mem.model import tree_bytes
                predicted_peak_bytes = predicted_peak_bytes + tree_bytes(
                    (params, opt_state, first))
                if predicted_peak_bytes > 0:
                    drift = measured_peak / predicted_peak_bytes - 1.0
                if drift is not None and abs(drift) > 0.25:
                    slog.log("train.peak_drift",
                             f"[train] WARNING: measured peak "
                             f"{measured_peak} B is {drift:+.0%} off the "
                             f"planner's {predicted_peak_bytes} B",
                             measured_peak_bytes=measured_peak,
                             predicted_peak_bytes=predicted_peak_bytes,
                             drift=drift)
            slog.metric("train.compile",
                        measured_peak_bytes=measured_peak,
                        predicted_peak_bytes=predicted_peak_bytes,
                        drift=drift)
        detector = StragglerDetector()
        stragglers: list[int] = []
        loss_by_step: dict[int, float] = {}
        skipped = 0
        rollbacks = 0
        consec_bad = 0
        preempted = False
        stop = {"sig": False}
        prev_handler = None
        try:  # SIGTERM = finish the in-flight step, checkpoint, drain
            prev_handler = signal.signal(
                signal.SIGTERM, lambda signum, frame:
                stop.__setitem__("sig", True))
        except ValueError:  # not on the main thread; no handler swap
            prev_handler = None
        try:
            with TrainSupervisor(
                    heartbeat_timeout_s=600.0, straggler=detector,
                    on_straggler=lambda s, dt: stragglers.append(s)) as sup:
                step = start_step
                while step < steps:
                    if stop["sig"]:
                        preempted = True
                        break
                    batch = pipe.batch(jnp.int32(step))
                    poison = False
                    want_preempt = False
                    if fault_plan is not None:
                        spec = fault_plan.tick("train.step")
                        if spec is not None and spec.kind == "nan":
                            poison = sentinel  # the in-graph hook
                        elif spec is not None and spec.kind == "preempt":
                            want_preempt = True
                    holder = {}

                    def do_step():
                        args = ((params, opt_state, comp_state, batch,
                                 jnp.int32(step)) if int8 else
                                (params, opt_state, batch, jnp.int32(step)))
                        if sentinel:
                            args = args + (poison,)
                        if int8:
                            p, o, c, m = step_fn(*args)
                            holder.update(c=c)
                        else:
                            p, o, m = step_fn(*args)
                        jax.block_until_ready(m["loss"])
                        holder.update(p=p, o=o, m=m)

                    dt = sup.step(do_step, step)
                    # the step donates its inputs: always pick up the
                    # returned buffers (on a skipped step they carry the
                    # old values bitwise — the in-graph select)
                    params, opt_state = holder["p"], holder["o"]
                    if int8:
                        comp_state = holder["c"]
                    m = holder["m"]
                    bad = sentinel and bool(m.get("nonfinite", 0))
                    if bad:
                        skipped += 1
                        consec_bad += 1
                        slog.log("train.skip",
                                 f"[train] step {step}: non-finite "
                                 f"loss/grad — update skipped (streak "
                                 f"{consec_bad})", step=step,
                                 streak=consec_bad)
                        if consec_bad >= sentinel_bad_steps:
                            if mgr is None or mgr.latest_step() is None:
                                raise FloatingPointError(
                                    f"training produced non-finite "
                                    f"loss/grads for {consec_bad} "
                                    f"consecutive attempts at step {step} "
                                    "and there is no checkpoint to roll "
                                    "back to")
                            if rollbacks >= max_rollbacks:
                                raise FloatingPointError(
                                    f"training still non-finite at step "
                                    f"{step} after {rollbacks} rollbacks "
                                    "— giving up (deterministic replay "
                                    "reproduces the divergence; this is "
                                    "not a transient)")
                            restored, rstep = mgr.restore_latest(
                                ckpt_tree(), shardings)
                            params = restored["params"]
                            opt_state = restored["opt_state"]
                            if int8:
                                comp_state = restored["comp_state"]
                            rollbacks += 1
                            consec_bad = 0
                            for s in [s for s in loss_by_step if s >= rstep]:
                                del loss_by_step[s]
                            slog.log("train.rollback",
                                     f"[train] rolled back to step {rstep} "
                                     f"after {sentinel_bad_steps} "
                                     f"consecutive bad steps",
                                     step=rstep, rollbacks=rollbacks)
                            step = rstep
                        # else: retry the same step — nothing was
                        # committed, and the pipeline is keyed by step, so
                        # a clean retry reproduces the fault-free loss
                        # bitwise
                        continue
                    consec_bad = 0
                    loss = float(m["loss"])
                    loss_by_step[step] = loss
                    if sink is not None:
                        gn = m.get("grad_norm")
                        slog.metric("train.step", step=step, loss=loss,
                                    grad_norm=(None if gn is None
                                               else float(gn)),
                                    step_ms=dt * 1e3)
                    if step % log_every == 0 or step == steps - 1:
                        log_fn(f"[train] step {step:5d} loss {loss:.4f} "
                               f"({dt*1e3:.0f} ms)")
                    if mgr and (step + 1) % ckpt_every == 0:
                        mgr.save(step + 1, ckpt_tree())
                    step += 1
                    if want_preempt:
                        fault_plan.note("train.preempt", step)
                        preempted = True
                        break
        finally:
            if prev_handler is not None:
                try:
                    signal.signal(signal.SIGTERM, prev_handler)
                except ValueError:
                    pass
        if mgr:
            # `step` is the committed progress (next step to run): the
            # final checkpoint lands there whether the loop completed or a
            # preemption broke out early, and wait() drains every pending
            # async commit before we return
            mgr.save(step, ckpt_tree())
            mgr.wait()
        losses = [loss_by_step[s] for s in sorted(loss_by_step)]
    return {"losses": losses, "resumed_from": start_step or None,
            "stragglers": stragglers, "params": params,
            "skipped_steps": skipped, "rollbacks": rollbacks,
            "preempted": preempted}


def parse_bytes(spec: str) -> int:
    """'512M' / '8G' / '1e9' / '123456' -> bytes."""
    spec = str(spec).strip()
    mult = {"K": 2 ** 10, "M": 2 ** 20, "G": 2 ** 30, "T": 2 ** 40}
    if spec and spec[-1].upper() in mult:
        return int(float(spec[:-1]) * mult[spec[-1].upper()])
    return int(float(spec))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production", action="store_true",
                    help="full config on the 16x16 production mesh "
                         "(requires real devices)")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--grad-dtype", default=None)
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8"],
                    help="gradient wire compression (optim/compress.py)")
    ap.add_argument("--mem-budget", default=None,
                    help="activation-memory budget in bytes (suffixes "
                         "K/M/G); the repro.mem planner picks the depth "
                         "remat policy for it, overriding --remat")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write per-step metrics as JSONL to PATH "
                         "(repro.obs.MetricsSink)")
    ap.add_argument("--no-sentinel", action="store_true",
                    help="disable the in-graph non-finite loss/grad "
                         "sentinel (skip-and-retry of poisoned steps)")
    ap.add_argument("--sentinel-bad-steps", type=int, default=3,
                    metavar="K",
                    help="roll back to the last committed checkpoint "
                         "after K consecutive non-finite steps (default 3)")
    ap.add_argument("--max-rollbacks", type=int, default=2,
                    help="give up (FloatingPointError) after this many "
                         "rollbacks (default 2)")
    args = ap.parse_args()

    full = get_arch(args.arch)
    if args.production:
        cfg, mesh = full, make_production_mesh()
    else:
        cfg, mesh = reduced(full), make_host_mesh()
    if args.remat:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    cell = ShapeCell("cli", args.seq, args.batch, "train")
    sink = MetricsSink(args.metrics) if args.metrics else None
    slog = StructuredLogger(sink=sink)
    predicted = None
    if args.mem_budget is not None:
        from repro.mem.planner import depth_remat_live_bytes, plan_depth_remat
        budget = parse_bytes(args.mem_budget)
        remat, ncheck, fits = plan_depth_remat(cfg, cell, budget)
        predicted = depth_remat_live_bytes(cfg, cell, remat, ncheck)
        slog.log("train.plan",
                 f"[train] mem budget {budget} B -> depth remat={remat!r} "
                 f"ncheck={ncheck} (predicted live {predicted} B)",
                 mem_budget=budget, remat=remat, ncheck=ncheck, fits=fits,
                 predicted_peak_bytes=predicted)
        if not fits:
            slog.log("train.plan_overflow",
                     "[train] WARNING: no depth-checkpointing policy fits "
                     "this budget — proceeding with the minimum-memory "
                     "plan, expect to exceed it", mem_budget=budget)
        cfg = dataclasses.replace(cfg, remat=remat, ncheck=ncheck)
    t0 = time.time()
    out = train(cfg, cell, steps=args.steps, mesh=mesh,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                accum=args.accum, lr=args.lr, grad_dtype=args.grad_dtype,
                compress=None if args.compress == "none" else args.compress,
                sink=sink, predicted_peak_bytes=predicted,
                sentinel=not args.no_sentinel,
                sentinel_bad_steps=args.sentinel_bad_steps,
                max_rollbacks=args.max_rollbacks)
    slog.log("train.done",
             f"[train] done in {time.time()-t0:.1f}s; "
             f"final loss {out['losses'][-1]:.4f}",
             final_loss=out["losses"][-1], stragglers=out["stragglers"])
    if sink is not None:
        sink.close()


if __name__ == "__main__":
    main()
