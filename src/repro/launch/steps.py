"""Step functions (train / prefill / decode) shared by the real launcher and
the dry-run.  Pure functions of (cfg, cell); jit/sharding applied by callers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import lm
from repro.optim import compress as compress_mod
from repro.optim.adamw import AdamW


def global_grad_norm(grads) -> jnp.ndarray:
    """Global L2 norm over every leaf — the per-step gradient-health
    scalar the metrics sink records."""
    leaves = jtu.tree_leaves(grads)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def make_train_step(cfg: ModelConfig, opt: AdamW, accum: int = 1,
                    compress: str | None = None, sentinel: bool = False):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).  accum > 1 scans over microbatches (gradient
    accumulation): live activation memory scales with B/accum.

    ``sentinel=True`` adds the non-finite step sentinel **in-graph** (the
    jitted step donates its params/opt_state buffers, so a host-side
    "check then retry" is impossible — the inputs are gone by the time the
    loss is observable): the step takes an extra traced ``poison`` bool
    (the fault-injection hook; pass False when unused), a poisoned or
    naturally non-finite loss/grad skips the parameter and optimizer
    update via a select (the optimizer count does NOT advance on skipped
    steps), and ``metrics["nonfinite"]`` reports the skip.  With a False
    poison and finite grads the selects are exact pass-throughs — the
    updated params are bitwise the sentinel-off ones.

    ``compress`` applies optim/compress.py wire compression to the grads
    before the optimizer sees them (flag-gated, default off):
      "bf16"  stateless bf16 round-trip — the quantization the cross-pod
              all-reduce wire sees (the dry-run's shard_map path carries
              the same dtype on the wire; under plain GSPMD the implicit
              all-reduce stays fp32 and this reproduces the numerics);
      "int8"  per-leaf symmetric int8 with error feedback — the step gains
              a residual state: signature becomes (params, opt_state,
              comp_state, batch, step) -> (..., comp_state, metrics).
    """
    if compress not in (None, "none", "bf16", "int8"):
        raise ValueError(f"unknown compression scheme {compress!r}; "
                         "one of (None, 'none', 'bf16', 'int8')")
    if compress == "none":
        compress = None
    if compress == "int8" and accum != 1:
        raise NotImplementedError(
            "int8 gradient compression with accum > 1 is not wired "
            "(quantize-per-microbatch would break error feedback)")

    def loss_of(params, batch):
        return lm.loss_fn(cfg, params, batch)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def finite_gate(params, opt_state, new_params, new_opt_state, loss,
                    grads, poison):
        """Select the committed (params, opt_state): the fresh update when
        the step is healthy, the untouched inputs when poisoned or
        non-finite."""
        ok = jnp.logical_and(jnp.isfinite(loss),
                             jnp.logical_not(poison))
        for g in jtu.tree_leaves(grads):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(g)))
        keep = lambda new, old: jtu.tree_map(
            lambda a, b: jnp.where(ok, a, b), new, old)
        return keep(new_params, params), keep(new_opt_state, opt_state), ok

    def _poison_tree(tree, poison):
        return jtu.tree_map(
            lambda x: x + jnp.where(poison, jnp.asarray(jnp.nan, x.dtype),
                                    jnp.asarray(0, x.dtype)), tree)

    def train_step(params, opt_state, batch, step, poison=False):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(b_):
                return jtu.tree_map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), b_)

            micro_batches = micro(batch)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                return (jtu.tree_map(jnp.add, gsum, g), lsum + l), None

            g0 = jtu.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, 0.0),
                                                micro_batches)
            grads = jtu.tree_map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = {}
        if compress == "bf16":
            grads = compress_mod.bf16_decompress(
                compress_mod.bf16_compress(grads))
        if sentinel:
            loss = loss + jnp.where(poison, jnp.asarray(jnp.nan, loss.dtype),
                                    jnp.asarray(0, loss.dtype))
            grads = _poison_tree(grads, poison)
        new_params, new_opt_state, opt_metrics = opt.update(grads, opt_state,
                                                            params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        if "grad_norm" not in metrics:  # AdamW already reports pre-clip norm
            metrics["grad_norm"] = global_grad_norm(grads)
        if sentinel:
            new_params, new_opt_state, ok = finite_gate(
                params, opt_state, new_params, new_opt_state, loss, grads,
                poison)
            metrics["nonfinite"] = jnp.logical_not(ok).astype(jnp.int32)
        return new_params, new_opt_state, metrics

    if compress != "int8":
        return train_step

    def train_step_int8(params, opt_state, comp_state, batch, step,
                        poison=False):
        (loss, metrics), grads = grad_fn(params, batch)
        if sentinel:
            loss = loss + jnp.where(poison, jnp.asarray(jnp.nan, loss.dtype),
                                    jnp.asarray(0, loss.dtype))
            grads = _poison_tree(grads, poison)
        q, new_comp_state = compress_mod.int8_compress(grads, comp_state)
        grads_d = compress_mod.int8_decompress(q)
        new_params, new_opt_state, opt_metrics = opt.update(grads_d,
                                                            opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        if "grad_norm" not in metrics:  # AdamW already reports pre-clip norm
            metrics["grad_norm"] = global_grad_norm(grads_d)
        if sentinel:
            new_params, new_opt_state, ok = finite_gate(
                params, opt_state, new_params, new_opt_state, loss, grads,
                poison)
            # a skipped step must not consume its error-feedback residual
            new_comp_state = jtu.tree_map(
                lambda a, b: jnp.where(ok, a, b), new_comp_state, comp_state)
            metrics["nonfinite"] = jnp.logical_not(ok).astype(jnp.int32)
        return new_params, new_opt_state, new_comp_state, metrics

    return train_step_int8


def init_compress_state(compress: str | None, params):
    """Error-feedback residual state for the chosen scheme (None if
    stateless)."""
    if compress == "int8":
        return compress_mod.int8_init(params)
    return None


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        state, last_logits = lm.prefill(cfg, params, batch, max_seq)
        return state, last_logits

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, state, token, pos):
        logits, state = lm.decode_step(cfg, params, state, token, pos)
        return logits, state

    return decode_step
