"""Batched serving driver: prefill a batch of prompts, then decode tokens
autoregressively with the KV-cache/recurrent decode state.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b \
      --batch 4 --prompt-len 64 --gen 32

Reduced configs on host devices by default (CPU-runnable); the full-config
production path is exercised shape-only by launch/dryrun.py decode cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.data.pipeline import SyntheticLM
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import lm
from repro.obs import MetricsSink, StructuredLogger


def serve(cfg, *, batch: int, prompt_len: int, gen: int, mesh=None,
          temperature: float = 0.0, seed: int = 0, log_fn=print,
          sink: MetricsSink | None = None):
    """Prefill + greedy/temperature decode.  Returns (tokens, stats).

    ``sink`` receives a structured ``serve.done`` record (prefill/decode
    wall time, tokens/s) alongside the human line through ``log_fn``."""
    mesh = mesh or make_host_mesh()
    max_seq = prompt_len + gen
    cell = ShapeCell("serve", prompt_len, batch, "prefill")
    pipe = SyntheticLM(cfg, cell, seed=seed)

    with mesh:
        params = jax.jit(lambda k: lm.init_params(cfg, k))(
            jax.random.PRNGKey(seed))
        prompt = {k: v for k, v in
                  pipe.batch(jnp.zeros((), jnp.int32)).items()
                  if k != "targets"}

        prefill_fn = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
        decode_fn = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

        t0 = time.time()
        state, logits = prefill_fn(params, prompt)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        def sample(key, logits):
            if temperature <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits / temperature, axis=-1).astype(jnp.int32)

        key = jax.random.PRNGKey(seed + 1)
        # decode state position starts where the prompt ended (frontends
        # prepend patches, so use the true prefill length)
        pos0 = prompt_len + (cfg.n_patches if cfg.frontend == "vision_stub"
                             else 0)
        tok = sample(key, logits)[:, None]
        out_tokens = [tok]
        t0 = time.time()
        for i in range(gen - 1):
            key = jax.random.fold_in(key, i)
            logits, state = decode_fn(params, state, tok,
                                      jnp.int32(pos0 + i))
            tok = sample(key, logits)[:, None]
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    tokens = jnp.concatenate(out_tokens, axis=1)
    stats = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }
    StructuredLogger(log_fn=log_fn, sink=sink).log(
        "serve.done",
        f"[serve] prefill {t_prefill*1e3:.0f} ms, "
        f"decode {stats['tok_per_s']:.1f} tok/s",
        batch=batch, prompt_len=prompt_len, gen=gen, **stats)
    return tokens, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write structured serve stats as JSONL to PATH")
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    sink = MetricsSink(args.metrics) if args.metrics else None
    tokens, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                          gen=args.gen, temperature=args.temperature,
                          sink=sink)
    print(f"[serve] generated {tokens.shape} tokens; stats={stats}")
    if sink is not None:
        sink.close()


if __name__ == "__main__":
    main()
