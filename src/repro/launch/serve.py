"""Serving driver: a thin front over ``repro.serve.LMEngine``.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b \
      --batch 4 --prompt-len 64 --gen 32 --replicas 2

The engine owns admission, wave scheduling, prefill/decode interleaving
and the per-call timing log; this driver builds synthetic prompts,
submits them, and turns the engine's ``call_log`` into the ``serve.done``
record.  Accounting (fixed here, previously wrong in two ways): the first
sampled token — produced by prefill — counts toward throughput, and the
first decode call's compile time is reported as *warm-up* instead of
being lumped into the steady-state rate:

  ``warmup_s``          prefill wall + the first (compiling) decode call
  ``steady_s``          every later decode call
  ``tok_per_s_steady``  tokens emitted by post-warm-up decode calls / steady_s
  ``tok_per_s``         ALL tokens (batch * gen, first token included) over
                        the end-to-end wall — the honest user-facing rate

``--replicas N`` runs N model replicas (one ``LMEngine`` each, lanes
split across them, decode state sharded per ``repro.dist``
decode-state specs) and aggregates their stats.

Reduced configs on host devices by default (CPU-runnable); the full-config
production path is exercised shape-only by launch/dryrun.py decode cells.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeCell, reduced
from repro.configs.registry import get_arch
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.obs import MetricsSink, StructuredLogger
from repro.serve import LMEngine


def _stats_from_log(call_log, tokens_total: int) -> dict:
    """Warm-up / steady-state split of an engine ``call_log``."""
    prefill_s = sum(c["wall_s"] for c in call_log if c["op"] == "prefill")
    decode = [c for c in call_log if c["op"] == "decode"]
    decode_s = sum(c["wall_s"] for c in decode)
    warm = [c for c in decode if c.get("compile")]
    steady = [c for c in decode if not c.get("compile")]
    warmup_s = prefill_s + sum(c["wall_s"] for c in warm)
    steady_s = sum(c["wall_s"] for c in steady)
    steady_tok = sum(c["tokens"] for c in steady)
    total_s = prefill_s + decode_s
    return {
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "warmup_s": warmup_s,
        "steady_s": steady_s,
        "tokens": tokens_total,
        "tok_per_s": tokens_total / max(total_s, 1e-9),
        "tok_per_s_steady": steady_tok / max(steady_s, 1e-9),
    }


def serve(cfg, *, batch: int, prompt_len: int, gen: int, mesh=None,
          temperature: float = 0.0, seed: int = 0, log_fn=print,
          sink: MetricsSink | None = None, replicas: int = 1,
          decode_slice: int = 8):
    """Prefill + greedy/temperature decode through the serve engine.
    Returns (tokens ``(batch, gen)``, stats).

    ``sink`` receives a structured ``serve.done`` record (warm-up and
    steady-state split out — see module docstring) alongside the human
    line through ``log_fn``."""
    replicas = max(1, int(replicas))
    if batch % replicas != 0:
        raise ValueError(f"batch {batch} must divide evenly over "
                         f"{replicas} replicas")
    lanes = batch // replicas
    mesh = mesh or make_host_mesh()
    cell = ShapeCell("serve", prompt_len, batch, "prefill")
    pipe = SyntheticLM(cfg, cell, seed=seed)
    prompt = {k: np.asarray(v) for k, v in
              pipe.batch(jnp.zeros((), jnp.int32)).items()
              if k != "targets"}
    extras_keys = [k for k in prompt if k != "tokens"]

    engines = [LMEngine(cfg, lanes=lanes, prompt_len=prompt_len,
                        max_gen=gen, decode_slice=decode_slice,
                        temperature=temperature, seed=seed, mesh=mesh,
                        shard=replicas > 1)
               for _ in range(replicas)]
    tickets = []
    for b in range(batch):
        eng = engines[b % replicas]
        extras = {k: prompt[k][b] for k in extras_keys}
        tickets.append(eng.submit(prompt["tokens"][b], gen=gen,
                                  extras=extras or None))
    for eng in engines:
        eng.run()
    tokens = jnp.asarray(np.stack([t.result(60.0) for t in tickets]))

    merged = [c for eng in engines for c in eng.call_log]
    stats = _stats_from_log(merged, tokens_total=batch * gen)
    stats["replicas"] = replicas
    StructuredLogger(log_fn=log_fn, sink=sink).log(
        "serve.done",
        f"[serve] warm-up {stats['warmup_s']*1e3:.0f} ms, "
        f"steady {stats['tok_per_s_steady']:.1f} tok/s "
        f"({stats['tok_per_s']:.1f} end-to-end)",
        batch=batch, prompt_len=prompt_len, gen=gen, **stats)
    return tokens, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="model replicas (lanes split across them; decode "
                         "state sharded per repro.dist specs)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write structured serve stats as JSONL to PATH")
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    sink = MetricsSink(args.metrics) if args.metrics else None
    tokens, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                          gen=args.gen, temperature=args.temperature,
                          replicas=args.replicas, sink=sink)
    print(f"[serve] generated {tokens.shape} tokens; stats={stats}")
    if sink is not None:
        sink.close()


if __name__ == "__main__":
    main()
