"""Trip-count-aware HLO cost accounting for the roofline analysis.

``compiled.cost_analysis()`` counts every ``while`` body ONCE, but a JAX
``lax.scan`` over 30 transformer layers executes its body 30 times — so the
built-in numbers under-report FLOPs/bytes/collective-bytes of scanned models
by up to the trip count (verified: a scanned 10x matmul reports exactly 1
matmul of FLOPs).  XLA:CPU attaches ``backend_config={"known_trip_count":
{"n": "30"}}`` to while ops, so an exact re-count is possible from the
optimized HLO text.

This module parses the post-optimization HLO and computes, with loop
multipliers applied:

  * flops             — 2*M*N*K for every dot (incl. dots inside fusions),
                        2*out*window for convolutions
  * bytes             — XLA-style per-op "bytes accessed" (operands +
                        results) at fusion granularity (fusion internals are
                        VMEM-resident and excluded, matching how
                        HloCostAnalysis treats fused ops)
  * collective_bytes  — result bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute

Used by launch/dryrun.py; validated against cost_analysis() on loop-free
graphs (equal dot flops) and against trip-count scaling on scanned graphs
in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\d]+?)\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=(%[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([\dx]+)")


def shape_numel_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over every array in a (possibly tuple) type."""
    n_el = n_by = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_el += n
        n_by += n * _DTYPE_BYTES[dt]
    # scalar like "f32[]" -> the regex catches it with empty dims (n=1)
    return n_el, n_by


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        if not raw:
            continue
        if not raw.startswith(" ") and "(" in raw and "->" in raw \
                and raw.rstrip().endswith("{"):
            m = _COMP_HDR.match(raw)
            if not m:
                continue
            name = m.group(2)
            if not name.startswith("%"):
                name = "%" + name
            cur = Computation(name)
            comps[name] = cur
            if m.group(1):
                entry = name
            # params: "a: f32[2,3], b: (s32[], f32[4])" — split carefully
            psrc = m.group(3)
            depth = 0
            part = ""
            parts = []
            for ch in psrc:
                if ch == "," and depth == 0:
                    parts.append(part)
                    part = ""
                    continue
                if ch in "([{":
                    depth += 1
                elif ch in ")]}":
                    depth -= 1
                part += ch
            if part.strip():
                parts.append(part)
            for p in parts:
                if ":" in p:
                    pname, ptype = p.split(":", 1)
                    pname = pname.strip()
                    if not pname.startswith("%"):
                        pname = "%" + pname
                    cur.params[pname] = ptype.strip()
                    cur.symbols[pname] = ptype.strip()
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(raw)
        if not m:
            continue
        name, rtype, kind = m.groups()
        # operands: inside the first (...) after the op kind
        paren = raw.index(kind + "(") + len(kind)
        depth = 0
        i = paren
        end = len(raw)
        for i in range(paren, len(raw)):
            if raw[i] == "(":
                depth += 1
            elif raw[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_src = raw[paren + 1:end]
        operands = _OPERAND_RE.findall(operand_src)
        op = Op(name=name, kind=kind, result_type=rtype, line=raw,
                operands=operands)
        cur.ops.append(op)
        cur.symbols[name] = rtype
    return comps, entry


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for c in COLLECTIVES:
            self.collective_bytes[c] += mult * other.collective_bytes[c]

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(op: Op, comp: Computation) -> float:
    _, out_bytes = shape_numel_bytes(op.result_type)
    out_el, _ = shape_numel_bytes(op.result_type)
    lhs_type = comp.symbols.get(op.operands[0], "") if op.operands else ""
    lhs_dims = _shape_dims(lhs_type)
    m = _LHS_C_RE.search(op.line)
    k = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d:
                k *= lhs_dims[int(d)]
    return 2.0 * out_el * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_el, _ = shape_numel_bytes(op.result_type)
    m = _WINDOW_SIZE_RE.search(op.line)
    window = 1
    if m:
        for d in m.group(1).split("x"):
            window *= int(d)
    rhs_type = comp.symbols.get(op.operands[1], "") if len(op.operands) > 1 \
        else ""
    rhs_dims = _shape_dims(rhs_type)
    in_feat = rhs_dims[-2] if len(rhs_dims) >= 2 else 1
    return 2.0 * out_el * window * in_feat


def _fusion_flops(comp: Computation, comps) -> float:
    """dots/convs inside a fusion computation (CPU fuses some dots)."""
    total = 0.0
    for op in comp.ops:
        if op.kind in ("dot", "dot-general"):
            total += _dot_flops(op, comp)
        elif op.kind == "convolution":
            total += _conv_flops(op, comp)
        elif op.kind == "fusion":
            m = _CALLS_RE.search(op.line)
            if m and m.group(1) in comps:
                total += _fusion_flops(comps[m.group(1)], comps)
    return total


# ops whose *operand* traffic is proportional to their OUTPUT, not to the
# (possibly huge) operand they address into — matching HloCostAnalysis's
# special cases.  Charging the full operand would bill a scan's whole
# stacked parameter table once per iteration.
_SLICING = {"dynamic-slice", "gather", "slice"}
_UPDATING = {"dynamic-update-slice", "scatter"}


def _op_bytes(op: Op, comp: Computation) -> float:
    _, out_b = shape_numel_bytes(op.result_type)
    if op.kind in _SLICING:
        return 2.0 * out_b  # read the addressed window + write the result
    if op.kind in _UPDATING:
        # traffic ~ the update operand (base is updated in place)
        upd = op.operands[1] if len(op.operands) > 1 else None
        upd_b = shape_numel_bytes(comp.symbols.get(upd, ""))[1] if upd else 0
        return 2.0 * upd_b
    if op.kind in ("broadcast", "iota"):
        return float(out_b)
    total = float(out_b)
    for o in op.operands:
        t = comp.symbols.get(o)
        if t:
            total += shape_numel_bytes(t)[1]
    return total


def _fusion_bytes(op: Op, comp: Computation,
                  comps: Dict[str, "Computation"]) -> float:
    """Fusion operand/result traffic with slice-aware parameter billing:
    a fusion parameter consumed only by slicing ops inside the fusion is
    charged at the slices' output size, not the full array."""
    _, out_b = shape_numel_bytes(op.result_type)
    total = float(out_b)
    called = None
    m = _CALLS_RE.search(op.line)
    if m:
        called = comps.get(m.group(1))
    if called is None:
        for o in op.operands:
            t = comp.symbols.get(o)
            if t:
                total += shape_numel_bytes(t)[1]
        return total
    # in-place dynamic-update-slice fusion: the full base buffer is aliased
    # (scan residual stacking) — traffic is the updated window, not the
    # buffer.  Charge 2x update bytes; skip operands aliasing the result.
    dus = [o for o in called.ops if o.kind == "dynamic-update-slice"]
    if dus:
        total = 0.0
        for d in dus:
            upd = d.operands[1] if len(d.operands) > 1 else None
            total += 2.0 * shape_numel_bytes(
                called.symbols.get(upd, ""))[1] if upd else 0.0
        for o in op.operands:
            t = comp.symbols.get(o)
            if t and _SHAPE_RE.search(t) and t.split("{")[0] \
                    != op.result_type.split("{")[0]:
                total += min(shape_numel_bytes(t)[1],
                             shape_numel_bytes(op.result_type)[1])
        return total
    params = list(called.params)
    for i, o in enumerate(op.operands):
        t = comp.symbols.get(o)
        if not t:
            continue
        full = shape_numel_bytes(t)[1]
        pname = params[i] if i < len(params) else None
        if pname is not None:
            sliced = _sliced_usage_bytes(pname, called)
            if sliced is not None:
                total += min(full, sliced)
                continue
        total += full
    return total


def _sliced_usage_bytes(pname: str, comp: "Computation"):
    """If every use of ``pname`` inside ``comp`` is a slicing op, return the
    summed slice-output bytes; otherwise None (charge the full operand)."""
    used = False
    total = 0.0
    for o in comp.ops:
        if pname in o.operands:
            used = True
            if o.kind in _SLICING and o.operands and o.operands[0] == pname:
                total += shape_numel_bytes(o.result_type)[1]
            else:
                return None
    return total if used else 0.0


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all"}


def computation_cost(comp_name: str, comps: Dict[str, Computation],
                     memo: Dict[str, Cost]) -> Cost:
    if comp_name in memo:
        return memo[comp_name]
    memo[comp_name] = Cost()  # break cycles defensively
    comp = comps.get(comp_name)
    if comp is None:
        return memo[comp_name]
    cost = Cost()
    for op in comp.ops:
        if op.kind == "while":
            trip = 1
            m = _TRIP_RE.search(op.line)
            if m:
                trip = int(m.group(1))
            mb = _BODY_RE.search(op.line)
            mc = _COND_RE.search(op.line)
            if mb:
                cost.add(computation_cost(mb.group(1), comps, memo), trip)
            if mc:
                cost.add(computation_cost(mc.group(1), comps, memo),
                         trip + 1)
            continue
        if op.kind == "conditional":
            mbr = _BRANCHES_RE.search(op.line)
            if mbr:
                branch_costs = [
                    computation_cost(b.strip(), comps, memo)
                    for b in mbr.group(1).split(",") if b.strip()]
                if branch_costs:
                    # one branch executes; take the max (upper bound)
                    best = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
            continue
        if op.kind == "fusion":
            m = _CALLS_RE.search(op.line)
            if m:
                cost.flops += _fusion_flops(comps.get(m.group(1),
                                                      Computation("")), comps)
            cost.bytes += _fusion_bytes(op, comp, comps)
            continue
        if op.kind == "call":
            m = _TO_APPLY_RE.search(op.line)
            if m:
                cost.add(computation_cost(m.group(1), comps, memo))
            continue
        if op.kind in ("dot", "dot-general"):
            cost.flops += _dot_flops(op, comp)
            cost.bytes += _op_bytes(op, comp)
            continue
        if op.kind == "convolution":
            cost.flops += _conv_flops(op, comp)
            cost.bytes += _op_bytes(op, comp)
            continue
        base = op.kind.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES:
            if op.kind.endswith("-done"):
                continue  # counted at -start
            _, out_b = shape_numel_bytes(op.result_type)
            cost.collective_bytes[base] += out_b
            cost.bytes += _op_bytes(op, comp)
            continue
        if op.kind in _SKIP_BYTES:
            continue
        cost.bytes += _op_bytes(op, comp)
    memo[comp_name] = cost
    return cost


def analyze(hlo_text: str) -> Cost:
    """Full-module cost with loop trip counts applied."""
    comps, entry = parse_hlo(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    memo: Dict[str, Cost] = {}
    return computation_cost(entry, comps, memo)


# ---------------------------------------------------------------------------
# peak live bytes (liveness sweep)
#
# The planner (repro.mem) needs "does this reverse pass fit in B bytes" from
# the lowered HLO alone.  memory_analysis() gives XLA's buffer-assignment
# answer but only per whole module; this sweep computes an *analytic* peak
# from the optimized HLO text so the same number exists on any backend and
# can be decomposed in tests.  Model: program order is execution order
# (post-scheduling HLO), a value is live from its defining op to its last
# use, parameters are live throughout, and control-flow ops add the peak of
# their called computation on top of the caller's live set at that point.
# Aliasing (while-loop state donation, tuple views) is ignored, so this is
# a modest over-estimate — consistent, monotone in problem size, and tight
# enough to rank adjoint policies (validated against memory_analysis in
# tests/test_hlo_cost.py).
# ---------------------------------------------------------------------------

# ops whose result aliases/views an operand: no new buffer
_ALIASING = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "add-dependency", "copy-done", "all-gather-done",
             "all-reduce-done", "collective-permute-done",
             "optimization-barrier"}


def _called_comps(op: Op) -> List[str]:
    names: List[str] = []
    for regex in (_BODY_RE, _COND_RE, _CALLS_RE, _TO_APPLY_RE):
        m = regex.search(op.line)
        if m:
            names.append(m.group(1))
    m = _BRANCHES_RE.search(op.line)
    if m:
        names.extend(b.strip() for b in m.group(1).split(",") if b.strip())
    return names


def _comp_peak(comp_name: str, comps: Dict[str, Computation],
               memo: Dict[str, float]) -> float:
    if comp_name in memo:
        return memo[comp_name]
    memo[comp_name] = 0.0  # break cycles defensively
    comp = comps.get(comp_name)
    if comp is None:
        return 0.0
    size = {name: float(shape_numel_bytes(t)[1])
            for name, t in comp.symbols.items()}
    last_use: Dict[str, int] = {}
    for i, op in enumerate(comp.ops):
        for o in op.operands:
            last_use[o] = i
    base = sum(size.get(p, 0.0) for p in comp.params)
    alive: Dict[str, float] = {}
    peak = base
    for i, op in enumerate(comp.ops):
        nested = 0.0
        called = _called_comps(op)
        if op.kind == "fusion":
            called = []  # fusion internals live in registers/VMEM
        for c in called:
            nested = max(nested, _comp_peak(c, comps, memo))
        res = 0.0 if op.kind in _ALIASING else size.get(op.name, 0.0)
        peak = max(peak, base + sum(alive.values()) + res + nested)
        if res:
            alive[op.name] = res
        for o in set(op.operands):
            if last_use.get(o) == i:
                alive.pop(o, None)
    memo[comp_name] = peak
    return peak


def peak_live_bytes(hlo_text: str) -> float:
    """Analytic peak live-buffer bytes of the module's entry computation."""
    comps, entry = parse_hlo(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    return _comp_peak(entry, comps, {})
