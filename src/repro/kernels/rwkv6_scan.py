"""Pallas TPU kernel for the RWKV6 (Finch) chunked recurrence.

TPU mapping (chunked linear attention, matching nn/ssm.rwkv6_mix_chunked):
  * grid = (B, H, num_chunks); the chunk dimension is sequential on TPU, so
    the (dk, dv) state matrix lives in VMEM scratch and carries across
    chunks — the HBM<->VMEM traffic per chunk is just the (C, dh) tiles of
    r/k/v/logw plus the (C, dh) output tile.
  * Inside a chunk everything is dense (C x dh) x (dh x dh) matmuls on the
    MXU (intra-chunk attention, state application, state update) instead of
    a length-S sequential scan — the TPU-native adaptation of RWKV's
    CUDA per-timestep kernel.
  * VMEM working set at C=64, dh=64, fp32: 5*(64*64) + (64*64) state +
    (64,64) attention ~= 115 KB — tiny; production would raise C to 256.
  * Numerical form: per-channel log-decay cumsum with midpoint
    renormalization for the intra-chunk product form (see nn/ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sfin_ref,
                  s_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)      # (C, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)      # (C, dv)
    lw = lw_ref[0, 0].astype(jnp.float32)    # (C, dk), < 0
    u = u_ref[0].astype(jnp.float32)         # (1, dk) bonus

    cum = jnp.cumsum(lw, axis=0)
    cum_prev = cum - lw
    total = cum[-1:]                          # (1, dk)
    mid = cum[chunk // 2][None]               # midpoint renormalizer

    q_in = r * jnp.exp(cum_prev)              # decay from chunk start (<=1)
    q_mid = r * jnp.exp(cum_prev - mid)
    k_mid = k * jnp.exp(mid - cum)
    k_out = k * jnp.exp(total - cum)          # decay to chunk end (<=1)

    s_prev = s_scr[...]
    o_inter = jax.lax.dot_general(q_in, s_prev, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    att = jax.lax.dot_general(q_mid, k_mid, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(si < ti, att, 0.0)        # strictly lower triangular
    o_intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    o_diag = jnp.sum(r * u * k, axis=1, keepdims=True) * v

    s_scr[...] = jnp.exp(total).T * s_prev + jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    o_ref[0, 0] = (o_inter + o_intra + o_diag).astype(o_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        sfin_ref[0, 0] = s_scr[...].astype(sfin_ref.dtype)


def rwkv6_chunked_bhsd(r: jax.Array, k: jax.Array, v: jax.Array,
                       logw: jax.Array, u: jax.Array, *, chunk: int = 64,
                       interpret: bool | None = None):
    """r/k/v/logw: (B, H, S, dh); u: (H, dh).  Returns (out (B,H,S,dh),
    final state (B,H,dk,dv)).  S must be a multiple of `chunk` (the ops.py
    wrapper pads)."""
    b, h, s, dh = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk)
    out, sfin = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, dh), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dh), r.dtype),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return out, sfin
