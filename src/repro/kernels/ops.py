"""jit'd public wrappers for the Pallas kernels (model-facing layouts)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rwkv6_scan import rwkv6_chunked_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512):
    """Model layout: q (B,S,H,Dh), k/v (B,S,Hkv,Dh) -> (B,S,H,Dh)."""
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             block_q=block_q, block_k=block_k)
    return jnp.moveaxis(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_chunked(r, k, v, logw, u, *, chunk: int = 64):
    """Model layout: r/k/v/logw (B,S,H,Dh), u (H,Dh).
    Returns (out (B,S,H,Dh), final_state (B,H,dk,dv))."""
    s = r.shape[1]
    pad = (-s) % chunk
    def mov(t):
        tt = jnp.moveaxis(t, 1, 2)
        if pad:
            tt = jnp.pad(tt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return tt
    out, sfin = rwkv6_chunked_bhsd(mov(r), mov(k), mov(v), mov(logw), u,
                                   chunk=chunk)
    return jnp.moveaxis(out, 1, 2)[:, :s], sfin
