"""jit'd public wrappers for the Pallas kernels (model-facing layouts) plus
the fused RK stage-combine kernel used by the adjoint hot path.

Note (interpret-mode CPU caveat, same as flash_attention/rwkv6): on
non-TPU backends every kernel here runs through the Pallas interpreter, so
the fusion is semantic (one kernel call, one output buffer, accumulation
order fixed inside the kernel) rather than a measured VMEM win; real-TPU
validation is an open ROADMAP item.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rwkv6_scan import rwkv6_chunked_bhsd


# ---------------------------------------------------------------------------
# fused linear combination (the RK stage-update / stage-adjoint primitive)
#
# Every hot operation of the discrete adjoint is the same shape of math:
#
#   forward stage inputs   x_i = u + h * sum_j a_ij k_j
#   forward combine        u'  = u + h * sum_i b_i  k_i
#   adjoint stage weights  v_i = b_i * lam + sum_{j>i} a_ji w_j
#
# i.e. out = (base_coeff * base) + sum_i c_i * term_i with trace-time
# tableau weights.  Unfused, each term lowers to a separate mul+add pair
# with its own output buffer; this kernel emits ONE pallas_call per pytree
# leaf with the whole accumulation inside, in the exact order the unfused
# ``tree_axpy`` chain uses — so results (and therefore the adjoint's
# gradients) are bitwise-identical to the unfused path when both run under
# jit (XLA's FMA contraction is consistent within a compiled program).
# ---------------------------------------------------------------------------


def _lincomb_kernel_static(*refs, coeffs, base_coeff):
    """out = base_coeff*base + sum_i coeffs[i]*terms[i]; coeffs are
    trace-time Python floats (fixed-step path: h folded into coeffs)."""
    base_ref = refs[0]
    out_ref = refs[-1]
    term_refs = refs[1:-1]
    acc = base_ref[...]
    if base_coeff is not None:
        acc = base_coeff * acc
    for c, r in zip(coeffs, term_refs):
        acc = acc + c * r[...]
    out_ref[...] = acc


def _lincomb_kernel_scaled(*refs, weights, base_coeff):
    """Like _lincomb_kernel_static but the per-term coefficient is
    h * weights[i] with h a traced scalar operand (adaptive-step path) —
    computed inside the kernel in the same order the unfused chain uses."""
    base_ref, h_ref = refs[0], refs[1]
    out_ref = refs[-1]
    term_refs = refs[2:-1]
    h = h_ref[0]
    acc = base_ref[...]
    if base_coeff is not None:
        acc = base_coeff * acc
    for w, r in zip(weights, term_refs):
        acc = acc + (h * w) * r[...]
    out_ref[...] = acc


def fused_lincomb(base: jax.Array, terms, weights, scale=None,
                  base_coeff: float | None = None, *,
                  interpret: bool | None = None) -> jax.Array:
    """One-kernel ``base_coeff*base + sum_i (scale*weights[i]) * terms[i]``.

    ``weights`` are trace-time floats (Butcher-tableau entries); ``scale``
    is the step size h — a Python float (fixed-step: folded into the
    coefficients at trace time) or a traced scalar (adaptive: passed as a
    kernel operand).  ``base_coeff=None`` means the base enters unscaled
    (the RK state-update form); a float (including 0.0) multiplies it
    first (the adjoint ``v_i = b_i*lam + ...`` form).  Zero weights must be
    dropped by the caller (to mirror the unfused chain's trace-time skip).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = base.shape
    flat = base.reshape(-1)  # interpret-mode pallas wants >= 1-D operands
    fterms = [t.reshape(-1) for t in terms]
    out_sds = jax.ShapeDtypeStruct(flat.shape, flat.dtype)
    if scale is None or isinstance(scale, (int, float)):
        coeffs = [w if scale is None else float(scale) * w for w in weights]
        kern = functools.partial(_lincomb_kernel_static, coeffs=coeffs,
                                 base_coeff=base_coeff)
        out = pl.pallas_call(kern, out_shape=out_sds,
                             interpret=interpret)(flat, *fterms)
    else:
        kern = functools.partial(_lincomb_kernel_scaled, weights=list(weights),
                                 base_coeff=base_coeff)
        h_op = jnp.asarray(scale, flat.dtype).reshape(1)
        out = pl.pallas_call(kern, out_shape=out_sds,
                             interpret=interpret)(flat, h_op, *fterms)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512):
    """Model layout: q (B,S,H,Dh), k/v (B,S,Hkv,Dh) -> (B,S,H,Dh)."""
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             block_q=block_q, block_k=block_k)
    return jnp.moveaxis(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_chunked(r, k, v, logw, u, *, chunk: int = 64):
    """Model layout: r/k/v/logw (B,S,H,Dh), u (H,Dh).
    Returns (out (B,S,H,Dh), final_state (B,H,dk,dv))."""
    s = r.shape[1]
    pad = (-s) % chunk
    def mov(t):
        tt = jnp.moveaxis(t, 1, 2)
        if pad:
            tt = jnp.pad(tt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return tt
    out, sfin = rwkv6_chunked_bhsd(mov(r), mov(k), mov(v), mov(logw), u,
                                   chunk=chunk)
    return jnp.moveaxis(out, 1, 2)[:, :s], sfin
