"""Pure-jnp oracles for the Pallas kernels (used by per-kernel allclose
tests, sweeping shapes and dtypes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,Sq,Dh); k,v: (B,Hkv,Sk,Dh).  fp32 reference softmax attention."""
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(dh)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok = kp <= qp
    if window > 0:
        ok = jnp.logical_and(ok, kp > qp - window)
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def lincomb_ref(base, terms, weights, scale=None, base_coeff=None):
    """Oracle for kernels.ops.fused_lincomb: the exact unfused tree_axpy
    accumulation order (base first, then terms left to right)."""
    acc = base if base_coeff is None else base_coeff * base
    for w, t in zip(weights, terms):
        c = w if scale is None else scale * w
        acc = acc + c * t
    return acc


def rwkv6_ref(r, k, v, logw, u):
    """Sequential RWKV6 recurrence oracle.
    r/k/v/logw: (B,H,S,dh); u: (H,dh).  Returns (out, final state)."""
    b, h, s, dh = r.shape

    def step(S, inp):
        rt, kt, vt, lw = inp          # (B,H,dh)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, S) \
            + jnp.einsum("bhk,bhk,bhv->bhv", rt, u[None] * kt, vt)
        S_new = jnp.exp(lw)[..., None] * S + jnp.einsum(
            "bhk,bhv->bhkv", kt, vt)
        return S_new, ot

    S0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    seq = tuple(jnp.moveaxis(t.astype(jnp.float32), 2, 0)
                for t in (r, k, v, logw))
    S, outs = jax.lax.scan(step, S0, seq)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), S
