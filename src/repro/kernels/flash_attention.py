"""Pallas TPU flash-attention kernel (online softmax, causal / sliding
window, GQA-aware kv-head indexing).

TPU mapping:
  * grid = (B, H, num_q_blocks, num_k_blocks); the last grid dimension is
    sequential on TPU, so VMEM scratch (m, l, acc) carries the online-softmax
    state across k-blocks of one q-block.
  * BlockSpecs tile Q to (block_q, head_dim) and K/V to (block_k, head_dim)
    in VMEM; head_dim and block sizes are multiples of 128 for MXU alignment
    (tests sweep smaller shapes in interpret mode; production blocks are
    q=512, k=512, dh in {64,128,256} -> working set
    2*(bq*dh + 2*bk*dh + bq*bk) * 4B  ~=  3.3 MB at bq=bk=512, dh=128,
    comfortably inside the ~16 MB VMEM budget with double buffering).
  * GQA: the kv BlockSpec index map selects kv head = h // (H // H_kv), so
    kv tiles are fetched once per kv head group, not H/H_kv times.
  * causal/window: tiles entirely above the diagonal (or entirely outside
    the sliding-window band) are skipped with pl.when — no MXU work and no
    accumulator traffic for masked-out tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tile-level skip: fully-masked tiles do no MXU work
    relevant = k_start < seq_k
    if causal:
        relevant = jnp.logical_and(relevant,
                                   k_start <= q_start + block_q - 1)
    if window > 0:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        ok = k_pos < seq_k
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         block_q: int = 512, block_k: int = 512,
                         interpret: bool | None = None) -> jax.Array:
    """q: (B, H, Sq, Dh); k, v: (B, Hkv, Sk, Dh) with H % Hkv == 0.
    Returns (B, H, Sq, Dh)."""
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (dh ** 0.5), block_q=block_q,
        block_k=block_k, causal=causal, window=int(window), seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * block_q, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
