"""AdamW with fp32 moments over (possibly bf16) params, global-norm clip,
warmup-cosine schedule, gradient accumulation, and optional gradient
compression for the cross-pod reduction (optax is not available offline).

State layout mirrors the param tree (so the FSDP sharding specs of the
params apply leaf-for-leaf to m and v), plus a scalar step count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # cast gradients to this dtype before the (cross-pod) reduction/update —
    # halves all-reduce bytes when bf16 (distributed-optimization trick)
    grad_dtype: str | None = None

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jtu.tree_map(zeros, params),
                          v=jtu.tree_map(zeros, params))

    def schedule(self, step) -> jax.Array:
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        prog = jnp.clip((step - self.warmup_steps)
                        / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def update(self, grads, state: AdamWState, params):
        if self.grad_dtype:
            gd = jnp.dtype(self.grad_dtype)
            grads = jtu.tree_map(lambda g: g.astype(gd), grads)
        grads = jtu.tree_map(lambda g: g.astype(jnp.float32), grads)
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jtu.tree_leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jtu.tree_map(lambda g: g * scale, grads)

        step = state.step + 1
        lr = self.schedule(state.step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        m = jtu.tree_map(lambda m_, g: self.b1 * m_ + (1 - self.b1) * g,
                         state.m, grads)
        v = jtu.tree_map(lambda v_, g: self.b2 * v_ + (1 - self.b2) * g * g,
                         state.v, grads)

        def upd(p, m_, v_):
            mh = m_ / b1c
            vh = v_ / b2c
            u = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay \
                * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jtu.tree_map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), \
            {"grad_norm": gnorm, "lr": lr}
