"""Gradient compression for cross-pod reduction (distributed-optimization
trick, §6 of DESIGN.md).

Two composable schemes:

* ``bf16``  — cast fp32 grads to bf16 before the pod-axis all-reduce and
  back after: halves the slowest collective's bytes for ~0 quality cost at
  LM scale.  Stateless.

* ``int8``  — per-leaf symmetric int8 quantization with *error feedback*
  (the residual from quantization is carried into the next step), the
  standard trick that keeps SGD/Adam convergence with aggressive
  compression.  4x fewer bytes on the wire.

Both are expressed as (compress, decompress) around a reduction closure so
they drop into either a jit'd psum (shard_map) or the implicit GSPMD
all-reduce of a pjit'd grad — the dry-run path uses ``compressed_psum``
inside shard_map so the wire dtype is visible in the lowered HLO.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

PyTree = Any


# ---------------------------------------------------------------------------
# bf16 wire compression
# ---------------------------------------------------------------------------

def bf16_compress(grads: PyTree) -> PyTree:
    return jtu.tree_map(lambda g: g.astype(jnp.bfloat16), grads)


def bf16_decompress(grads: PyTree) -> PyTree:
    return jtu.tree_map(lambda g: g.astype(jnp.float32), grads)


# ---------------------------------------------------------------------------
# int8 + error feedback
# ---------------------------------------------------------------------------

def int8_init(grads_shape: PyTree) -> PyTree:
    """Error-feedback residual state (zeros like the grads)."""
    return jtu.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_compress(grads: PyTree, residual: PyTree):
    """Returns ((q, scales), new_residual).  new_residual = g+r - deq(q)."""
    def one(g, r):
        gr = g + r
        q, s = int8_quantize(gr)
        return (q, s), gr - int8_dequantize(q, s)

    pairs = jtu.tree_map(one, grads, residual)
    qs = jtu.tree_map(lambda p: p[0], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    res = jtu.tree_map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return qs, res


def int8_decompress(qs: PyTree) -> PyTree:
    return jtu.tree_map(lambda p: int8_dequantize(*p), qs,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# compressed cross-pod reduction (shard_map building block)
# ---------------------------------------------------------------------------

def compressed_psum(grads: PyTree, axis_name: str,
                    scheme: str = "bf16") -> PyTree:
    """All-reduce ``grads`` over ``axis_name`` with wire compression.

    bf16: psum in bf16 (half the bytes on the slow inter-pod links).
    int8: each participant all-gathers (q, scale) — int8 payload — and sums
    the dequantized shards locally, so the wire carries 1/4 the bytes at the
    cost of a gather instead of a tree-reduce.
    """
    if scheme == "none":
        return jax.lax.psum(grads, axis_name)
    if scheme == "bf16":
        g16 = bf16_compress(grads)
        summed = jax.lax.psum(g16, axis_name)
        return bf16_decompress(summed)
    if scheme == "int8":
        def one(g):
            q, s = int8_quantize(g)
            qs = jax.lax.all_gather(q, axis_name)      # int8 on the wire
            ss = jax.lax.all_gather(s, axis_name)
            deq = qs.astype(jnp.float32) \
                * ss.reshape((-1,) + (1,) * g.ndim)
            return deq.sum(axis=0)
        return jtu.tree_map(one, grads)
    raise ValueError(f"unknown compression scheme {scheme!r}")


def wire_bytes(grads: PyTree, scheme: str = "bf16") -> int:
    """Bytes a single participant puts on the wire for one reduction."""
    per = {"none": 4, "bf16": 2, "int8": 1}[scheme]
    return sum(leaf.size * per for leaf in jtu.tree_leaves(grads))
