"""repro.obs — the observability layer: metrics registry, jit-safe
counters, the solver flight recorder, JSONL metrics sink, profiler
annotations, and the unified benchmark-baseline checker.

Every other layer reports through this package instead of inventing its
own dict: spill-store traffic (``repro.mem.offload``), Newton/GMRES
health (``repro.core.implicit``), adaptive accept/reject decisions
(``repro.core.adaptive``), planner decisions (``repro.mem.planner``
``explain=True``), and per-train-step records (``repro.launch``).

Attach a ``FlightRecorder`` to a solve with the ``obs=`` knob:

    rec = FlightRecorder()
    u = odeint(f, u0, theta, dt=..., n_steps=..., obs=rec)
    rec.events("spill.write"); rec.adaptive_steps(); rec.spill_traffic()

With ``obs=None`` (default) the knob is zero-overhead: no extra op, no
callback, nothing traced.
"""
from repro.obs.baseline import (BaselineRef, Gate, check_against_baseline,
                                lookup)
from repro.obs.registry import (DEFAULT_REGISTRY, FevalCounter, JitCounter,
                                MetricsRegistry, default_registry)
from repro.obs.sink import MetricsSink, StructuredLogger, read_jsonl
from repro.obs.trace import FlightRecorder, TraceEvent
from repro.obs.trace_export import export_chrome_trace, to_chrome_trace
from repro.obs.profile import host_annotation, scope

__all__ = [
    "BaselineRef", "Gate", "check_against_baseline", "lookup",
    "DEFAULT_REGISTRY", "FevalCounter", "JitCounter", "MetricsRegistry",
    "default_registry",
    "MetricsSink", "StructuredLogger", "read_jsonl",
    "FlightRecorder", "TraceEvent",
    "export_chrome_trace", "to_chrome_trace",
    "host_annotation", "scope",
]
