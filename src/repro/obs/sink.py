"""JSONL metrics sink + structured logger.

``MetricsSink`` appends one JSON object per ``emit`` to a file — the
machine-readable channel the launch layer reports through (per-step train
records, serve stats, benchmark records) and CI uploads as an artifact.
Records carry an ``event`` name, a monotonically increasing ``seq``, and a
wall-clock ``ts``; writes are lock-guarded and flushed per record so a
crashed run keeps every completed line.

``StructuredLogger`` is the human+machine bridge that replaces the bare
``print`` calls in ``launch/train.py`` / ``launch/dryrun.py``: each
``.log(event, msg, **fields)`` writes the formatted line through ``log_fn``
(default ``print``; tests pass a no-op, exactly as they did before) AND
emits the structured record to the sink when one is attached.  Either side
can be switched off independently.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        try:
            import numpy as np
            a = np.asarray(v)
            return a.item() if a.ndim == 0 else a.tolist()
        except Exception:
            return repr(v)


class MetricsSink:
    """Append-only JSONL writer; one JSON object per ``emit``."""

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.RLock()
        self._seq = 0
        self._fh = open(self.path, "a")

    def emit(self, event: str, **fields) -> Dict[str, Any]:
        rec = {"event": event, "ts": time.time(),
               **{k: _jsonable(v) for k, v in fields.items()}}
        with self._lock:
            rec["seq"] = self._seq
            self._seq += 1
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        return rec

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Read a JSONL file back into a list of dicts (skips blank lines)."""
    out: List[Dict[str, Any]] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class StructuredLogger:
    """Route a message to both a human line (via ``log_fn``) and a JSONL
    record (via ``sink``); either may be None."""

    def __init__(self, log_fn: Optional[Callable[[str], None]] = print,
                 sink: Optional[MetricsSink] = None):
        self.log_fn = log_fn
        self.sink = sink

    def log(self, event: str, msg: str, **fields) -> None:
        if self.log_fn is not None:
            self.log_fn(msg)
        if self.sink is not None:
            self.sink.emit(event, msg=msg, **fields)

    def metric(self, event: str, **fields) -> None:
        """Sink-only record (no human line) — per-step metrics."""
        if self.sink is not None:
            self.sink.emit(event, **fields)
