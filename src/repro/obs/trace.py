"""Solver flight recorder: a structured, append-only trace of what a solve
actually did — per-attempt adaptive step decisions, per-step Newton health,
checkpoint-store traffic with segment ids and payload bytes — attached to a
solve with the ``obs=`` knob (``odeint`` / ``odeint_implicit`` /
``odeint_adaptive``) and **zero-overhead when off**: with ``obs=None`` not a
single extra op is traced.

Two event classes, honestly labelled by when they are recorded:

  trace-time   configuration and schedule events (``odeint.solve``, the
               revolve checkpoint put/get/free/recompute schedule, the
               planner's decision).  Emitted while jax traces the program —
               ONCE per compilation.  A cached jit re-execution emits no new
               trace-time events (they describe the program, not the run).
  runtime      events carrying runtime values (``adaptive.step`` with
               dt/error-norm/accept, ``implicit.steps`` with stacked
               per-step Newton iterations/residuals,
               ``spill.write``/``spill.read`` with payload bytes).
               Emitted from inside the compiled program via
               ``jax.debug.callback`` (traced sites) or directly from
               the spill store's host callbacks — once per EXECUTION.

jax-0.4.37 caveat (why implicit events are STACKED): a
``jax.debug.callback`` issued inside a ``lax.scan`` body within a
``custom_vjp`` *fwd* rule is silently dropped under ``jit(grad(...))``
(while_loop bodies and bwd-rule scans are fine).  The implicit sweeps
therefore thread per-step ``StepInfo`` out of the scan as stacked ys and
issue ONE top-level tap per sweep; ``implicit_steps()`` expands those
stacked events back into per-step records.

``jax.debug.callback`` is unordered, so runtime events may interleave
across concurrent solves; every emitter therefore includes enough state to
reconstruct order (the adaptive tap carries the attempt counter
``n_accepted + n_rejected``, spill events carry slot bases).  The
reconstruction helpers (``adaptive_steps``, ``spill_traffic``) sort on
those fields, not on arrival order.

Numerics: debug callbacks only add an effect, never an op that feeds the
computation — gradients with a recorder attached are bitwise-identical to
the unobserved solve (tests/test_obs.py locks this across
policy x offload-tier x (eager|jit)).

Lifecycle: a recorder is baked into the traced program as a static
argument, so use ONE recorder per jitted solve (a fresh recorder forces a
retrace) and ``clear()`` between measured runs (compile/warmup executions
emit events too).  Host-side mutation is lock-guarded; events carry a
monotonically increasing ``seq``.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _pyval(x):
    """Host-side: numpy/array scalar -> plain python (JSON-ready)."""
    a = np.asarray(x)
    if a.ndim == 0:
        v = a.item()
        return v
    return a.tolist()


@dataclass(frozen=True)
class TraceEvent:
    kind: str
    data: Dict[str, Any]
    seq: int
    runtime: bool  # True: emitted during execution; False: during tracing
    #: host wall clock at record time (time.time()).  Host-side metadata
    #: only — nothing traced reads it, so numerics stay untouched; the
    #: Perfetto export (``obs.trace_export``) uses it for the timeline.
    ts: float = 0.0

    def to_json(self) -> Dict[str, Any]:
        return {"kind": self.kind, "seq": self.seq,
                "runtime": self.runtime, "ts": self.ts, **self.data}


class FlightRecorder:
    """Append-only structured solver trace (see module docstring)."""

    def __init__(self, registry=None):
        self._lock = threading.RLock()
        self._events: List[TraceEvent] = []
        self._seq = 0
        #: optional MetricsRegistry mirror: every event also bumps the
        #: counter ``trace.<kind>``
        self.registry = registry

    # -- host-side recording (trace-time events, store callbacks) ----------
    def record(self, kind: str, *, _runtime: bool = False, **data) -> None:
        with self._lock:
            self._events.append(TraceEvent(kind, data, self._seq, _runtime,
                                           time.time()))
            self._seq += 1
        if self.registry is not None:
            self.registry.inc(f"trace.{kind}")

    # -- traced-side recording (runtime events) -----------------------------
    def emit(self, kind: str, **traced_fields) -> None:
        """Call from inside traced code: schedules a ``jax.debug.callback``
        that records the runtime values of ``traced_fields`` on execution.
        Adds only a debug effect to the program — no op feeds the
        computation, so numerics are untouched."""
        keys = tuple(traced_fields.keys())
        vals = tuple(traced_fields.values())

        def cb(*host_vals):
            self.record(kind, _runtime=True,
                        **{k: _pyval(v) for k, v in zip(keys, host_vals)})

        jax.debug.callback(cb, *vals)

    # -- access --------------------------------------------------------------
    def sync(self) -> None:
        """Block until pending emits have landed.  ``jax.debug.callback``
        is asynchronous: reading the recorder right after a solve returns
        can miss late callbacks (the reverse sweep's recompute taps are
        the last to run).  Called automatically by ``events()`` — never
        call it from inside a callback body (it would wait on itself)."""
        barrier = getattr(jax, "effects_barrier", None)
        if barrier is not None:
            barrier()

    def events(self, kind: Optional[str] = None) -> List[TraceEvent]:
        self.sync()
        with self._lock:
            evs = list(self._events)
        if kind is None:
            return evs
        return [e for e in evs if e.kind == kind]

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    # -- reconstruction helpers ---------------------------------------------
    def adaptive_steps(self) -> List[Dict[str, Any]]:
        """The adaptive sweep's attempt sequence, ordered by the attempt
        counter each tap carried (immune to callback reordering): one dict
        per attempted step with t, h, err_norm, and accept."""
        evs = self.events("adaptive.step")
        return sorted((e.data for e in evs), key=lambda d: d["attempt"])

    def accepted_rejected(self) -> Tuple[int, int]:
        steps = self.adaptive_steps()
        acc = sum(1 for d in steps if d["accept"])
        return acc, len(steps) - acc

    def spill_traffic(self) -> Dict[str, Dict[str, Any]]:
        """Per-store, per-direction spill I/O: callbacks, slots, and payload
        bytes, plus the per-segment breakdown keyed by slot base and the
        per-MEDIUM byte split (``media``: "ram" vs "disk" — the multi-tier
        store tags every write/read event with where the payload landed).
        ``dispatch_cb`` counts the token-only async prefetch dispatches
        (``spill.dispatch`` events) separately from data-carrying reads."""
        out: Dict[str, Dict[str, Any]] = {}
        for e in self.events():
            if e.kind not in ("spill.write", "spill.read", "spill.free",
                              "spill.dispatch"):
                continue
            store = e.data.get("store", "?")
            s = out.setdefault(store, {
                "write_cb": 0, "read_cb": 0, "free_cb": 0, "dispatch_cb": 0,
                "write_slots": 0, "read_slots": 0,
                "write_bytes": 0, "read_bytes": 0,
                "segments": {}, "media": {}})
            if e.kind == "spill.dispatch":
                s["dispatch_cb"] += 1
                continue
            if e.kind == "spill.free":
                s["free_cb"] += 1
                continue
            medium = e.data.get("medium")
            if medium is not None:
                m = s["media"].setdefault(str(medium), {
                    "write_bytes": 0, "read_bytes": 0})
                key = ("write_bytes" if e.kind == "spill.write"
                       else "read_bytes")
                m[key] += int(e.data.get("bytes", 0))
            d = "write" if e.kind == "spill.write" else "read"
            s[f"{d}_cb"] += 1
            s[f"{d}_slots"] += int(e.data.get("slots", 1))
            s[f"{d}_bytes"] += int(e.data.get("bytes", 0))
            seg = s["segments"].setdefault(int(e.data.get("base", -1)), {
                "write_slots": 0, "read_slots": 0,
                "write_bytes": 0, "read_bytes": 0})
            seg[f"{d}_slots"] += int(e.data.get("slots", 1))
            seg[f"{d}_bytes"] += int(e.data.get("bytes", 0))
        return out

    @staticmethod
    def _expand_stacked(evs: List[TraceEvent]) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for e in evs:
            base = int(e.data.get("base", 0))
            its = e.data["iters"]
            res = e.data["residual"]
            conv = e.data["converged"]
            if not isinstance(its, list):  # single-step sweep
                its, res, conv = [its], [res], [conv]
            for i in range(len(its)):
                out.append({"step": base + i, "iters": its[i],
                            "residual": res[i], "converged": conv[i]})
        return sorted(out, key=lambda d: d["step"])

    def implicit_steps(self) -> List[Dict[str, Any]]:
        """Forward-sweep Newton exit states, one dict per step ordered by
        step index — expanded from the stacked ``implicit.steps`` taps
        (one per scan; see module docstring)."""
        return self._expand_stacked(self.events("implicit.steps"))

    def implicit_recomputes(self) -> List[Dict[str, Any]]:
        """Reverse-sweep re-advance Newton exit states, per step."""
        return self._expand_stacked(self.events("implicit.recompute"))

    # -- export --------------------------------------------------------------
    def to_jsonl(self, path_or_sink) -> int:
        """Write every event as one JSON line; accepts a path or a
        ``MetricsSink``.  Returns the number of events written."""
        evs = self.events()
        emit = getattr(path_or_sink, "emit", None)
        if emit is not None:
            for e in evs:
                emit(f"trace.{e.kind}", **e.to_json())
            return len(evs)
        with open(path_or_sink, "a") as fh:
            for e in evs:
                fh.write(json.dumps(e.to_json()) + "\n")
        return len(evs)
