"""Perfetto / chrome://tracing export of the observability streams.

Turns the flight recorder's structured events (or their JSONL dumps —
``FlightRecorder.to_jsonl`` / ``MetricsSink`` files) into the Chrome
trace-event JSON format that https://ui.perfetto.dev and
chrome://tracing load directly:

  PYTHONPATH=src python -m repro.obs.trace_export METRICS.jsonl trace.json

Every recorded event becomes an *instant* event on a track named after
its kind, grouped into process rows by subsystem — ``solver`` (odeint /
adaptive / implicit / newton), ``spill`` (checkpoint-store traffic),
``serve`` (queue + engine events), ``misc`` for the rest.  On top of the
instants the exporter synthesizes *counter* tracks, which is where the
timeline gets readable:

  ``spill bytes``     cumulative write/read payload bytes per store
  ``queue depth``     the serve queue's depth gauge over time
  ``adaptive h``      the adaptive controller's step size per attempt

Timestamps come from the host wall clock each ``TraceEvent`` now carries
(``ts``, seconds); records without one (older JSONL dumps) fall back to
their ``seq`` so ordering survives even when the absolute timeline is
unknown.  The export is a pure host-side transform — it never touches a
live solve.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

__all__ = ["to_chrome_trace", "export_chrome_trace", "read_events"]

_SOLVER_PREFIXES = ("odeint", "adaptive", "implicit", "newton", "revolve",
                    "plan")
_SPILL_PREFIXES = ("spill",)
_SERVE_PREFIXES = ("queue", "serve")

# stable pid per subsystem row (Perfetto sorts by pid)
_PIDS = {"solver": 1, "spill": 2, "serve": 3, "misc": 4}


def _subsystem(kind: str) -> str:
    head = kind.split(".", 1)[0]
    if head in _SPILL_PREFIXES:
        return "spill"
    if head in _SERVE_PREFIXES:
        return "serve"
    if head in _SOLVER_PREFIXES:
        return "solver"
    return "misc"


def _micros(rec: Dict[str, Any]) -> float:
    ts = rec.get("ts")
    if ts:
        return float(ts) * 1e6
    # no wall clock (older dump): seq keeps relative order, 1 us apart
    return float(rec.get("seq", 0))


def read_events(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL file of trace/metrics records.  Accepts both
    ``FlightRecorder.to_jsonl`` lines (``kind`` field, possibly prefixed
    ``trace.<kind>`` when routed through a ``MetricsSink``) and plain
    sink records (``event`` field)."""
    out: List[Dict[str, Any]] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if "kind" not in rec:
                ev = rec.get("event")
                if ev is None:
                    continue
                rec = dict(rec, kind=ev)
            kind = rec["kind"]
            if kind.startswith("trace."):
                rec = dict(rec, kind=kind[len("trace."):])
            out.append(rec)
    return out


def to_chrome_trace(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Chrome trace-event JSON (the ``{"traceEvents": [...]}`` envelope)
    from an iterable of event dicts (``TraceEvent.to_json()`` shape)."""
    trace: List[Dict[str, Any]] = []
    named_rows: set = set()
    counters: Dict[str, Dict[str, float]] = {}  # name -> running totals

    def row(sub: str) -> int:
        pid = _PIDS[sub]
        if sub not in named_rows:
            named_rows.add(sub)
            trace.append({"ph": "M", "pid": pid, "name": "process_name",
                          "args": {"name": sub}})
        return pid

    def counter(sub: str, name: str, ts: float,
                values: Dict[str, float]) -> None:
        trace.append({"ph": "C", "pid": row(sub), "name": name, "ts": ts,
                      "args": {k: float(v) for k, v in values.items()}})

    for rec in events:
        kind = rec.get("kind")
        if not kind:
            continue
        sub = _subsystem(kind)
        ts = _micros(rec)
        args = {k: v for k, v in rec.items()
                if k not in ("kind", "ts") and _jsonable(v)}
        trace.append({"ph": "i", "s": "t", "pid": row(sub), "tid": kind,
                      "name": kind, "ts": ts, "cat": sub, "args": args})
        # counter synthesis
        if kind in ("spill.write", "spill.read"):
            store = str(rec.get("store", "?"))
            tot = counters.setdefault(f"spill bytes [{store}]",
                                      {"write": 0.0, "read": 0.0})
            d = "write" if kind == "spill.write" else "read"
            tot[d] += float(rec.get("bytes", 0) or 0)
            counter("spill", f"spill bytes [{store}]", ts, tot)
        elif kind in ("queue.submit", "queue.schedule", "queue.reject"):
            depth = rec.get("depth")
            if depth is not None:
                counter("serve", "queue depth", ts,
                        {"depth": float(depth)})
        elif kind == "adaptive.step":
            h = rec.get("h")
            if h is not None:
                counter("solver", "adaptive h", ts, {"h": float(h)})
        elif kind == "serve.batch":
            occ = rec.get("occupancy")
            if occ is not None:
                counter("serve", "batch occupancy", ts,
                        {"occupancy": float(occ)})
    return {"traceEvents": trace,
            "displayTimeUnit": "ms",
            "otherData": {"exporter": "repro.obs.trace_export"}}


def _jsonable(v) -> bool:
    return isinstance(v, (str, int, float, bool, list, dict, type(None)))


def export_chrome_trace(src, path: str) -> int:
    """Write a Perfetto-loadable trace JSON for ``src`` — a
    ``FlightRecorder``, a JSONL file path, or an iterable of event dicts.
    Returns the number of trace entries written."""
    events = getattr(src, "events", None)
    if callable(events):  # FlightRecorder
        recs: Iterable[Dict[str, Any]] = [e.to_json() for e in events()]
    elif isinstance(src, str):
        recs = read_events(src)
    else:
        recs = src
    doc = to_chrome_trace(recs)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return len(doc["traceEvents"])


def main(argv: Optional[List[str]] = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="Export JSONL flight-recorder/metrics records to "
                    "Chrome trace-event JSON (Perfetto-loadable)")
    ap.add_argument("jsonl", help="input JSONL (FlightRecorder.to_jsonl or "
                                  "MetricsSink output)")
    ap.add_argument("out", help="output trace JSON path")
    args = ap.parse_args(argv)
    n = export_chrome_trace(args.jsonl, args.out)
    print(f"[trace_export] wrote {n} trace entries -> {args.out}")


if __name__ == "__main__":
    main()
