"""Unified benchmark-baseline regression checker.

``benchmarks/hotpath.py`` (BENCH_3) and ``benchmarks/stiff_ensemble.py``
(BENCH_4) used to each carry a bespoke comparator; CI now routes both
through this one: a benchmark declares its gates as data
(``Gate(path, op, ref=...)`` against the measured record, with thresholds
optionally read from the recorded baseline JSON) and
``check_against_baseline`` evaluates them, returning human-readable error
strings and mirroring pass/fail counts into the metrics registry
(``baseline.<bench>.pass|fail``) so the smoke run's JSONL artifact records
which gates tripped.

Paths are dotted lookups into the record (``"spill_io.callbacks"``); a
``*`` segment fans out over every key of a dict (``"fused.*.bitwise"`` —
ALL fanned-out values must pass).  ``ref`` is a literal, or
``BaselineRef("key.path")`` to read the threshold from the baseline dict.
A gate with ``precondition=True`` short-circuits: if it fails, its message
is returned alone and no other gate runs (used for "baseline recorded for
a different problem size" guards where every other comparison would be
meaningless).
"""
from __future__ import annotations

import json
import operator
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

_MISSING = object()

_OPS: Dict[str, Callable[[Any, Any], bool]] = {
    "<=": operator.le,
    "<": operator.lt,
    ">=": operator.ge,
    ">": operator.gt,
    "==": operator.eq,
    "!=": operator.ne,
    "truthy": lambda v, _: bool(v),
    "falsy": lambda v, _: not bool(v),
}


def lookup(record: Any, path: str) -> List[Tuple[str, Any]]:
    """Resolve a dotted path; ``*`` fans out over dict keys.  Returns
    ``[(concrete_path, value), ...]`` — value is ``_MISSING`` if absent."""
    results: List[Tuple[str, Any]] = [("", record)]
    for seg in path.split("."):
        nxt: List[Tuple[str, Any]] = []
        for pfx, cur in results:
            if cur is _MISSING:
                nxt.append((pfx, _MISSING))
            elif seg == "*":
                if isinstance(cur, dict):
                    for k, v in cur.items():
                        nxt.append((f"{pfx}.{k}".lstrip("."), v))
                else:
                    nxt.append((f"{pfx}.*".lstrip("."), _MISSING))
            elif isinstance(cur, dict) and seg in cur:
                nxt.append((f"{pfx}.{seg}".lstrip("."), cur[seg]))
            elif isinstance(cur, (list, tuple)) and seg.lstrip("-").isdigit():
                i = int(seg)
                v = cur[i] if -len(cur) <= i < len(cur) else _MISSING
                nxt.append((f"{pfx}.{seg}".lstrip("."), v))
            else:
                nxt.append((f"{pfx}.{seg}".lstrip("."), _MISSING))
        results = nxt
    return results


@dataclass(frozen=True)
class BaselineRef:
    """Threshold read from the baseline JSON at this dotted path."""
    path: str


@dataclass(frozen=True)
class Gate:
    """One regression gate: ``lookup(record, path) <op> ref``."""
    name: str
    path: str
    op: str  # one of _OPS
    ref: Any = None  # literal, or BaselineRef into the baseline dict
    message: str = ""  # extra context appended to the failure line
    precondition: bool = False  # failure short-circuits remaining gates

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown gate op {self.op!r}; "
                             f"expected one of {sorted(_OPS)}")


def _resolve_ref(ref: Any, baseline: Optional[dict]) -> Any:
    if isinstance(ref, BaselineRef):
        if baseline is None:
            return _MISSING
        hits = lookup(baseline, ref.path)
        return hits[0][1] if hits else _MISSING
    return ref


def check_against_baseline(
        record: dict,
        gates: Sequence[Gate],
        baseline: Union[dict, str, Path, None] = None,
        *,
        bench: str = "bench",
        registry=None) -> List[str]:
    """Evaluate every gate against ``record``; returns failure messages
    (empty list == all gates passed).  ``baseline`` may be a dict, a path
    to a JSON file, or None (then any ``BaselineRef`` gate fails with a
    missing-baseline message)."""
    if isinstance(baseline, (str, Path)):
        p = Path(baseline)
        if not p.exists():
            return [f"baseline file missing: {p}"]
        baseline = json.loads(p.read_text())

    errs: List[str] = []
    npass = 0
    for g in gates:
        ref = _resolve_ref(g.ref, baseline)
        if ref is _MISSING:
            errs.append(f"[{g.name}] baseline has no "
                        f"{g.ref.path!r} (needed by gate {g.path!r})")
            continue
        gate_errs: List[str] = []
        for cpath, val in lookup(record, g.path):
            if val is _MISSING:
                gate_errs.append(f"[{g.name}] record has no {cpath!r}")
                continue
            if not _OPS[g.op](val, ref):
                want = (f" {g.op} {ref}" if g.op not in ("truthy", "falsy")
                        else f" is not {g.op}")
                extra = f" — {g.message}" if g.message else ""
                gate_errs.append(f"[{g.name}] {cpath} = {val!r}{want}{extra}")
        if gate_errs and g.precondition:
            # the rest of the gates are meaningless; report only this
            if registry is not None:
                registry.inc(f"baseline.{bench}.skipped")
            return gate_errs
        errs.extend(gate_errs)
        npass += not gate_errs
    if registry is not None:
        registry.inc(f"baseline.{bench}.pass", npass)
        registry.inc(f"baseline.{bench}.fail", len(gates) - npass)
    return errs
