"""Host-side metrics registry: counters, gauges, and histograms that every
layer of the stack reports through instead of inventing its own dict.

Three metric kinds, all host-side Python state guarded by one re-entrant
lock (callbacks fired from XLA's thread pool may run concurrently with a
benchmark's ``reset()`` — see ``repro.mem.offload`` for the vmapped-chunk
case that motivated the locking):

  counter    monotonically increasing int (``inc``); host callbacks bump
             these when they EXECUTE, so under jit the counts are the
             measured runtime quantity, not a trace artifact;
  gauge      last-written float (``set_gauge``) — e.g. the planner's
             predicted peak bytes, a step's wall-clock;
  histogram  running (count, sum, min, max) summary (``observe``) — cheap
             enough to live in a hot host callback.

``snapshot()`` returns plain dicts (JSON-ready, used by the MetricsSink);
``reset()`` zeroes everything atomically.

Jit-safe counting (``JitCounter`` / ``FevalCounter``)
-----------------------------------------------------
A Python-side ``registry.inc`` inside traced code runs at *trace* time —
once per compilation, not once per execution.  ``JitCounter.tap(x)``
threads ``x`` through an identity ``jax.pure_callback`` whose host side
increments the counter, so compiled programs bump it once per runtime
execution of the tap site.  ``FevalCounter`` (promoted here from
``benchmarks/hotpath.py``) applies the tap to a vector field's ``t``
argument to count runtime f evaluations.

jax-0.4.37 caveat (unchanged from the hotpath original): ``pure_callback``
execution counts are only trustworthy **under jit** — compiled programs
execute the callback faithfully, while the eager tracing path may
constant-fold it away; and even under jit counts can drift +-1 per call
site across program variants (CSE merges same-``t`` tap sites, some
variants run a site once extra).  The artifact-immune measurement is
*invariance*: e.g. reverse NFE not growing with ``max_steps``
(``benchmarks/hotpath.py`` asserts exactly that).
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class MetricsRegistry:
    """Thread-safe named counters/gauges/histograms."""

    def __init__(self):
        self._lock = threading.RLock()
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Dict[str, float]] = {}

    # -- counters -----------------------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(n)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    # -- gauges -------------------------------------------------------------
    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def gauge(self, name: str) -> Optional[float]:
        with self._lock:
            return self._gauges.get(name)

    # -- histograms ---------------------------------------------------------
    def observe(self, name: str, value: float) -> None:
        value = float(value)
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = {"count": 0, "sum": 0.0, "min": value, "max": value}
                self._hists[name] = h
            h["count"] += 1
            h["sum"] += value
            h["min"] = min(h["min"], value)
            h["max"] = max(h["max"], value)

    def histogram(self, name: str) -> Optional[Dict[str, float]]:
        with self._lock:
            h = self._hists.get(name)
            return dict(h) if h is not None else None

    # -- bulk ---------------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict]:
        """JSON-ready copy of every metric."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: dict(v) for k, v in self._hists.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


#: process-wide default registry (the one ``spill_stats`` mirrors into and
#: the benchmarks snapshot); library code takes an explicit registry and
#: defaults to this one
DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return DEFAULT_REGISTRY


class JitCounter:
    """Count runtime executions of a tap site inside compiled programs.

    ``tap(x)`` returns ``x`` routed through an identity ``pure_callback``
    whose host side increments this counter (and, when a registry is
    given, the registry counter of the same name).  Because the tap is an
    identity on a *non-differentiated* value, wrapping a computation with
    it linearizes exactly like the original — gradients are unchanged.

    The tapped value must feed the downstream computation, or XLA
    dead-codes the callback away.  Counts are only trustworthy under jit
    (see module docstring for the jax-0.4.37 eager/CSE caveats).
    """

    def __init__(self, name: str = "jit_counter",
                 registry: Optional[MetricsRegistry] = None):
        self.name = name
        self.count = 0
        self._registry = registry

    def reset(self) -> None:
        self.count = 0

    def _bump(self, x):
        self.count += 1
        if self._registry is not None:
            self._registry.inc(self.name)
        return np.asarray(x)

    def tap(self, x):
        return jax.pure_callback(
            self._bump,
            jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), x)


class FevalCounter:
    """Wrap a vector field so each runtime evaluation bumps a host counter
    (identity pure_callback on t — on the non-diff path, so the wrapped f
    linearizes exactly like the original).  Only trustworthy under jit:
    compiled programs execute the callback faithfully, the eager tracing
    path may constant-fold it away (jax 0.4.37), and counts can drift +-1
    per call site (CSE/elision) — max_steps-invariance is the
    artifact-immune check (see ``benchmarks/hotpath.py``).  The wrapped f
    must actually USE t, or XLA dead-codes the tap."""

    def __init__(self, f: Callable, name: str = "nfe",
                 registry: Optional[MetricsRegistry] = None):
        self._f = f
        self._tap = JitCounter(name, registry)

    @property
    def count(self) -> int:
        return self._tap.count

    def reset(self) -> None:
        self._tap.reset()

    def __call__(self, u, theta, t):
        return self._f(u, theta, self._tap.tap(t))
