"""Profiler annotation helpers.

Two mechanisms, matched to where code runs:

``scope(name)``
    ``jax.named_scope`` wrapper for *traced* code: stamps the name into
    the HLO metadata of every op traced inside it, so ``jax.profiler``
    traces and HLO dumps show ``obs:forward`` / ``obs:reverse/seg3`` /
    ``obs:spill`` frames.  Purely trace-time metadata — no runtime op is
    added and numerics are untouched (named_scope participates in CSE
    like any unannotated op).

``host_annotation(name)``
    ``jax.profiler.TraceAnnotation`` for *host* code: wraps the body of a
    spill-store callback (or any host-side work) in a named profiler
    activity so the time XLA spends blocked on host I/O is attributed in
    the trace viewer.  Degrades to a no-op context manager when the
    profiler API is unavailable.
"""
from __future__ import annotations

import contextlib

import jax

PREFIX = "obs"


def scope(name: str):
    """Named scope for traced code: ``with scope("reverse/seg3"): ...``"""
    return jax.named_scope(f"{PREFIX}:{name}")


def host_annotation(name: str):
    """Profiler annotation for host-callback bodies; no-op if the
    profiler API is missing."""
    ta = getattr(jax.profiler, "TraceAnnotation", None)
    if ta is None:
        return contextlib.nullcontext()
    return ta(f"{PREFIX}:{name}")
