"""Shared benchmark utilities: timing, compiled-memory accounting, CSV."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
from jax import tree_util as jtu


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (s) of ``fn(*args)`` after warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def compiled_bytes(fn: Callable, *args) -> dict:
    """Compiler-accounted live-buffer bytes of the jitted fn — the CPU/TPU
    analogue of the paper's nvidia-smi GPU memory column (stronger: it is
    XLA's own temp+argument accounting, not an allocator high-water mark)."""
    jitted = jax.jit(fn)
    compiled = jitted.lower(*args).compile()
    mem = compiled.memory_analysis()
    if mem is None:  # backend without memory analysis
        return {"temp": -1, "argument": -1, "output": -1, "total": -1}
    d = {
        "temp": getattr(mem, "temp_size_in_bytes", -1),
        "argument": getattr(mem, "argument_size_in_bytes", -1),
        "output": getattr(mem, "output_size_in_bytes", -1),
    }
    d["total"] = d["temp"] + d["argument"]
    return d


class NFECounter:
    """Wrap a vector field to count true f evaluations at trace time."""

    def __init__(self, f):
        self.f = f
        self.n = 0

    def __call__(self, u, theta, t):
        self.n += 1
        return self.f(u, theta, t)

    def reset(self):
        self.n = 0


def fmt_row(*cells, widths=None) -> str:
    if widths is None:
        widths = [18] * len(cells)
    return "  ".join(str(c)[:w].ljust(w) for c, w in zip(cells, widths))


def gib(n: int | float) -> str:
    return f"{n / 2**30:.3f}"
