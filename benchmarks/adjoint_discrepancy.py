"""Paper Table 1 / Prop. 1: continuous-vs-discrete adjoint gradient
discrepancy and its O(h^2)-per-step decay, plus reverse-accuracy of every
discrete policy (gradients vs AD-through-solver at machine precision)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row
from repro.core.adjoint import odeint

jax.config.update("jax_enable_x64", True)

D = 10


def _problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    u0 = jax.random.normal(ks[0], (D,))
    th = {"W": 0.3 * jax.random.normal(ks[1], (D, D)),
          "b": 0.1 * jax.random.normal(ks[2], (D,))}

    def f(u, theta, t):
        return jnp.tanh(theta["W"] @ u + theta["b"])

    return f, u0, th


def grad_gap(policy: str, n_steps: int, method: str = "euler",
             horizon: float = 0.8, **kw) -> float:
    f, u0, th = _problem()
    dt = horizon / n_steps

    def gof(pol, kw_):
        def L(u0):
            return jnp.sum(odeint(f, u0, th, dt=dt, n_steps=n_steps,
                                  method=method, adjoint=pol, **kw_) ** 2)
        return jax.grad(L)(u0)

    g = gof(policy, kw)
    g_ref = gof("naive", {})
    return float(jnp.max(jnp.abs(g - g_ref)) / jnp.max(jnp.abs(g_ref)))


def main() -> None:
    print("== adjoint_discrepancy (paper Table 1 / Prop. 1) ==")
    print(fmt_row("method", "N_t", "cont rel-gap", "ratio", "pnode rel-gap",
                  widths=[10, 6, 14, 8, 14]))
    for method in ("euler", "midpoint", "rk4"):
        prev = None
        for n in (10, 20, 40, 80):
            gap_c = grad_gap("continuous", n, method)
            gap_p = grad_gap("pnode", n, method)
            ratio = "" if prev is None else f"{prev / gap_c:.2f}"
            print(fmt_row(method, n, f"{gap_c:.3e}", ratio, f"{gap_p:.1e}",
                          widths=[10, 6, 14, 8, 14]))
            prev = gap_c
    print("(cont ratio ~2 per halving of h at fixed horizon = O(h) global,"
          " O(h^2) per step; pnode pinned at machine eps)")


if __name__ == "__main__":
    main()
