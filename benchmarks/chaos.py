"""Benchmark 5: chaos — train and differentiate under an injected fault
schedule, and prove recovery is *exact*, not just "doesn't crash".

Three layers, one deterministic ``repro.ft.FaultPlan`` each:

  solver   a Robertson ensemble gradient through the scanned pnode+spill
           path with the acceptance-criteria schedule — one NaN-poisoned
           f-eval step, one forced Newton divergence, one corrupted spill
           payload, one transient read flake — under
           ``rescue=True, resilient=True``.  The gates pin: gradients
           bitwise-identical to the fault-free run (rescue retries
           converge to the same bits; the corrupted segment is recomputed
           from its entry state), exactly 2 rescued steps, >= 1 integrity
           failure detected, >= 1 read retry, and the host-callback count
           unchanged by all of it.

  train    the LM loop under ``launch.train``'s sentinel: a single
           poisoned step is skipped and retried (loss curve bitwise equal
           to fault-free), and a 3-step poison window forces one rollback
           to the last committed checkpoint with an exact replay.

  adaptive the Dopri5 controller under NaN-poisoned attempts: the solve
           completes with finite output, the poisoned attempts show up as
           rejections (recovery here is convergent, not bitwise — the
           step-size trajectory legitimately changes).

Counter reads sit behind ``jax.block_until_ready`` (jitted calls return
before host callbacks run), and the faulted gradient is measured WITHOUT a
warmup call: the fault plan's host-side ticks are keyed by callback
execution index, so the first execution must be the measured one.
"""
import json
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core.adaptive import odeint_adaptive
from repro.core.implicit import odeint_implicit
from repro.ft import FaultPlan, FaultSpec
from repro.mem.offload import reset_spill_stats, spill_stats
from repro.obs import (DEFAULT_REGISTRY, BaselineRef, Gate,
                       check_against_baseline as _obs_check)

from benchmarks.stiff_ensemble import robertson_vf

N_STEPS = 16
SEGMENT = 4
DT = 0.01


def _newton_faults():
    # the acceptance-criteria schedule: one NaN step + one forced Newton
    # divergence, both keyed by absolute step index so adjoint recomputes
    # re-fire (and re-rescue) them identically
    return [FaultSpec("newton", 2, "nan"),
            FaultSpec("newton", 9, "diverge")]


def _loss(c, u0s, *, fault_plan=None, rescue=None, resilient=False):
    def solve(u, ci):
        return odeint_implicit(robertson_vf, u, ci, dt=DT, n_steps=N_STEPS,
                               method="cn", adjoint="pnode", offload="spill",
                               offload_segment=SEGMENT, newton_iters=16,
                               newton_tol=1e-10, gmres_iters=5,
                               gmres_tol=1e-12, fault_plan=fault_plan,
                               rescue=rescue, resilient=resilient)

    uf = jax.vmap(solve)(u0s, c)
    return jnp.mean(jnp.sum(uf ** 2, axis=-1))


def run_solver_chaos(batch=32, seed=0):
    u0s = jnp.tile(jnp.array([1.0, 0.0, 0.0]), (batch, 1))
    c = 0.1 * jax.random.normal(jax.random.PRNGKey(seed), (batch, 3))

    # fault-free reference: the plain PR-6 spill path, no recovery knobs
    g_clean = jax.jit(jax.grad(lambda cc: _loss(cc, u0s)))(c)
    jax.block_until_ready(g_clean)

    # full schedule: solver faults + storage faults, all recovery on
    plan = FaultPlan(_newton_faults() + [
        FaultSpec("spill.write", 1, "corrupt"),  # segment 1's payload
        FaultSpec("spill.read", 0, "flake"),     # transient: one retry
    ])
    faulted = jax.jit(jax.grad(
        lambda cc: _loss(cc, u0s, fault_plan=plan, rescue=True,
                         resilient=True)))
    reset_spill_stats()
    g_fault = faulted(c)  # NO warmup: tick indices must start at 0
    jax.block_until_ready(g_fault)
    io = spill_stats()

    # rescued-step count from the stats plumbing (fresh plan instance so
    # the storage tick counters above stay undisturbed)
    plan_stats = FaultPlan(_newton_faults())
    _, stats = jax.jit(jax.vmap(lambda u, ci: odeint_implicit(
        robertson_vf, u, ci, dt=DT, n_steps=N_STEPS, method="cn",
        newton_iters=16, newton_tol=1e-10, gmres_iters=5, gmres_tol=1e-12,
        fault_plan=plan_stats, rescue=True, return_stats=True)))(u0s, c)

    return {
        "n_steps": N_STEPS,
        "segment": SEGMENT,
        "ensemble": int(batch),
        "faults_fired": int(plan.fired_count()),
        "grads_bitwise": bool(np.array_equal(np.asarray(g_fault),
                                             np.asarray(g_clean))),
        "rescued_per_solve": int(np.max(np.asarray(stats.rescued))),
        "diverged": bool(np.any(np.asarray(stats.diverged))),
        "integrity_failures": int(io["integrity_fail"]),
        "read_retries": int(io["retry_cb"]),
        "callbacks_per_grad": int(io["write_cb"] + io["read_cb"]),
    }


def run_train_chaos(steps=8, ckpt_every=4):
    from repro.configs.base import ShapeCell, reduced
    from repro.configs.registry import get_arch
    from repro.launch.train import train

    cfg = reduced(get_arch("smollm-135m"), n_layers=2)
    cell = ShapeCell("chaos", 32, 2, "train")
    quiet = lambda *a, **k: None

    with tempfile.TemporaryDirectory() as tmp:
        clean = train(cfg, cell, steps=steps, ckpt_dir=f"{tmp}/clean",
                      ckpt_every=ckpt_every, log_fn=quiet)

        # one poisoned attempt: skipped, retried clean, curve bitwise
        skip = train(cfg, cell, steps=steps, ckpt_dir=f"{tmp}/skip",
                     ckpt_every=ckpt_every, log_fn=quiet,
                     fault_plan=FaultPlan(
                         [FaultSpec("train.step", 3, "nan")]))

        # K consecutive poisoned attempts: rollback + exact replay
        k = 3
        roll = train(cfg, cell, steps=steps, ckpt_dir=f"{tmp}/roll",
                     ckpt_every=ckpt_every, log_fn=quiet,
                     sentinel_bad_steps=k, fault_plan=FaultPlan(
                         [FaultSpec("train.step", ckpt_every + 1, "nan",
                                    count=k)]))

    return {
        "steps": int(steps),
        "skip_run": {
            "skipped_steps": int(skip["skipped_steps"]),
            "rollbacks": int(skip["rollbacks"]),
            "losses_equal": bool(skip["losses"] == clean["losses"]),
        },
        "rollback_run": {
            "skipped_steps": int(roll["skipped_steps"]),
            "rollbacks": int(roll["rollbacks"]),
            "losses_equal": bool(roll["losses"] == clean["losses"]),
        },
    }


def run_adaptive_chaos():
    def f(u, th, t):
        return -th * u

    u0 = jnp.ones(4)
    th = jnp.asarray(0.9)
    plan = FaultPlan([FaultSpec("adaptive", 2, "nan", count=2)])
    uf, info = odeint_adaptive(f, u0, th, t0=0.0, t1=1.0, max_steps=64,
                               fault_plan=plan)
    uf_clean, _ = odeint_adaptive(f, u0, th, t0=0.0, t1=1.0, max_steps=64)
    return {
        "finite": bool(np.all(np.isfinite(np.asarray(uf)))),
        "completed": bool(int(info.n_accepted) > 0),
        "n_rejected": int(info.n_rejected),
        "endpoint_close": bool(np.allclose(np.asarray(uf),
                                           np.asarray(uf_clean),
                                           rtol=1e-5)),
    }


GATES = [
    Gate("grads_bitwise", "solver.grads_bitwise", "truthy",
         message="post-recovery gradients are not bitwise-identical to "
                 "the fault-free run"),
    Gate("rescued", "solver.rescued_per_solve", "==",
         BaselineRef("rescued_per_solve"),
         message="rescued-step count drifted from the injected schedule"),
    Gate("not_diverged", "solver.diverged", "falsy",
         message="a rescued solve still reports divergence"),
    Gate("integrity", "solver.integrity_failures", ">=",
         BaselineRef("min_integrity_failures"),
         message="the corrupted spill payload was not detected"),
    Gate("retries", "solver.read_retries", ">=",
         BaselineRef("min_read_retries"),
         message="the transient read flake was not retried"),
    Gate("callbacks", "solver.callbacks_per_grad", "<=",
         BaselineRef("max_callbacks_per_grad"),
         message="recovery added host callbacks to the gradient"),
    Gate("train_skip_curve", "train.skip_run.losses_equal", "truthy",
         message="loss curve after a skipped step is not bitwise the "
                 "fault-free curve"),
    Gate("train_skipped", "train.skip_run.skipped_steps", "==",
         BaselineRef("expected_skipped"),
         message="sentinel skip count drifted from the injected schedule"),
    Gate("train_rollback_curve", "train.rollback_run.losses_equal",
         "truthy", message="loss curve after rollback+replay is not "
                           "bitwise the fault-free curve"),
    Gate("train_rollbacks", "train.rollback_run.rollbacks", "==",
         BaselineRef("expected_rollbacks"),
         message="rollback count drifted from the injected schedule"),
    Gate("adaptive_finite", "adaptive.finite", "truthy",
         message="adaptive solve went non-finite under poisoned attempts"),
    Gate("adaptive_rejected", "adaptive.n_rejected", ">=",
         BaselineRef("min_adaptive_rejected"),
         message="poisoned adaptive attempts were not rejected"),
]


def check_against_baseline(rec, baseline_path="benchmarks/"
                           "bench5_baseline.json"):
    """Regression gates for CI; returns a list of error strings."""
    return _obs_check(rec, GATES, baseline_path, bench="chaos",
                      registry=DEFAULT_REGISTRY)


def main(smoke=False, out_path="BENCH_5.json", check=False):
    rec = {
        "solver": run_solver_chaos(batch=32 if smoke else 128),
        "train": run_train_chaos(steps=8 if smoke else 12),
        "adaptive": run_adaptive_chaos(),
        "smoke": bool(smoke),
    }
    with open(out_path, "w") as fh:
        json.dump(rec, fh, indent=2)
    print(json.dumps(rec, indent=2))
    if check:
        errs = check_against_baseline(rec)
        if errs:
            for e in errs:
                print(f"BENCH_5 REGRESSION: {e}", file=sys.stderr)
            raise SystemExit(1)
        print("BENCH_5: all regression gates passed")
    return rec


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv, check="--check" in sys.argv)
