"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick versions
  PYTHONPATH=src python -m benchmarks.run --full     # full sweeps
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: mem_plan +
                                                    # hotpath +
                                                    # stiff_ensemble +
                                                    # chaos + longhaul
                                                    # + serve_load;
                                                    # writes
                                                    # BENCH_2/3/4/5/6/7
                                                    # .json, fails on
                                                    # host-callback,
                                                    # NFE-B, fault-
                                                    # recovery, multi-
                                                    # tier, or serving
                                                    # regressions
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    full = "--full" in sys.argv

    if "--smoke" in sys.argv:
        from benchmarks import (chaos, hotpath, longhaul, mem_plan,
                                serve_load, stiff_ensemble)
        from repro.obs import DEFAULT_REGISTRY, MetricsSink
        t0 = time.time()
        # METRICS.jsonl: per-section structured records + the unified
        # baseline-gate counters, uploaded as a CI artifact.  The sink
        # flushes per record, so a failing gate (SystemExit) still leaves
        # every completed section's record on disk.
        with MetricsSink("METRICS.jsonl") as sink:
            mem_plan.main(smoke=True)
            sink.emit("bench.section", section="mem_plan",
                      elapsed_s=time.time() - t0)
            t1 = time.time()
            rec3 = hotpath.main(smoke=True, check=True)
            sink.emit(
                "bench.section", section="hotpath",
                elapsed_s=time.time() - t1,
                callbacks_per_reverse_pass=rec3["spill_io"][
                    "callbacks_per_reverse_pass"],
                spill_grads_bitwise=rec3["spill_io"][
                    "grads_bitwise_identical"],
                reverse_fevals=rec3["adaptive"]["reverse_fevals"],
                nfe_invariant_in_max_steps=rec3["adaptive"][
                    "invariant_in_max_steps"])
            t2 = time.time()
            rec4 = stiff_ensemble.main(smoke=True, check=True)
            sink.emit(
                "bench.section", section="stiff_ensemble",
                elapsed_s=time.time() - t2,
                callbacks_per_grad=rec4["callbacks_per_grad"],
                nfe_backward=rec4["plan"]["nfe_backward"],
                grads_bitwise_vs_device=rec4["grads_bitwise_vs_device"],
                diverged_fraction=rec4["diverged_fraction"],
                losses=rec4["losses"])
            t3 = time.time()
            rec5 = chaos.main(smoke=True, check=True)
            sink.emit(
                "bench.section", section="chaos",
                elapsed_s=time.time() - t3,
                grads_bitwise=rec5["solver"]["grads_bitwise"],
                rescued_per_solve=rec5["solver"]["rescued_per_solve"],
                integrity_failures=rec5["solver"]["integrity_failures"],
                read_retries=rec5["solver"]["read_retries"],
                callbacks_per_grad=rec5["solver"]["callbacks_per_grad"],
                train_skip_bitwise=rec5["train"]["skip_run"][
                    "losses_equal"],
                train_rollback_bitwise=rec5["train"]["rollback_run"][
                    "losses_equal"])
            t4 = time.time()
            rec6 = longhaul.main(smoke=True, check=True)
            sink.emit(
                "bench.section", section="longhaul",
                elapsed_s=time.time() - t4,
                fixed_callbacks_per_grad=rec6["fixed"][
                    "callbacks_per_grad"],
                fixed_ram_peak_under_budget=rec6["fixed"][
                    "ram_peak_under_budget"],
                fixed_disk_write_bytes=rec6["fixed"]["disk_write_bytes"],
                adaptive_forward_cb_within_bound=rec6["adaptive"][
                    "forward_cb_within_bound"],
                bitwise_disk=rec6["bitwise"]["disk"],
                bitwise_split=rec6["bitwise"]["split"],
                bitwise_disk_vs_host=rec6["bitwise"]["disk_vs_host"])
            t5 = time.time()
            rec7 = serve_load.main(smoke=True, check=True)
            sink.emit(
                "bench.section", section="serve_load",
                elapsed_s=time.time() - t5,
                requests_per_s=rec7["load"]["requests_per_s"],
                latency_p50_s=rec7["load"]["latency_p50_s"],
                latency_p99_s=rec7["load"]["latency_p99_s"],
                batch_occupancy_mean=rec7["load"]["batch_occupancy_mean"],
                callbacks_per_request=rec7["load"]["callbacks_per_request"],
                census_empty=rec7["load"]["census_empty"])
            sink.emit("bench.gates",
                      **{k: v for k, v in
                         DEFAULT_REGISTRY.snapshot()["counters"].items()
                         if k.startswith("baseline.")})
        print(f"\n== bench smoke done in {time.time()-t0:.1f}s ==")
        return

    from benchmarks import (adjoint_discrepancy, chaos, cnf_tables,
                            fig3_memory, hotpath, longhaul, mem_plan,
                            roofline, serve_load, stiff_ensemble,
                            stiff_table8, table2_costs)

    sections = [
        ("adjoint_discrepancy (Table 1 / Prop 1)",
         adjoint_discrepancy.main),
        ("table2_costs (Table 2)", table2_costs.main),
        ("cnf_tables (Tables 3-7)",
         lambda: cnf_tables.main(quick=not full)),
        ("stiff_table8 (Table 8 / Fig 5)", stiff_table8.main),
        ("fig3_memory (Fig 3)", fig3_memory.main),
        ("mem_plan (planner / BENCH_2.json)", mem_plan.main),
        ("hotpath (reverse-pass hot path / BENCH_3.json)", hotpath.main),
        ("stiff_ensemble (vmapped implicit under budget / BENCH_4.json)",
         stiff_ensemble.main),
        ("chaos (fault injection + recovery / BENCH_5.json)", chaos.main),
        ("longhaul (multi-tier long-horizon / BENCH_6.json)",
         longhaul.main),
        ("serve_load (continuous-batching serve / BENCH_7.json)",
         serve_load.main),
        ("roofline (EXPERIMENTS Roofline)", roofline.main),
    ]

    t00 = time.time()
    failures = []
    for name, fn in sections:
        print(f"\n######## {name} ########")
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 - report and continue
            failures.append((name, e))
            print(f"SECTION FAILED: {type(e).__name__}: {e}")
        print(f"[{name}: {time.time()-t0:.1f}s]")
    print(f"\n== benchmarks done in {time.time()-t00:.1f}s; "
          f"{len(failures)} failed sections ==")
    for name, e in failures:
        print(f"  FAILED {name}: {type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
