"""Roofline report: reads the dry-run JSONs under experiments/dryrun/ and
prints the per-(arch x shape x mesh) three-term roofline table used in
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import fmt_row

ROOT = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh: str = "pod", tag: str = "") -> list[dict]:
    out = []
    d = ROOT / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag", "") == tag:
            out.append(rec)
    return out


def main() -> None:
    for mesh in ("pod", "multipod"):
        recs = load(mesh)
        if not recs:
            print(f"(no dry-run records for mesh={mesh}; run "
                  f"`python -m repro.launch.dryrun --all --mesh {mesh}`)")
            continue
        print(f"== roofline ({mesh}) ==")
        print(fmt_row("arch", "shape", "compute_s", "memory_s", "coll_s",
                      "dominant", "useful/HLO", "hbm GiB/dev",
                      widths=[24, 12, 10, 10, 10, 10, 10, 11]))
        n_ok = n_skip = 0
        for r in recs:
            if r["status"] == "skipped":
                n_skip += 1
                print(fmt_row(r["arch"], r["shape"], "-", "-", "-", "SKIP",
                              "-", "-",
                              widths=[24, 12, 10, 10, 10, 10, 10, 11]))
                continue
            n_ok += 1
            rr = r["roofline"]
            mem = r.get("memory") or {}
            hbm = (mem.get("temp_bytes") or 0) + (mem.get("argument_bytes")
                                                  or 0)
            print(fmt_row(
                r["arch"], r["shape"], f"{rr['compute_s']:.4f}",
                f"{rr['memory_s']:.4f}", f"{rr['collective_s']:.4f}",
                rr["dominant"], f"{r['useful_flops_ratio']:.3f}",
                f"{hbm / 2**30:.2f}",
                widths=[24, 12, 10, 10, 10, 10, 10, 11]))
        print(f"{n_ok} ok, {n_skip} skipped")


if __name__ == "__main__":
    main()
