"""Roofline report: reads the dry-run JSONs under experiments/dryrun/ and
prints the per-(arch x shape x mesh) three-term roofline table used in
EXPERIMENTS.md §Roofline, plus an odeint section that rooflines the
adjoint REVERSE pass (not just the forward solve) so the fused-stage
kernels' effect on the hot path is visible in the same units."""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import fmt_row

ROOT = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _cost(compiled) -> dict:
    """flops / bytes accessed from XLA's cost analysis (list on jax
    0.4.37, dict on newer), -1 when unavailable."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {"flops": -1.0, "bytes": -1.0}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {"flops": -1.0, "bytes": -1.0}
    return {"flops": float(ca.get("flops", -1.0)),
            "bytes": float(ca.get("bytes accessed", -1.0))}


def odeint_reverse_roofline() -> list[dict]:
    """Forward vs reverse (grad) roofline rows for the pnode adjoint, with
    and without the fused Pallas stage kernels."""
    import jax
    import jax.numpy as jnp

    D, HID, BATCH = 32, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    u0 = jax.random.normal(ks[0], (BATCH, D))
    th = {"w1": 0.05 * jax.random.normal(ks[1], (D, HID)),
          "w2": 0.05 * jax.random.normal(ks[2], (HID, D))}

    def f(u, theta, t):
        return jnp.tanh(u @ theta["w1"]) @ theta["w2"]

    from repro.core.adjoint import odeint
    from repro.launch.hlo_cost import peak_live_bytes

    rows = []
    print("== roofline (odeint adjoint: forward AND reverse pass) ==")
    print(fmt_row("variant", "pass", "Mflops", "MB moved", "hlo peak B",
                  "wall_ms", widths=[18, 8, 10, 10, 12, 9]))
    for fused in (False, True):
        kw = dict(dt=0.05, n_steps=32, method="rk4", adjoint="pnode",
                  fused_stages=fused)

        def fwd_fn(u0_, th_):
            return odeint(f, u0_, th_, **kw)

        def loss(u0_, th_):
            return jnp.sum(fwd_fn(u0_, th_) ** 2)

        for name, fn in (("forward", fwd_fn),
                         ("reverse", jax.grad(loss, argnums=(0, 1)))):
            compiled = jax.jit(fn).lower(u0, th).compile()
            c = _cost(compiled)
            peak = peak_live_bytes(compiled.as_text())
            jax.block_until_ready(compiled(u0, th))  # warm the executable
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(u0, th))
            wall = time.perf_counter() - t0
            row = {"variant": "fused" if fused else "unfused",
                   "pass": name, "flops": c["flops"], "bytes": c["bytes"],
                   "hlo_peak_bytes": float(peak), "wall_s": wall}
            rows.append(row)
            print(fmt_row(row["variant"], name, f"{c['flops']/1e6:.2f}",
                          f"{c['bytes']/2**20:.2f}", f"{peak:.0f}",
                          f"{wall*1e3:.2f}", widths=[18, 8, 10, 10, 12, 9]))
    return rows


def load(mesh: str = "pod", tag: str = "") -> list[dict]:
    out = []
    d = ROOT / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag", "") == tag:
            out.append(rec)
    return out


def main() -> None:
    odeint_reverse_roofline()
    for mesh in ("pod", "multipod"):
        recs = load(mesh)
        if not recs:
            print(f"(no dry-run records for mesh={mesh}; run "
                  f"`python -m repro.launch.dryrun --all --mesh {mesh}`)")
            continue
        print(f"== roofline ({mesh}) ==")
        print(fmt_row("arch", "shape", "compute_s", "memory_s", "coll_s",
                      "dominant", "useful/HLO", "hbm GiB/dev",
                      widths=[24, 12, 10, 10, 10, 10, 10, 11]))
        n_ok = n_skip = 0
        for r in recs:
            if r["status"] == "skipped":
                n_skip += 1
                print(fmt_row(r["arch"], r["shape"], "-", "-", "-", "SKIP",
                              "-", "-",
                              widths=[24, 12, 10, 10, 10, 10, 10, 11]))
                continue
            n_ok += 1
            rr = r["roofline"]
            mem = r.get("memory") or {}
            hbm = (mem.get("temp_bytes") or 0) + (mem.get("argument_bytes")
                                                  or 0)
            print(fmt_row(
                r["arch"], r["shape"], f"{rr['compute_s']:.4f}",
                f"{rr['memory_s']:.4f}", f"{rr['collective_s']:.4f}",
                rr["dominant"], f"{r['useful_flops_ratio']:.3f}",
                f"{hbm / 2**30:.2f}",
                widths=[24, 12, 10, 10, 10, 10, 10, 11]))
        print(f"{n_ok} ok, {n_skip} skipped")


if __name__ == "__main__":
    main()
