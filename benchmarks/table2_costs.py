"""Paper Table 2: per-policy forward/reverse computation, recomputation
overhead, and memory — both the analytic model and *measured* quantities
(counted NFE + XLA compiled temp bytes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import NFECounter, compiled_bytes, fmt_row, gib
from repro.core.adjoint import (checkpoint_floats, nfe_backward, nfe_forward,
                                odeint)

D = 256        # state dim (wide enough that checkpoint bytes dominate)
HID = 512


def _problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    u0 = jax.random.normal(ks[0], (8, D))
    th = {"w1": 0.05 * jax.random.normal(ks[1], (D, HID)),
          "w2": 0.05 * jax.random.normal(ks[2], (HID, D))}

    def f(u, theta, t):
        return jnp.tanh(u @ theta["w1"]) @ theta["w2"]

    return f, u0, th


POLICIES = [("naive", {}), ("continuous", {}), ("anode", {}), ("aca", {}),
            ("pnode", {}), ("pnode2", {}), ("revolve", {"ncheck": 4}),
            ("revolve2", {"ncheck": 4})]


def main(method: str = "rk4", n_steps: int = 16) -> None:
    print(f"== table2_costs ({method}, N_t={n_steps}) ==")
    print(fmt_row("policy", "NFE-F", "NFE-B", "NFE-B(model)", "grad MiB",
                  "ckpt model (floats)",
                  widths=[12, 7, 7, 13, 10, 20]))
    f, u0, th = _problem()

    for pol, kw in POLICIES:
        counter = NFECounter(f)

        def L(u0, th):
            uf = odeint(counter, u0, th, dt=0.05, n_steps=n_steps,
                        method=method, adjoint=pol, **kw)
            return jnp.sum(uf ** 2)

        counter.reset()
        with jax.disable_jit():
            jax.grad(L, argnums=(0, 1))(u0, th)
        measured_total = counter.n
        nfe_f = nfe_forward(method, n_steps)
        nfe_b = measured_total - nfe_f

        mem = compiled_bytes(
            lambda u0, th: jax.grad(L, argnums=(0, 1))(u0, th), u0, th)
        model_b = nfe_backward(method, n_steps, pol, kw.get("ncheck"))
        ck = checkpoint_floats(method, n_steps, pol, state_size=8 * D,
                               ncheck=kw.get("ncheck"))
        print(fmt_row(pol, nfe_f, nfe_b, model_b,
                      f"{mem['temp'] / 2**20:.2f}", ck,
                      widths=[12, 7, 7, 13, 10, 20]))


if __name__ == "__main__":
    main()
