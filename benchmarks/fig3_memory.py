"""Paper Fig. 3: memory growth vs number of time steps N_t per policy.

Memory = XLA's compiled live-buffer accounting (temp + args) of the jitted
loss-and-grad — the compiler's own statement of what must be resident.
The paper's claims to reproduce:
  * NODE-naive grows ~N_t * N_s * N_l (steepest),
  * ACA / PNODE2 grow ~N_t (solutions only),
  * PNODE grows ~N_t * (N_s+1) but with NO NN graph inside (shallow),
  * NODE-cont is flat,
  * slope(PNODE)/slope(naive) ~ (N_s+1)/(N_s*N_l-ish)  — big savings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_bytes, fmt_row
from repro.core.adjoint import odeint

D, HID, BATCH = 128, 256, 16


def _problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    u0 = jax.random.normal(ks[0], (BATCH, D))
    th = {"w1": 0.05 * jax.random.normal(ks[1], (D, HID)),
          "w2": 0.05 * jax.random.normal(ks[2], (HID, HID)),
          "w3": 0.05 * jax.random.normal(ks[3], (HID, D))}

    def f(u, theta, t):
        h = jnp.tanh(u @ theta["w1"])
        h = jnp.tanh(h @ theta["w2"])
        return h @ theta["w3"]

    return f, u0, th


POLICIES = [("naive", {}), ("continuous", {}), ("aca", {}), ("pnode", {}),
            ("pnode2", {}), ("revolve", {"ncheck": 4}),
            ("revolve2", {"ncheck": 4})]


def main(method: str = "dopri5") -> None:
    from repro.mem.model import f_activation_bytes, policy_cost, tree_bytes

    f, u0, th = _problem()
    nts = (2, 5, 8, 11)
    state_b = tree_bytes(u0)
    theta_b = tree_bytes(th)
    fa = f_activation_bytes(f, u0, th)
    print(f"== fig3_memory ({method}): compiled temp bytes (MiB) vs N_t, "
          "measured | model-predicted ==")
    print(fmt_row("policy", *[f"N_t={n}" for n in nts], "slope MiB/step",
                  widths=[12] + [14] * len(nts) + [15]))
    rows = {}
    for pol, kw in POLICIES:
        mibs, preds = [], []
        for n in nts:
            # the planner's validity rule: at most one slot per step
            nkw = {k: min(v, n - 1) for k, v in kw.items()}

            def L(u0, th):
                uf = odeint(f, u0, th, dt=0.5 / n, n_steps=n, method=method,
                            adjoint=pol, **nkw)
                return jnp.sum(uf ** 2)

            mem = compiled_bytes(
                lambda u0, th: jax.grad(L, argnums=(0, 1))(u0, th), u0, th)
            mibs.append(mem["temp"] / 2 ** 20)
            preds.append(policy_cost(
                pol, method=method, n_steps=n, state_bytes=state_b,
                theta_bytes=theta_b, f_act_bytes=fa,
                ncheck=nkw.get("ncheck")).peak_bytes / 2 ** 20)
        slope = (mibs[-1] - mibs[0]) / (nts[-1] - nts[0])
        rows[pol] = slope
        print(fmt_row(pol, *[f"{m:.2f}|{p:.2f}" for m, p in zip(mibs, preds)],
                      f"{slope:.3f}",
                      widths=[12] + [14] * len(nts) + [15]))
    if rows.get("naive", 0) > 0:
        print(f"PNODE slope / naive slope = "
              f"{rows['pnode'] / rows['naive']:.3f} "
              f"(paper: ~71% memory saved at dopri5 N_t=11)")


if __name__ == "__main__":
    main()
