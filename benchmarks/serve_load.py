"""Serving load benchmark (BENCH_7): open-loop request stream against the
``repro.serve`` ODE engine — the paper workload (CNF log-density and
score over a concatsquash field) as a service, reverse passes running
through the lane-keyed spill store.

Open-loop means arrivals do NOT wait for completions: ``arrive_per_step``
fresh requests join the queue before every scheduling quantum regardless
of how the engine is doing, so queueing delay shows up in the latency
tail instead of being hidden by a closed feedback loop.  The arrival
schedule is deterministic (tick-based, seeded payloads) — wall-clock
numbers vary with the host, the *counts* (callbacks per request, batch
occupancy, census) do not, and only count-like quantities are gated.

Reported (BENCH_7.json, gated vs ``bench7_baseline.json`` through the
unified ``repro.obs.baseline`` checker):

  requests/sec           completed requests over the measured wall
  p50/p99 latency        submit→resolve wall seconds (and the
                         deterministic tick-latency alongside)
  batch occupancy        mean real-lanes/bucket over every served batch
  callbacks-per-request  spill-store host round-trips (write + read +
                         dispatch + prefetch-hit) per completed request
  census                 every store empty after the drain (departures
                         freed their slots)
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.mem.offload import reset_spill_stats, spill_stats
from repro.models.ode_nets import cnf_vf, cnf_vf_init
from repro.obs import (DEFAULT_REGISTRY, BaselineRef, Gate, MetricsRegistry,
                       check_against_baseline as _obs_check)
from repro.serve import BucketSpec, ODEEngine

BASELINE_PATH = Path(__file__).resolve().parent / "bench7_baseline.json"

DIM = 4


def _percentile(xs, q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


def bench_load(n_requests: int, arrive_per_step: int, n_steps: int,
               segment: int, snaps_in_ram: int, score_every: int = 3,
               seed: int = 0) -> dict:
    """Drive ``n_requests`` through the engine open-loop; every
    ``score_every``-th request is a score (reverse-pass) request, the rest
    are forward densities — so the spill store sees a realistic mixed
    read/write stream while forward traffic stays checkpoint-free."""
    theta = cnf_vf_init(jax.random.PRNGKey(seed), DIM, hidden=(16, 16))
    registry = MetricsRegistry()
    engine = ODEEngine(cnf_vf, theta, dim=DIM, dt=0.05, n_steps=n_steps,
                       method="rk4", offload="spill",
                       offload_segment=segment, snaps_in_ram=snaps_in_ram,
                       buckets=BucketSpec((1, 2, 4, 8)),
                       registry=registry)
    engine.warmup()  # compiles happen outside the measured window
    rng = np.random.default_rng(seed)
    payloads = rng.normal(size=(n_requests, DIM)).astype(np.float32)

    reset_spill_stats()
    pending: list = []
    lat_s: list = []
    lat_ticks: list = []
    submitted = 0
    quanta = 0
    t_start = time.perf_counter()
    while submitted < n_requests or pending:
        # open-loop arrivals: a fixed number per quantum, never gated on
        # completions
        for _ in range(arrive_per_step):
            if submitted >= n_requests:
                break
            kind = "score" if submitted % score_every == 0 else "density"
            tk = engine.submit(kind, payloads[submitted])
            pending.append((tk, time.perf_counter()))
            submitted += 1
        engine.step()
        quanta += 1
        now = time.perf_counter()
        still = []
        for tk, ts in pending:
            if tk.done():
                lat_s.append(now - ts)
                lat_ticks.append(tk.latency_ticks)
            else:
                still.append((tk, ts))
        pending = still
        if quanta > 100 * n_requests:
            raise RuntimeError("serve_load failed to drain")
    wall = time.perf_counter() - t_start

    st = spill_stats()
    cbs = (st["write_cb"] + st["read_cb"] + st["dispatch_cb"]
           + st["prefetch_hit_cb"])
    occ = registry.histogram("serve.batch_occupancy") or {}
    census = engine.slot_census()
    rec = {
        "n_requests": n_requests,
        "arrive_per_step": arrive_per_step,
        "n_steps": n_steps, "segment": segment,
        "snaps_in_ram": snaps_in_ram,
        "completed": registry.counter("serve.completed"),
        "errors": registry.counter("serve.errors"),
        "wall_s": wall,
        "requests_per_s": n_requests / max(wall, 1e-9),
        "latency_p50_s": _percentile(lat_s, 50),
        "latency_p99_s": _percentile(lat_s, 99),
        "latency_p50_ticks": _percentile(lat_ticks, 50),
        "latency_p99_ticks": _percentile(lat_ticks, 99),
        "batch_occupancy_mean": (occ.get("sum", 0.0)
                                 / max(occ.get("count", 0), 1)),
        "callbacks_total": cbs,
        "callbacks_per_request": cbs / n_requests,
        "write_cb": st["write_cb"], "read_cb": st["read_cb"],
        "dispatch_cb": st["dispatch_cb"],
        "prefetch_hit_cb": st["prefetch_hit_cb"],
        "census_after_drain": census,
        "census_empty": not any(census.values()),
    }
    print(f"load: {n_requests} reqs in {wall:.2f}s "
          f"({rec['requests_per_s']:.1f} req/s), "
          f"p50 {rec['latency_p50_s']*1e3:.1f} ms / "
          f"p99 {rec['latency_p99_s']*1e3:.1f} ms, "
          f"occupancy {rec['batch_occupancy_mean']:.2f}, "
          f"{rec['callbacks_per_request']:.1f} cb/req, "
          f"census empty: {rec['census_empty']}")
    return rec


#: BENCH_7 regression gates.  Wall-clock metrics (req/s, latency) are
#: recorded but NOT gated — CI hosts vary; the gates hold the
#: deterministic invariants: every request completes, callbacks per
#: request stay at the recorded O(n_steps/segment) level, batching
#: actually happens, and the stores drain empty.
GATES = [
    Gate("smoke_config", "load.n_requests", "==",
         BaselineRef("smoke_n_requests"), precondition=True,
         message="callback counts scale with request count; the baseline "
                 "is recorded for the --smoke configuration — re-run "
                 "with --smoke to compare against it"),
    Gate("all_completed", "load.completed", "==",
         BaselineRef("smoke_n_requests"),
         message="not every admitted request completed"),
    Gate("no_errors", "load.errors", "==", 0,
         message="fault-free load run produced request errors"),
    Gate("callbacks_bounded", "load.callbacks_total", "<=",
         BaselineRef("callbacks_total_max"),
         message="spill callbacks per request regressed past the "
                 "recorded bound (lane-keyed batching is degrading)"),
    Gate("occupancy", "load.batch_occupancy_mean", ">=",
         BaselineRef("occupancy_min"),
         message="mean batch occupancy fell below the recorded floor — "
                 "the scheduler stopped batching"),
    Gate("census_empty", "load.census_empty", "truthy",
         message="stores not empty after drain: departing requests are "
                 "leaking checkpoint slots"),
]


def check_against_baseline(record: dict) -> list[str]:
    return _obs_check(record, GATES, BASELINE_PATH, bench="serve_load",
                      registry=DEFAULT_REGISTRY)


def main(smoke: bool = False, out_path: str = "BENCH_7.json",
         check: bool = False) -> dict:
    if smoke:
        cfg = dict(n_requests=24, arrive_per_step=3, n_steps=16,
                   segment=4, snaps_in_ram=8)
    else:
        cfg = dict(n_requests=200, arrive_per_step=4, n_steps=64,
                   segment=8, snaps_in_ram=32)
    print("== serve_load: open-loop CNF density/score service ==")
    load = bench_load(**cfg)
    record = {"bench": "serve_load", "smoke": smoke, "load": load}
    Path(out_path).write_text(json.dumps(record, indent=2))
    print(f"[serve_load] wrote {out_path}")
    if check:
        errs = check_against_baseline(record)
        for e in errs:
            print(f"[serve_load] BASELINE REGRESSION: {e}")
        if errs:
            raise SystemExit(1)
        print("[serve_load] serve gates within baseline")
    return record


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv, check="--check" in sys.argv)
