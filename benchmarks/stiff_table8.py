"""Paper §5.3 / Table 8 / Fig. 5: learning stiff Robertson dynamics —
implicit Crank-Nicolson (PNODE-only capability) vs adaptive explicit Dopri5.

Reports NFE-F / NFE-B / time per iteration and the gradient-norm behaviour
(Dopri5's gradients blow up as the learned model stiffens; CN's stay tame)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, time_call
from repro.core.adaptive import odeint_adaptive
from repro.core.implicit import odeint_implicit
from repro.models.ode_nets import mlp_vf, mlp_vf_init

jax.config.update("jax_enable_x64", True)

K1, K2, K3 = 0.04, 3e7, 1e4


def robertson_rhs(u):
    u1, u2, u3 = u[..., 0], u[..., 1], u[..., 2]
    return jnp.stack([
        -K1 * u1 + K3 * u2 * u3,
        K1 * u1 - K2 * u2 ** 2 - K3 * u2 * u3,
        K2 * u2 ** 2,
    ], axis=-1)


def robertson_data(n_pts: int = 40):
    """Ground truth via a tiny implicit solve on log-spaced output times."""
    ts = np.logspace(-5, 2, n_pts)
    u = jnp.array([1.0, 0.0, 0.0])
    out = [np.asarray(u)]
    t_prev = 0.0

    def f(uu, _th, _t):
        return robertson_rhs(uu)

    for t in ts:
        n = 20
        u = odeint_implicit(f, u, 0.0, dt=(t - t_prev) / n, n_steps=n,
                            t0=t_prev, method="beuler", newton_iters=20)
        out.append(np.asarray(u))
        t_prev = float(t)
    return np.array(ts), np.array(out[1:])


def minmax_scale(y):
    lo, hi = y.min(axis=0), y.max(axis=0)
    return (y - lo) / (hi - lo + 1e-12), (lo, hi)


def bench(train_iters: int = 30) -> None:
    ts, y = robertson_data(20)
    y_s, _ = minmax_scale(y)
    y0 = jnp.asarray(y_s[0])
    target = jnp.asarray(y_s)
    theta = mlp_vf_init(jax.random.PRNGKey(0), 3, hidden=32, n_hidden=3)

    N_CN, NEWTON, GMRES = 40, 5, 10

    # --- Crank-Nicolson (fixed steps over the scaled horizon) ---
    def loss_cn(theta):
        uf = odeint_implicit(mlp_vf, y0, theta, dt=1.0 / N_CN, n_steps=N_CN,
                             method="cn", newton_iters=NEWTON,
                             gmres_iters=GMRES)
        return jnp.mean(jnp.abs(uf - target[-1]))

    # --- adaptive Dopri5 ---
    def loss_dopri(theta):
        uf, info = odeint_adaptive(mlp_vf, y0, theta, t0=0.0, t1=1.0,
                                   rtol=1e-6, atol=1e-6, max_steps=1024)
        return jnp.mean(jnp.abs(uf - target[-1]))

    # NFE model (counting every f linearization/evaluation):
    #   CN fwd: per step 1 f_n + <=NEWTON x (residual f + GMRES jvp actions)
    #   CN bwd: per step transposed solve (<=GMRES vjp actions) + 2 vjps
    #   Dopri5: info.nfe_forward exact; bwd = 6 linearizations per accepted
    _, info = odeint_adaptive(mlp_vf, y0, theta, t0=0.0, t1=1.0,
                              rtol=1e-6, atol=1e-6, max_steps=1024)
    nfe = {"CN": (N_CN * (1 + NEWTON * (2 + GMRES)),
                  N_CN * (GMRES + 2)),
           "Dopri5": (int(info.nfe_forward),
                      6 * int(info.n_accepted))}

    print("== stiff_table8 (Robertson; CN vs Dopri5) ==")
    print(fmt_row("method", "NFE-F", "NFE-B", "t/iter (s)", "grad norm",
                  widths=[10, 9, 9, 11, 12]))
    for name, loss in (("CN", loss_cn), ("Dopri5", loss_dopri)):
        g_fn = jax.jit(jax.value_and_grad(loss))
        _, g = g_fn(theta)
        gn = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                                for x in jax.tree_util.tree_leaves(g))))
        t = time_call(g_fn, theta, warmup=1, iters=2)
        print(fmt_row(name, nfe[name][0], nfe[name][1], f"{t:.3f}",
                      f"{gn:.3e}", widths=[10, 9, 9, 11, 12]))


def main() -> None:
    bench()


if __name__ == "__main__":
    main()
