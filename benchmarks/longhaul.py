"""Long-horizon trajectory benchmark (BENCH_6): the multi-tier (RAM/disk)
checkpoint stack at ROADMAP-scale step counts — the run the pre-PR-9
O(N) callback paths made infeasible.

  fixed      an N_t >= 10^6 (full mode) fixed-step rk4 trajectory
             gradient under a RAM budget the host-only tier CANNOT
             satisfy: the checkpoint slots split ``snaps_in_ram``/disk
             (dolfin-adjoint multistage), forward+reverse data callbacks
             stay O(N_t/segment) — gated EXACTLY against the recorded
             baseline — and the store's RAM-resident peak stays under
             the budget while the disk tier absorbs the overflow.
  adaptive   an adaptive dopri5 trajectory (>= 10^5 accepted steps in
             full mode) through the segment-flushed staging ring:
             forward write callbacks <= ceil(n_attempted/segment)+1.
             The pre-PR-9 sweep paid one host callback per ATTEMPTED
             step (`write_at` inside the while_loop body).
  bitwise    disk-tier and split-tier gradients bitwise-identical to the
             device oracle on a small control problem — the tier
             contract the big runs rely on, checked where a device
             oracle is still affordable.

``main(check=True)`` (CI bench-smoke) gates the record against
``benchmarks/bench6_baseline.json`` via the unified ``repro.obs.baseline``
checker: exact callbacks-per-grad, RAM-peak-vs-budget, host-only
infeasibility, the adaptive forward bound, and the bitwise contracts.
"""
from __future__ import annotations

import json
import math
import resource
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.adaptive import odeint_adaptive
from repro.core.adjoint import odeint
from repro.mem.model import slot_bytes
from repro.mem.offload import reset_spill_stats, spill_stats
from repro.obs import (DEFAULT_REGISTRY, BaselineRef, Gate,
                       check_against_baseline as _obs_check)

BASELINE_PATH = Path(__file__).resolve().parent / "bench6_baseline.json"

D = 4  # small state: the point is trajectory LENGTH, not width


def _f(u, th, t):
    # cheap, parameter-coupled, with a fast forcing term: one rk4 step is
    # a handful of flops so 10^6 of them is an I/O-bound problem (the
    # regime under test), and the sin(20t) forcing keeps the adaptive
    # controller's step size small enough to accumulate real step counts
    return jnp.tanh(u * th) - 0.1 * u + jnp.sin(20.0 * t)


def _problem():
    u0 = jnp.linspace(-0.5, 0.5, D)
    th = jnp.linspace(0.8, 1.2, D)
    return u0, th


def _rss_bytes() -> int:
    # ru_maxrss is KiB on Linux
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def bench_fixed(n_steps: int, segment: int, snaps_in_ram: int) -> dict:
    """The headline run: fixed-step gradient with the checkpoint set split
    across RAM and disk under a budget host-only storage cannot meet."""
    u0, th = _problem()
    sb = slot_bytes("rk4", D * u0.dtype.itemsize)
    ram_budget = snaps_in_ram * sb
    host_only_bytes = n_steps * sb

    def loss(th_):
        uf = odeint(_f, u0, th_, dt=1e-3, n_steps=n_steps, method="rk4",
                    adjoint="pnode", offload="spill",
                    offload_segment=segment, snaps_in_ram=snaps_in_ram)
        return jnp.sum(uf ** 2)

    gfn = jax.jit(jax.grad(loss))
    jax.block_until_ready(gfn(th))  # compile
    reset_spill_stats()
    t0 = time.perf_counter()
    g = gfn(th)
    jax.block_until_ready(g)
    wall = time.perf_counter() - t0
    st = spill_stats()
    n_segments = math.ceil(n_steps / segment)

    rec = {
        "n_steps": n_steps, "segment": segment, "n_segments": n_segments,
        "snaps_in_ram": snaps_in_ram,
        "slot_bytes": sb,
        "ram_budget_bytes": ram_budget,
        "host_only_ckpt_bytes": host_only_bytes,
        "host_only_exceeds_ram_budget": host_only_bytes > ram_budget,
        "callbacks_per_grad": st["write_cb"] + st["read_cb"],
        "callbacks_per_step_api": 2 * n_steps,  # the pre-PR cost
        "write_cb": st["write_cb"], "read_cb": st["read_cb"],
        "dispatch_cb": st["dispatch_cb"],
        "prefetch_hit_cb": st["prefetch_hit_cb"],
        "ram_bytes_peak": st["ram_bytes_peak"],
        "ram_peak_under_budget": st["ram_bytes_peak"] <= ram_budget,
        "disk_write_bytes": st["disk_write_bytes"],
        "disk_read_bytes": st["disk_read_bytes"],
        "process_rss_bytes": _rss_bytes(),
        "grad_finite": bool(jnp.all(jnp.isfinite(g))),
        "wall_s": wall,
    }
    print(f"fixed: N_t={n_steps} grad in {wall:.1f}s; "
          f"{rec['callbacks_per_grad']} data callbacks "
          f"(pre-PR per-step API: {rec['callbacks_per_step_api']}); "
          f"store RAM peak {st['ram_bytes_peak']} B <= budget "
          f"{ram_budget} B: {rec['ram_peak_under_budget']} "
          f"(host-only would need {host_only_bytes} B); "
          f"disk absorbed {st['disk_write_bytes']} B")
    return rec


def bench_adaptive(max_steps: int, segment: int, t1: float) -> dict:
    """The staging-ring run: forward callbacks bounded by segments of
    ACCEPTED steps, not one per attempted step."""
    u0, th = _problem()

    def loss(th_):
        uf, info = odeint_adaptive(_f, u0, th_, t0=0.0, t1=t1,
                                   rtol=1e-6, atol=1e-6,
                                   max_steps=max_steps,
                                   offload="spill",
                                   offload_segment=segment)
        return jnp.sum(uf ** 2), info

    gfn = jax.jit(jax.value_and_grad(loss, has_aux=True))
    jax.block_until_ready(gfn(th))  # compile
    reset_spill_stats()
    t0 = time.perf_counter()
    (_, info), g = gfn(th)
    jax.block_until_ready(g)
    wall = time.perf_counter() - t0
    st = spill_stats()
    n_acc = int(info.n_accepted)
    n_att = n_acc + int(info.n_rejected)
    bound = math.ceil(n_att / segment) + 1

    rec = {
        "max_steps": max_steps, "segment": segment,
        "n_accepted": n_acc, "n_attempted": n_att,
        "forward_write_cb": st["write_cb"],
        "forward_cb_bound": bound,
        "forward_cb_within_bound": st["write_cb"] <= bound,
        "forward_cb_per_attempt_api": n_att,  # the pre-PR cost
        "read_cb": st["read_cb"],
        "dispatch_cb": st["dispatch_cb"],
        "prefetch_hit_cb": st["prefetch_hit_cb"],
        "grad_finite": bool(jnp.all(jnp.isfinite(g))),
        "wall_s": wall,
    }
    print(f"adaptive: {n_acc} accepted / {n_att} attempted in {wall:.1f}s; "
          f"forward writes {st['write_cb']} <= ceil(n_att/seg)+1={bound} "
          f"(pre-PR staging: {n_att} callbacks); reverse reads "
          f"{st['read_cb']}, async hits {st['prefetch_hit_cb']}")
    return rec


def bench_bitwise(n_steps: int = 48) -> dict:
    """Tier contract on a control problem small enough for a device
    oracle: disk and RAM/disk-split gradients must be bit-identical."""
    u0, th = _problem()

    def grad(adjoint="pnode", **kw):
        def loss(th_):
            uf = odeint(_f, u0, th_, dt=0.01, n_steps=n_steps,
                        method="rk4", adjoint=adjoint,
                        ncheck=6 if adjoint != "pnode" else None, **kw)
            return jnp.sum(uf ** 2)

        return jax.jit(jax.grad(loss))(th)

    g_dev = grad()
    out = {}
    for name, kw in (("spill", dict(offload="spill")),
                     ("disk", dict(offload="disk")),
                     ("split", dict(offload="spill", snaps_in_ram=3,
                                    offload_segment=2))):
        out[name] = bool(jnp.all(grad(**kw) == g_dev))
    # host is slot-addressed (revolve only): disk must match it bitwise
    g_host = grad(adjoint="revolve", offload="host")
    g_rdisk = grad(adjoint="revolve", offload="disk")
    out["disk_vs_host"] = bool(jnp.all(g_rdisk == g_host))
    print("bitwise vs device oracle: " +
          ", ".join(f"{k}={v}" for k, v in out.items()))
    return out


#: BENCH_6 regression gates (unified repro.obs.baseline checker): the CI
#: guard that the multi-tier stack stays O(N/seg) in callbacks, under its
#: RAM budget, and bitwise across media.
GATES = [
    Gate("smoke_config", "fixed.n_steps", "==",
         BaselineRef("smoke_n_steps"), precondition=True,
         message="callback counts scale with problem size; the baseline "
                 "is recorded for the --smoke configuration — re-run "
                 "with --smoke to compare against it"),
    Gate("fixed_callbacks", "fixed.callbacks_per_grad", "==",
         BaselineRef("fixed_callbacks_per_grad"),
         message="fixed-step data callbacks per grad changed (exact "
                 "O(N/seg) gate)"),
    Gate("fixed_ram_budget", "fixed.ram_peak_under_budget", "truthy",
         message="store RAM peak exceeded the snaps_in_ram budget"),
    Gate("fixed_host_infeasible", "fixed.host_only_exceeds_ram_budget",
         "truthy",
         message="the benchmark no longer exercises a budget host-only "
                 "storage cannot satisfy"),
    Gate("fixed_disk_used", "fixed.disk_write_bytes", ">",
         0, message="no bytes reached the disk tier"),
    Gate("adaptive_forward_cb", "adaptive.forward_cb_within_bound",
         "truthy",
         message="adaptive forward callbacks exceed ceil(n_att/seg)+1 — "
                 "the O(N) staging path is back"),
    Gate("bitwise_disk", "bitwise.disk", "truthy",
         message="disk-tier grads no longer bitwise vs device"),
    Gate("bitwise_split", "bitwise.split", "truthy",
         message="RAM/disk-split grads no longer bitwise vs device"),
    Gate("bitwise_disk_vs_host", "bitwise.disk_vs_host", "truthy",
         message="revolve disk-tier grads diverged from the host tier"),
]


def check_against_baseline(record: dict) -> list[str]:
    return _obs_check(record, GATES, BASELINE_PATH, bench="longhaul",
                      registry=DEFAULT_REGISTRY)


def main(smoke: bool = False, out_path: str = "BENCH_6.json",
         check: bool = False) -> dict:
    if smoke:
        fixed_cfg = dict(n_steps=20_000, segment=200, snaps_in_ram=4_000)
        adaptive_cfg = dict(max_steps=2_000, segment=100, t1=100.0)
    else:
        # ROADMAP item 4: N_t >= 10^6 fixed, >= 10^5 accepted adaptive
        fixed_cfg = dict(n_steps=1_000_000, segment=1_000,
                         snaps_in_ram=100_000)
        adaptive_cfg = dict(max_steps=125_000, segment=500, t1=7_000.0)
    print("== longhaul: fixed-step multi-tier (RAM/disk) grad ==")
    fixed = bench_fixed(**fixed_cfg)
    print("== longhaul: adaptive staging-ring grad ==")
    adaptive = bench_adaptive(**adaptive_cfg)
    print("== longhaul: tier bitwise contract ==")
    bitwise = bench_bitwise()
    record = {"bench": "longhaul", "smoke": smoke, "fixed": fixed,
              "adaptive": adaptive, "bitwise": bitwise}
    Path(out_path).write_text(json.dumps(record, indent=2))
    print(f"[longhaul] wrote {out_path}")
    if check:
        errs = check_against_baseline(record)
        for e in errs:
            print(f"[longhaul] BASELINE REGRESSION: {e}")
        if errs:
            raise SystemExit(1)
        print("[longhaul] multi-tier gates within baseline")
    return record


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv, check="--check" in sys.argv)
