"""Paper Tables 3-7: CNF density-estimation performance per integration
scheme x framework policy on the three tabular datasets (POWER 6-d,
MINIBOONE 43-d, BSDS300 63-d — synthetic stand-ins with the paper's dims,
batch sizes, and step counts; the datasets aren't available offline).

Columns mirror the paper: NFE-F, NFE-B, time/iteration, memory (XLA
compiled temp+arg bytes standing in for nvidia-smi GPU GiB)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_bytes, fmt_row, gib, time_call
from repro.core.cnf import cnf_log_prob
from repro.models.ode_nets import cnf_vf, cnf_vf_init

# dataset stand-ins: (dim, batch, hidden) per FFJORD's tuned configs
DATASETS = {
    "POWER": (6, 512, (64, 64, 64)),       # paper batch 10000: scaled to CPU
    "MINIBOONE": (43, 256, (171, 171)),
    "BSDS300": (63, 128, (128, 128)),
}

# scheme -> N_t per dataset, matching Tables 3-7 row headers
SCHEMES = {
    "euler": {"POWER": 50, "MINIBOONE": 20, "BSDS300": 100},
    "midpoint": {"POWER": 40, "MINIBOONE": 16, "BSDS300": 80},
    "bosh3": {"POWER": 30, "MINIBOONE": 12, "BSDS300": 60},
    "rk4": {"POWER": 20, "MINIBOONE": 8, "BSDS300": 40},
    "dopri5": {"POWER": 10, "MINIBOONE": 4, "BSDS300": 20},
}

FRAMEWORKS = [("naive", {}), ("continuous", {}), ("anode", {}), ("aca", {}),
              ("pnode", {}), ("pnode2", {})]


def bench_cell(dataset: str, scheme: str, policy: str, pkw: dict,
               iters: int = 2) -> dict:
    dim, batch, hidden = DATASETS[dataset]
    n_steps = SCHEMES[scheme][dataset]
    theta = cnf_vf_init(jax.random.PRNGKey(0), dim, hidden=hidden)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, dim))

    def nll(theta, x):
        lp = cnf_log_prob(cnf_vf, x, theta, dt=1.0 / n_steps,
                          n_steps=n_steps, method=scheme, adjoint=policy,
                          **pkw)
        return -lp.mean()

    grad_fn = jax.jit(jax.value_and_grad(nll))

    # analytic NFE accounting — validated against runtime-counted f calls in
    # tests/test_adjoint.py::test_nfe_accounting (eager counting here would
    # take minutes per cell on CPU)
    from repro.core.adjoint import nfe_backward, nfe_forward
    nfe_f = nfe_forward(scheme, n_steps)
    nfe_b = nfe_backward(scheme, n_steps, policy, pkw.get("ncheck"))

    t = time_call(grad_fn, theta, x, warmup=1, iters=iters)
    mem = compiled_bytes(jax.value_and_grad(nll), theta, x)
    return {"nfe_f": nfe_f, "nfe_b": nfe_b, "time_s": t,
            "mem_bytes": mem["total"]}


def main(quick: bool = True) -> None:
    schemes = ["euler", "dopri5"] if quick else list(SCHEMES)
    datasets = ["POWER", "MINIBOONE"] if quick else list(DATASETS)
    for scheme in schemes:
        print(f"== cnf_tables ({scheme}; paper Tables 3-7) ==")
        print(fmt_row("dataset", "framework", "N_t", "NFE-F", "NFE-B",
                      "t/iter (s)", "mem (GiB)",
                      widths=[10, 11, 5, 7, 7, 11, 10]))
        for ds in datasets:
            for pol, kw in FRAMEWORKS:
                try:
                    r = bench_cell(ds, scheme, pol, kw)
                    print(fmt_row(ds, pol, SCHEMES[scheme][ds], r["nfe_f"],
                                  r["nfe_b"], f"{r['time_s']:.3f}",
                                  gib(r["mem_bytes"]),
                                  widths=[10, 11, 5, 7, 7, 11, 10]))
                except Exception as e:  # noqa: BLE001
                    print(fmt_row(ds, pol, SCHEMES[scheme][ds], "-", "-",
                                  "FAIL", type(e).__name__,
                                  widths=[10, 11, 5, 7, 7, 11, 10]))


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
