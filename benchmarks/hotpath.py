"""Reverse-pass hot-path benchmark (BENCH_3): the three PR-3 claims,
measured.

  spill_io   host callbacks per reverse pass on the spill tier: the
             segment-batched write_batch/prefetch API issues one callback
             per checkpoint *segment* (2*ceil(N_t/seg) per grad) instead
             of one per step (2*N_t) — counted host-side via
             ``repro.mem.offload.spill_stats`` under jit, plus reverse-pass
             wall-clock for the device / spill / fused variants.
  adaptive   the masked reverse sweep's f-evaluations scale with accepted
             steps: a pure_callback tap inside f counts runtime f
             evaluations under jit (callbacks are faithfully executed in
             compiled programs; the eager path may elide them on
             jax 0.4.37), asserting reverse NFE <= sa*(n_accepted+1)
             rather than the pre-PR sa*max_steps; the spill tier's
             prefetch counters independently show only segments
             intersecting the accepted prefix are fetched.
  fused      fused_stages=True grads are bitwise-identical to the unfused
             path for every tableau (jit), with wall-clock columns.

``main(check=True)`` (the CI bench-smoke mode) compares the measured
callback counts against ``benchmarks/bench3_baseline.json`` and exits
nonzero on regression (more host callbacks than the recorded baseline).
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row
from repro.core.adaptive import odeint_adaptive
from repro.core.adjoint import adjoint_stages, odeint
from repro.mem.offload import (default_segment, reset_spill_stats,
                               spill_stats)
from repro.obs import (DEFAULT_REGISTRY, BaselineRef, FevalCounter, Gate,
                       check_against_baseline as _obs_check)

BASELINE_PATH = Path(__file__).resolve().parent / "bench3_baseline.json"

D, HID, BATCH = 32, 64, 4
TABLEAUS = ("euler", "midpoint", "bosh3", "rk4", "dopri5")


def _problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    u0 = jax.random.normal(ks[0], (BATCH, D))
    th = {"w1": 0.05 * jax.random.normal(ks[1], (D, HID)),
          "w2": 0.05 * jax.random.normal(ks[2], (HID, D))}

    def f(u, theta, t):
        return jnp.tanh(u @ theta["w1"]) @ theta["w2"]

    return f, u0, th


def _timeit(fn, *args, repeat: int = 3) -> float:
    fn(*args)  # warm: compile outside the timed region
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _grad_fn(f, u0, th, **kw):
    def loss(u0_, th_):
        return jnp.sum(odeint(f, u0_, th_, **kw) ** 2)

    return jax.jit(jax.grad(loss, argnums=(0, 1)))


def _bitwise_equal(a, b) -> bool:
    return all(bool((x == y).all()) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def bench_spill_io(n_steps: int) -> dict:
    f, u0, th = _problem()
    seg = default_segment(n_steps)
    kw = dict(dt=0.05, n_steps=n_steps, method="rk4", adjoint="pnode")
    g_dev = _grad_fn(f, u0, th, **kw)
    g_spl = _grad_fn(f, u0, th, offload="spill", **kw)
    g_fus = _grad_fn(f, u0, th, fused_stages=True, **kw)

    out_dev = g_dev(u0, th)
    reset_spill_stats()
    out_spl = g_spl(u0, th)
    jax.block_until_ready(out_spl)
    stats = spill_stats()
    n_segments = math.ceil(n_steps / seg)
    rec = {
        "n_steps": n_steps, "segment": seg, "n_segments": n_segments,
        "callbacks_per_reverse_pass": stats["write_cb"] + stats["read_cb"],
        "callbacks_per_step_api": 2 * n_steps,  # the pre-PR cost
        "write_cb": stats["write_cb"], "read_cb": stats["read_cb"],
        "write_slots": stats["write_slots"],
        "read_slots": stats["read_slots"],
        "grads_bitwise_identical": _bitwise_equal(out_dev, out_spl),
        "wall_s": {
            "pnode_device": _timeit(g_dev, u0, th),
            "pnode_spill_batched": _timeit(g_spl, u0, th),
            "pnode_fused": _timeit(g_fus, u0, th),
        },
    }
    print(f"spill I/O: {rec['callbacks_per_reverse_pass']} host callbacks "
          f"per grad (segment={seg}) vs {rec['callbacks_per_step_api']} "
          f"with per-step I/O; grads bitwise identical: "
          f"{rec['grads_bitwise_identical']}")
    return rec


def bench_adaptive(max_steps: int) -> dict:
    _, u0, th = _problem()

    def base(u, theta, t):
        # t-dependent so the counter tap's output feeds the computation
        # (a t-independent field would let XLA dead-code the tap away)
        return jnp.tanh(u @ theta["w1"]) @ theta["w2"] + 0.01 * t * u

    f = FevalCounter(base)
    t_span = dict(t0=0.0, t1=0.8, rtol=1e-6, atol=1e-6)
    sa = adjoint_stages("dopri5")

    def fwd(u0_, th_):
        uf, info = odeint_adaptive(f, u0_, th_, max_steps=max_steps,
                                   **t_span)
        return uf, info

    def loss(u0_, th_):
        uf, _ = odeint_adaptive(f, u0_, th_, max_steps=max_steps, **t_span)
        return jnp.sum(uf ** 2)

    def count_grad(ms: int) -> int:
        def loss_ms(u0_, th_):
            uf, _ = odeint_adaptive(f, u0_, th_, max_steps=ms, **t_span)
            return jnp.sum(uf ** 2)

        gj = jax.jit(jax.grad(loss_ms, argnums=(0, 1)))
        jax.block_until_ready(gj(u0, th))  # compile
        jax.block_until_ready(gj(u0, th))  # drain compile-run stragglers
        f.reset()
        jax.block_until_ready(gj(u0, th))
        return f.count

    fwd_j = jax.jit(fwd)
    grad_j = jax.jit(jax.grad(loss, argnums=(0, 1)))
    _, info = fwd_j(u0, th)
    n_acc = int(info.n_accepted)
    # the forward while_loop evaluates exactly N_s stages per iteration —
    # info.nfe_forward counts them (a fwd-only jit would under-count the
    # taps: XLA dead-codes stage math feeding only the discarded buffers;
    # CSE can also merge same-t stage taps, so measured counts are a LOWER
    # bound on true evals — fine for the <= bound below, and the
    # max_steps-invariance check is immune to it)
    fwd_evals = int(info.nfe_forward)
    grad_evals = count_grad(max_steps)
    grad_evals_2x = count_grad(2 * max_steps)
    reverse_evals = grad_evals - fwd_evals
    # measured callback counts are exact per compiled program but can
    # drift by +-1 per call site across program variants (CSE merges
    # same-t stage taps; some variants run each site once extra) — allow
    # one execution of slack per tap site when checking the bound; the
    # max_steps-invariance check below is exact and immune to this.
    from repro.core.tableaus import get_tableau
    tap_sites = get_tableau("dopri5").num_stages + sa  # fwd + adjoint sites
    bound = sa * (n_acc + 1)
    jax.block_until_ready(grad_j(u0, th))  # compile (for the timing below)

    # spill tier: prefetch only touches segments in the accepted prefix
    seg = default_segment(max_steps)

    def loss_spill(u0_, th_):
        uf, _ = odeint_adaptive(f, u0_, th_, max_steps=max_steps,
                                offload="spill", **t_span)
        return jnp.sum(uf ** 2)

    grad_spill_j = jax.jit(jax.grad(loss_spill, argnums=(0, 1)))
    jax.block_until_ready(grad_spill_j(u0, th))  # compile
    reset_spill_stats()
    jax.block_until_ready(grad_spill_j(u0, th))
    st = spill_stats()

    rec = {
        "max_steps": max_steps, "n_accepted": n_acc,
        "adjoint_stages": sa,
        "forward_fevals": fwd_evals,
        "reverse_fevals": reverse_evals,
        "reverse_fevals_bound": bound,
        "tap_site_slack": tap_sites,
        "reverse_fevals_premasking": sa * max_steps,
        "reverse_scales_with_accepted":
            reverse_evals <= bound + tap_sites,
        "grad_fevals_at_max_steps": grad_evals,
        "grad_fevals_at_2x_max_steps": grad_evals_2x,
        "invariant_in_max_steps": grad_evals_2x == grad_evals,
        "spill_prefetch_cb": st["read_cb"],
        "spill_prefetch_slots": st["read_slots"],
        "spill_prefetch_cb_bound": math.ceil(n_acc / seg) + 1,
        "wall_s": {
            "grad_device": _timeit(grad_j, u0, th),
            "grad_spill": _timeit(grad_spill_j, u0, th),
        },
    }
    print(f"adaptive: reverse NFE {reverse_evals} <= "
          f"{sa}*(n_acc={n_acc}+1)={rec['reverse_fevals_bound']} "
          f"(pre-masking cost {rec['reverse_fevals_premasking']}); "
          f"NFE invariant in max_steps: {rec['invariant_in_max_steps']} "
          f"({grad_evals} @ {max_steps} vs {grad_evals_2x} @ "
          f"{2 * max_steps}); spill prefetch {st['read_cb']} cb / "
          f"{st['read_slots']} slots of {max_steps}")
    return rec


def bench_fused() -> dict:
    f, u0, th = _problem()
    rows = {}
    print(fmt_row("tableau", "bitwise", "unfused_s", "fused_s",
                  widths=[10, 8, 10, 10]))
    for method in TABLEAUS:
        kw = dict(dt=0.05, n_steps=16, method=method, adjoint="pnode")
        g0 = _grad_fn(f, u0, th, **kw)
        g1 = _grad_fn(f, u0, th, fused_stages=True, **kw)
        same = _bitwise_equal(g0(u0, th), g1(u0, th))
        t0s = _timeit(g0, u0, th)
        t1s = _timeit(g1, u0, th)
        rows[method] = {"grads_bitwise_identical": same,
                        "unfused_s": t0s, "fused_s": t1s}
        print(fmt_row(method, same, f"{t0s:.4f}", f"{t1s:.4f}",
                      widths=[10, 8, 10, 10]))
    return rows


#: BENCH_3 regression gates, declared as data and evaluated by the
#: unified ``repro.obs.baseline`` checker (same machinery as BENCH_4) —
#: the CI guard for the batched-I/O win.
GATES = [
    Gate("smoke_config", "spill_io.n_steps", "==",
         BaselineRef("smoke_n_steps"), precondition=True,
         message="callback counts scale with problem size; the baseline "
                 "is recorded for the --smoke configuration — re-run "
                 "with --smoke to compare against it"),
    Gate("spill_callbacks", "spill_io.callbacks_per_reverse_pass", "<=",
         BaselineRef("spill_io_callbacks_per_reverse_pass"),
         message="segment-batched reverse-pass host callbacks regressed"),
    Gate("spill_bitwise", "spill_io.grads_bitwise_identical", "truthy",
         message="spill grads no longer bitwise-identical to device"),
    Gate("adaptive_masked", "adaptive.reverse_scales_with_accepted",
         "truthy",
         message="adaptive reverse NFE exceeds sa*(n_accepted+1)"),
    Gate("adaptive_invariant", "adaptive.invariant_in_max_steps", "truthy",
         message="adaptive reverse NFE grew with max_steps"),
    Gate("adaptive_prefetch", "adaptive.spill_prefetch_cb", "<=",
         BaselineRef("adaptive_spill_prefetch_cb_max"),
         message="adaptive prefetch callbacks regressed"),
    Gate("fused_bitwise", "fused.*.grads_bitwise_identical", "truthy",
         message="fused_stages grads diverged"),
]


def check_against_baseline(record: dict) -> list[str]:
    """Evaluate the BENCH_3 gates against the recorded baseline via the
    unified obs checker; returns failure messages (empty == pass)."""
    return _obs_check(record, GATES, BASELINE_PATH, bench="hotpath",
                      registry=DEFAULT_REGISTRY)


def main(smoke: bool = False, out_path: str = "BENCH_3.json",
         check: bool = False) -> dict:
    n_steps = 24 if smoke else 64
    max_steps = 128 if smoke else 512
    print("== hotpath: segment-batched spill I/O ==")
    spill_io = bench_spill_io(n_steps)
    print("== hotpath: masked adaptive reverse sweep ==")
    adaptive = bench_adaptive(max_steps)
    print("== hotpath: fused stage kernels ==")
    fused = bench_fused()
    record = {"bench": "hotpath", "smoke": smoke,
              "spill_io": spill_io, "adaptive": adaptive, "fused": fused}
    Path(out_path).write_text(json.dumps(record, indent=2))
    print(f"[hotpath] wrote {out_path}")
    if check:
        errs = check_against_baseline(record)
        for e in errs:
            print(f"[hotpath] BASELINE REGRESSION: {e}")
        if errs:
            raise SystemExit(1)
        print("[hotpath] callback counts within baseline")
    return record


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv, check="--check" in sys.argv)
