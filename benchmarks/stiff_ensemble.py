"""Benchmark 4: a stiff Robertson-style ensemble trained under a byte budget.

The workload the implicit memory stack exists for: >= 1000 vmapped
Robertson-type kinetics systems (per-element rate multipliers as the
learnable parameters), integrated with the theta-method family and trained
through the implicit discrete adjoint while the planner holds the
checkpoint set under a device-byte budget.

The budget is set just below the cheapest in-device candidate's peak, so
``plan_odeint`` must fall back to the segment-batched spill tier — the one
offload tier that composes with vmap (per-batch-element checkpoints ride
inside the batched host callbacks; one callback per segment serves the
whole ensemble).  What BENCH_4.json locks down:

  * callbacks_per_grad   2*ceil(n_steps/segment), independent of ensemble
                         size — regressions here mean per-element host
                         round-trips crept in;
  * nfe_backward         the plan's predicted NFE-B (pnode's implicit
                         optimum: n_steps extra transposed-GMRES solves,
                         no Newton recompute);
  * grads_bitwise        spill gradients == in-device gradients, bit for
                         bit, under jit+vmap;
  * diverged_fraction    0.0 — every Newton solve in the ensemble
                         converged (the stats plumbing would catch a
                         silently-diverging stiff element);
  * training             the loss actually decreases over the AdamW steps.

Counter reads sit behind ``jax.block_until_ready``: jitted calls return
before the host callbacks run, so an eager read undercounts.
"""
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core.implicit import odeint_implicit
from repro.mem.model import tree_bytes
from repro.mem.offload import default_segment, reset_spill_stats, spill_stats
from repro.mem.planner import candidate_costs, plan_odeint
from repro.obs import (DEFAULT_REGISTRY, BaselineRef, Gate,
                       check_against_baseline as _obs_check)
from repro.optim.adamw import AdamW

# Robertson kinetics: u1' = -k1 u1 + k3 u2 u3, u2' = k1 u1 - k3 u2 u3
# - k2 u2^2, u3' = k2 u2^2.  The classic stiffness ratio: k2/k1 ~ 1e9.
K_BASE = (0.04, 3.0e7, 1.0e4)
#: loss weights undo the ~1e-5 scale of the u2 component
LOSS_W = jnp.array([1.0, 1.0e4, 1.0])


def robertson_vf(u, c, t):
    """RHS with per-system log-multipliers c (shape (3,)) on the rates."""
    k1, k2, k3 = (b * jnp.exp(ci) for b, ci in zip(K_BASE, c))
    du1 = -k1 * u[0] + k3 * u[1] * u[2]
    du3 = k2 * u[1] ** 2
    return jnp.stack([du1, -du1 - du3, du3])


def _solve(u0, c, *, dt, n_steps, method, adjoint="pnode", offload=None,
           return_stats=False):
    return odeint_implicit(robertson_vf, u0, c, dt=dt, n_steps=n_steps,
                           method=method, adjoint=adjoint, offload=offload,
                           newton_iters=16, newton_tol=1e-10,
                           gmres_iters=5, gmres_tol=1e-12,
                           return_stats=return_stats)


def run_ensemble(batch=1024, n_steps=30, train_steps=5, dt=0.01, lr=0.05,
                 seed=0):
    """Train the ensemble under a spill-forcing budget; return the record."""
    # 16 Newton iters: the stiffest sampled elements converge linearly
    # (GMRES inexactness) and need >12 to hit newton_tol across the batch
    solver_opts = dict(newton_iters=16, gmres_iters=5)
    u0s = jnp.tile(jnp.array([1.0, 0.0, 0.0]), (batch, 1))
    key = jax.random.PRNGKey(seed)
    c_true = 0.2 * jax.random.normal(key, (batch, 3))
    c0 = jnp.zeros((batch, 3))

    # -- truth: the stiffness-robust end of the family (beuler) ------------
    truth = jax.jit(jax.vmap(lambda u, c: _solve(
        u, c, dt=dt, n_steps=n_steps, method="beuler")))(u0s, c_true)

    # -- plan: budget one byte below the cheapest in-device candidate ------
    cands = candidate_costs(method="cn", n_steps=n_steps,
                            state_bytes=tree_bytes(u0s),
                            theta_bytes=tree_bytes(c0),
                            solver_opts=solver_opts)
    budget = int(min(c.peak_bytes for c in cands)) - 1
    f_fold = jax.vmap(robertson_vf, in_axes=(0, 0, None))
    plan = plan_odeint(f_fold, u0s, c0, dt=dt, n_steps=n_steps, method="cn",
                       mem_budget=budget, verify="model",
                       solver_opts=solver_opts)
    assert plan.offload == "spill", plan

    def loss_fn(c, offload):
        uf = jax.vmap(lambda u, ci: _solve(
            u, ci, dt=dt, n_steps=n_steps, method="cn",
            adjoint=plan.policy, offload=offload))(u0s, c)
        return jnp.mean(jnp.sum((LOSS_W * (uf - truth)) ** 2, axis=-1))

    vgrad = jax.jit(jax.value_and_grad(lambda c: loss_fn(c, plan.offload)))
    vgrad_dev = jax.jit(jax.value_and_grad(lambda c: loss_fn(c, None)))

    # -- one warm gradient: time it and count the spill traffic ------------
    jax.block_until_ready(vgrad(c0))          # compile + warm the store
    reset_spill_stats()
    t0 = time.perf_counter()
    _, g_spill = vgrad(c0)
    jax.block_until_ready(g_spill)
    grad_seconds = time.perf_counter() - t0
    io = spill_stats()

    _, g_dev = vgrad_dev(c0)
    bitwise = bool(np.array_equal(np.asarray(g_spill), np.asarray(g_dev)))

    # -- convergence audit over the ensemble -------------------------------
    _, stats = jax.jit(jax.vmap(lambda u, c: _solve(
        u, c, dt=dt, n_steps=n_steps, method="cn",
        return_stats=True)))(u0s, c_true)
    diverged_fraction = float(jnp.mean(stats.diverged.astype(jnp.float64)))

    # -- train the rate multipliers under the plan -------------------------
    opt = AdamW(lr=lr, weight_decay=0.0, warmup_steps=1,
                total_steps=max(train_steps, 2))
    state = opt.init(c0)
    c, losses = c0, []
    for _ in range(train_steps):
        val, g = vgrad(c)
        losses.append(float(val))
        c, state, _ = opt.update(g, state, c)
    losses.append(float(vgrad(c)[0]))

    seg = default_segment(n_steps)
    return {
        "ensemble": int(batch),
        "n_steps": int(n_steps),
        "dt": float(dt),
        "method": "cn",
        "train_steps": int(train_steps),
        "plan": {
            "policy": plan.policy,
            "ncheck": plan.ncheck,
            "offload": plan.offload,
            "fits": bool(plan.fits),
            "budget_bytes": int(budget),
            "predicted_peak_bytes": int(plan.predicted.peak_bytes),
            "nfe_backward": int(plan.predicted.extra_fevals),
        },
        "effective_tier": "spill" if io["write_cb"] else "device",
        "segment": int(seg),
        "callbacks_per_grad": int(io["write_cb"] + io["read_cb"]),
        "write_cb": int(io["write_cb"]),
        "read_cb": int(io["read_cb"]),
        "write_slots": int(io["write_slots"]),
        "read_slots": int(io["read_slots"]),
        "grads_bitwise_vs_device": bitwise,
        "diverged_fraction": diverged_fraction,
        "losses": losses,
        "grad_seconds": float(grad_seconds),
    }


#: BENCH_4 regression gates, declared as data and evaluated by the
#: unified ``repro.obs.baseline`` checker (same machinery as BENCH_3).
GATES = [
    Gate("ensemble_size", "ensemble", ">=", BaselineRef("min_ensemble"),
         message="ensemble shrank below the recorded minimum"),
    Gate("spill_callbacks", "callbacks_per_grad", "<=",
         BaselineRef("max_callbacks_per_grad"),
         message="host callbacks per grad regressed"),
    Gate("nfe_backward", "plan.nfe_backward", "<=",
         BaselineRef("max_nfe_backward"), message="NFE-B regressed"),
    Gate("plan_spill", "plan.offload", "==", "spill",
         message="planner stopped selecting spill under the budget"),
    Gate("effective_spill", "effective_tier", "==", "spill",
         message="spill tier planned but no spill callbacks executed"),
    Gate("grads_bitwise", "grads_bitwise_vs_device", "truthy",
         message="spill gradients are not bitwise-identical to the "
                 "in-device gradients"),
    Gate("newton_converged", "diverged_fraction", "<=", 0.0,
         message="some of the ensemble's Newton solves diverged"),
    Gate("training", "loss_decreased", "truthy",
         message="training loss did not decrease"),
]


def check_against_baseline(rec, baseline_path="benchmarks/"
                           "bench4_baseline.json"):
    """Regression gates for CI; returns a list of error strings."""
    # derived field the declarative gate reads (first vs final loss)
    rec = dict(rec,
               loss_decreased=bool(rec["losses"][-1] < rec["losses"][0]))
    return _obs_check(rec, GATES, baseline_path, bench="stiff_ensemble",
                      registry=DEFAULT_REGISTRY)


def main(smoke=False, out_path="BENCH_4.json", check=False):
    if smoke:
        rec = run_ensemble(batch=1024, n_steps=30, train_steps=5)
    else:
        rec = run_ensemble(batch=2048, n_steps=60, train_steps=8)
    rec["smoke"] = bool(smoke)
    with open(out_path, "w") as fh:
        json.dump(rec, fh, indent=2)
    print(json.dumps(rec, indent=2))
    if check:
        errs = check_against_baseline(rec)
        if errs:
            for e in errs:
                print(f"BENCH_4 REGRESSION: {e}", file=sys.stderr)
            raise SystemExit(1)
        print("BENCH_4: all regression gates passed")
    return rec


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv, check="--check" in sys.argv)
