"""repro.mem planner: predicted vs measured reverse-pass memory + the
offload win, written to BENCH_2.json so the perf trajectory is tracked.

For a mid-sized neural vector field the section sweeps byte budgets,
lets ``plan_odeint`` choose the policy, and records

  * the analytic Table-2 prediction (ckpt + working-set bytes, NFE-B),
  * the measured peak of the lowered reverse pass (hlo_cost liveness and
    XLA's memory_analysis temp bytes),
  * whether the chosen policy actually fits the budget,

plus a pnode vs pnode+spill comparison showing the offload store removes
the O(N_t) checkpoint term from compiled device-live memory.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row
from repro.mem.model import measure_reverse_cost, tree_bytes
from repro.mem.planner import plan_odeint

D, HID, BATCH = 32, 64, 4


def _problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    u0 = jax.random.normal(ks[0], (BATCH, D))
    th = {"w1": 0.05 * jax.random.normal(ks[1], (D, HID)),
          "w2": 0.05 * jax.random.normal(ks[2], (HID, D))}

    def f(u, theta, t):
        return jnp.tanh(u @ theta["w1"]) @ theta["w2"]

    return f, u0, th


def main(smoke: bool = False, out_path: str = "BENCH_2.json") -> dict:
    f, u0, th = _problem()
    method, n_steps, dt = "dopri5", (6 if smoke else 10), 0.1
    kw = dict(dt=dt, n_steps=n_steps, method=method)

    # measured peaks of the named Table-2 points define the budget ladder
    anchors = {}
    for pol, nck in [("naive", None), ("pnode", None), ("pnode2", None),
                     ("revolve", max(1, n_steps // 4))]:
        anchors[f"{pol}" + (f"_nc{nck}" if nck else "")] = dict(
            policy=pol, ncheck=nck,
            **measure_reverse_cost(f, u0, th, policy=pol, ncheck=nck, **kw))

    print("== mem_plan: planner predicted vs measured (bytes) ==")
    print(fmt_row("budget", "chosen", "ncheck", "pred peak", "meas hlo",
                  "meas temp", "NFE-B", "fits", widths=[12, 10, 6, 12, 12,
                                                        12, 8, 5]))
    rows = []
    budgets = sorted({int(a["hlo_peak_bytes"]) for a in anchors.values()}
                     | {2 * int(anchors["naive"]["hlo_peak_bytes"])})
    for budget in budgets:
        plan = plan_odeint(f, u0, th, mem_budget=budget, **kw)
        meas = measure_reverse_cost(f, u0, th, policy=plan.policy,
                                    ncheck=plan.ncheck,
                                    offload=plan.offload, **kw)
        fits = meas["hlo_peak_bytes"] <= budget
        rows.append({
            "budget": budget, "policy": plan.policy, "ncheck": plan.ncheck,
            "offload": plan.offload,
            "predicted_peak_bytes": plan.predicted.peak_bytes,
            "predicted_extra_fevals": plan.predicted.extra_fevals,
            "measured_hlo_peak_bytes": meas["hlo_peak_bytes"],
            "measured_temp_bytes": meas["temp_bytes"],
            "fits": bool(fits),
        })
        print(fmt_row(budget, plan.policy, plan.ncheck,
                      plan.predicted.peak_bytes,
                      f"{meas['hlo_peak_bytes']:.0f}",
                      f"{meas['temp_bytes']:.0f}",
                      plan.predicted.extra_fevals, fits,
                      widths=[12, 10, 6, 12, 12, 12, 8, 5]))

    # offload: spilling pnode's checkpoints off device
    dev = measure_reverse_cost(f, u0, th, policy="pnode", **kw)
    spill = measure_reverse_cost(f, u0, th, policy="pnode", offload="spill",
                                 **kw)
    print(f"pnode offload: temp {dev['temp_bytes']:.0f} -> "
          f"{spill['temp_bytes']:.0f} B "
          f"(hlo peak {dev['hlo_peak_bytes']:.0f} -> "
          f"{spill['hlo_peak_bytes']:.0f})")

    record = {
        "bench": "mem_plan", "smoke": smoke, "method": method,
        "n_steps": n_steps, "state_bytes": tree_bytes(u0),
        "anchors": anchors, "plans": rows,
        "offload_pnode": {"device": dev, "spill": spill},
    }
    Path(out_path).write_text(json.dumps(record, indent=2))
    print(f"[mem_plan] wrote {out_path}")
    return record


if __name__ == "__main__":
    main()
